"""Cluster-runtime quickstart: straggler-tolerant training, measured.

Trains private logistic regression through the event-driven cluster
simulation (repro.cluster) under a heavy-tailed latency profile, then
replays the OBSERVED responder trace through the reference engine to show
the cluster layer changed timing only — the weights are bit-identical.

    PYTHONPATH=src python examples/cluster_quickstart.py
"""
import jax
import numpy as np

from repro.cluster import ClusterRunner, LognormalTailLatency
from repro.core import protocol
from repro.data import synthetic

cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=1000, d=64, margin=12.0)

latency = LognormalTailLatency(seed=0, tail_prob=0.1, tail_scale=10.0)
runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, latency)
w = runner.run(iters=20)

stats = runner.wait_stats()
print(f"threshold: decode from the fastest {cfg.threshold} of N={cfg.N}")
print(f"per-round wait: {stats['coded_T']['mean']:.2f}s (coded first-T) vs "
      f"{stats['wait_all']['mean']:.2f}s (wait-for-all)")
print(f"simulated run: {stats['coded_T']['total']:.1f}s vs "
      f"{stats['wait_all']['total']:.1f}s — "
      f"{stats['wait_all']['total'] / stats['coded_T']['total']:.2f}x faster")

# the cluster layer is timing-only: replaying its responder trace through
# the per-step reference engine reproduces the weights bit-for-bit.
w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                    iters=20, survivor_fn=runner.survivor_fn())
assert (np.asarray(w) == np.asarray(w_ref)).all()
print("bit-identical to train_reference over the same responder trace ✓")

_, xq = protocol.cleartext_baseline(cfg, x, y, 0)
_, acc = protocol.loss_and_accuracy(w, xq, y)
print(f"accuracy after 20 private iterations: {float(acc):.2%}")
