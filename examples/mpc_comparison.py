"""CodedPrivateML vs BGW-MPC (the paper's Fig. 2 / Table 1 comparison).

Both systems compute the SAME quantized polynomial gradient; only the
privacy machinery differs.  This prints the per-phase breakdown showing
where CPML's speedup comes from: 1/K-sized shares (encode+comp) and zero
worker<->worker rounds (comm).

    PYTHONPATH=src:. python examples/mpc_comparison.py
"""
import jax

from benchmarks import phases
from repro.core import mpc_baseline as mpc
from repro.data import synthetic


def main():
    N = 10
    x, y = synthetic.mnist_like(jax.random.PRNGKey(42), m=1200, d=128)
    print(f"N={N} workers, dataset {x.shape}; 3 iterations each\n")
    rows = [
        ("MPC (BGW, T=4)", phases.mpc_phase_times(
            mpc.MPCConfig(N=N, T=(N - 1) // 2), x, y, iters=3)),
        ("CPML case1 (K=3,T=1)", phases.cpml_phase_times(
            phases.case1(N), x, y, iters=3)),
        ("CPML case2 (K=2,T=2)", phases.cpml_phase_times(
            phases.case2(N), x, y, iters=3)),
    ]
    print(f"{'protocol':22s} {'encode':>8s} {'comm':>8s} {'comp':>8s} "
          f"{'total':>8s}")
    for name, t in rows:
        print(f"{name:22s} {t['encode']:8.2f} {t['comm']:8.2f} "
              f"{t['comp']:8.2f} {t['total']:8.2f}")
    base = rows[0][1]["total"]
    for name, t in rows[1:]:
        print(f"speedup {name}: {base / t['total']:.1f}x")


if __name__ == "__main__":
    main()
