"""Multi-class quickstart: 10-way private classification with CodedPrivateML.

The coded engine trains c = 10 one-vs-all logistic heads over a SINGLE set
of coded dataset shares — the dataset is quantized + Lagrange-encoded once,
and every round's worker pass serves all 10 heads (the X̃ read is amortized
across classes; see DESIGN.md §6).  Training runs as one jitted lax.scan.

Per-class accuracy is reported against the cleartext quantized baseline:
the same quantized dataset X̄, the TRUE sigmoid, the same iteration count.

    PYTHONPATH=src python examples/multiclass_quickstart.py
"""
import time

import jax

from repro.core import protocol
from repro.data import synthetic


def main():
    c = 10
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=c, batch_rows=256)
    print(f"CodedPrivateML: N={cfg.N} workers, K={cfg.K} parallel, "
          f"T={cfg.T}-private, {c} one-vs-all heads over ONE coded dataset, "
          f"mini-batches of {cfg.K * cfg.batch_rows} coded rows/round")

    x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(1), m=4000,
                                           d=256, c=c)
    t0 = time.time()
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=60,
                             eval_every=15)
    for h in hist:
        print(f"  iter {h['iter']:3d}  loss {h['loss']:.4f}  "
              f"acc {h['acc']:.2%}")
    print(f"trained 60 private iterations in {time.time()-t0:.1f}s "
          f"(one jitted scan, no per-step host round trips)")

    # cleartext quantized baseline: same X̄, true sigmoid, same step count
    wc, xq = protocol.cleartext_baseline(cfg, x, y, iters=60)

    acc_coded = protocol.per_class_accuracy(w, xq, y)
    acc_clear = protocol.per_class_accuracy(wc, xq, y)
    print(f"{'class':>5} {'coded':>8} {'cleartext':>10}")
    for cls in range(c):
        print(f"{cls:>5} {float(acc_coded[cls]):>8.2%} "
              f"{float(acc_clear[cls]):>10.2%}")
    _, overall = protocol.multiclass_loss_and_accuracy(w, xq, y)
    _, overall_c = protocol.multiclass_loss_and_accuracy(wc, xq, y)
    print(f"overall: coded {float(overall):.2%} vs cleartext "
          f"{float(overall_c):.2%}")


if __name__ == "__main__":
    main()
