"""Beyond-paper: Lagrange-coded LM head under shard failures.

Encodes a reduced tinyllama's vocab projection into N=6 coded TP shards
(K=4 useful + T=1 privacy mask + 1 spare), kills a shard, and shows the
decoded logits are bit-identical — straggler-tolerant tensor parallelism
built from the paper's coding machinery (core/coded_linear.py).

    PYTHONPATH=src python examples/coded_head_serving.py

Exits nonzero if either serving run fails, so CI can smoke it honestly.
"""
from repro.launch import serve


def main() -> int:
    print("=== coded LM head, no failures ===")
    rc = serve.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                     "--prompt-len", "16", "--gen", "4", "--coded-head",
                     "--coded-k", "4", "--coded-t", "1", "--coded-n", "6"])
    print("\n=== coded LM head, shard 2 killed ===")
    rc2 = serve.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                      "--prompt-len", "16", "--gen", "4", "--coded-head",
                      "--coded-k", "4", "--coded-t", "1", "--coded-n", "6",
                      "--kill-shard", "2"])
    return rc or rc2


if __name__ == "__main__":
    raise SystemExit(main())
