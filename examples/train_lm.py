"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full framework path — config -> mesh -> sharded init -> data
pipeline -> jit train step (remat, chunked loss, blockwise attention) ->
AdamW -> checkpointing.  The config is a 100M-scale member of the
tinyllama family (same code path as the 123B dry-run cells).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses

from repro.configs import registry
from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M-param llama-family config (d=512, 8 layers, 32k vocab)
    base = registry.get_config("tinyllama-1.1b")
    cfg100m = dataclasses.replace(
        base, name="llama-100m", num_layers=8, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048,
        block_pattern=(("dense", 8),))
    print(f"{cfg100m.name}: {cfg100m.param_count()/1e6:.0f}M params")

    rc = train.main(["--steps", str(args.steps),
                     "--batch", str(args.batch), "--seq", str(args.seq),
                     "--checkpoint-every", "100",
                     "--checkpoint-dir", "/tmp/repro_lm100m"],
                    config_override=cfg100m)
    raise SystemExit(rc)


if __name__ == "__main__":
    main()
