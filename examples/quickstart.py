"""Quickstart: privacy-preserving logistic regression with CodedPrivateML.

Reproduces the paper's core loop end-to-end on a synthetic MNIST-like task:
quantize -> Lagrange-encode (T-private) -> coded polynomial gradient on N
workers -> straggler-tolerant decode -> model update (paper Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol
from repro.data import synthetic


def main():
    # the paper's Case 2 at N=8: K = T = (N+2)/6 -> (2, 1); threshold 7
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    print(f"CodedPrivateML: N={cfg.N} workers, K={cfg.K} parallel, "
          f"T={cfg.T}-private, threshold={cfg.threshold} "
          f"(tolerates {cfg.N - cfg.threshold} stragglers)")

    x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=2000, d=256,
                                margin=12.0)
    t0 = time.time()
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=25,
                             eval_every=5)
    for h in hist:
        print(f"  iter {h['iter']:3d}  loss {h['loss']:.4f}  "
              f"acc {h['acc']:.2%}")
    print(f"trained 25 private iterations in {time.time()-t0:.1f}s")

    # straggler demo: drop one worker — identical model (erasure decode)
    state = protocol.setup(cfg, jax.random.PRNGKey(0), x, y)
    full = protocol.step(cfg, jax.random.PRNGKey(1), state, 0.5)
    drop = protocol.step(cfg, jax.random.PRNGKey(1), state, 0.5,
                         survivors=np.array([1, 2, 3, 4, 5, 6, 7]))
    same = bool(jnp.allclose(full.w, drop.w, atol=1e-6))
    print(f"worker-0 failure -> identical update from 7 survivors: {same}")


if __name__ == "__main__":
    main()
