"""Docs-consistency gate: README.md vs the actual CLIs (CI tier-1 leg).

Two checks, both of which fail the build (nonzero exit) when violated:

  1. FLAG EXISTENCE — every ``--flag`` documented in README (in the
     "``cpml_X`` flags at a glance" tables AND inside every quickstart
     ``sh`` snippet that invokes ``python -m repro.launch.X``) must exist
     in that module's ``--help`` output.  A flag rename or removal that
     forgets the README turns the build red instead of silently shipping
     stale docs.
  2. QUICKSTART EXECUTION — every runnable quickstart command under a
     "## Quickstart" heading is actually executed, at smoke shapes (the
     shape flags ``--m/--d/--iters/...`` are APPENDED, so argparse's
     last-wins overrides the documented values without editing the
     command), in one shared scratch directory so multi-command snippets
     (trace file -> validator) see each other's artifacts.  Commands
     containing ``<placeholders>`` are flag-checked but not executed.

    PYTHONPATH=src python tools/docs_check.py [--readme PATH] [--skip-run]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FLAG_RE = re.compile(r"--[A-Za-z][A-Za-z0-9-]*")
TABLE_LABEL_RE = re.compile(r"`(cpml_\w+)` flags at a glance")
MODULE_RE = re.compile(r"python -m (repro\.[\w.]+)")

# appended AFTER the documented flags (argparse last-wins) so every
# quickstart runs at CI-friendly shapes without rewriting the README
SMOKE_OVERRIDES = {
    # m=256 (not 96): the mini-batch quickstart's --batch-rows 64 needs
    # >= 64 rows per encoded part (padded m / K)
    "repro.launch.cpml_train": ["--m", "256", "--d", "12", "--iters", "2"],
    "repro.launch.cpml_cluster": ["--m", "96", "--d", "12", "--iters", "6"],
    "repro.launch.cpml_serve": ["--d", "12", "--queries", "4", "--rows", "4",
                                "--rate", "50"],
}
RUNNABLE_PREFIXES = ("repro.launch.", "repro.obs.")
PER_COMMAND_TIMEOUT_S = 420


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _help_text(module: str, cache: dict) -> str:
    if module not in cache:
        proc = subprocess.run([sys.executable, "-m", module, "--help"],
                              capture_output=True, text=True, timeout=120,
                              env=_env())
        assert proc.returncode == 0, (
            f"`python -m {module} --help` failed:\n{proc.stderr}")
        cache[module] = proc.stdout
    return cache[module]


def _flag_exists(flag: str, help_text: str) -> bool:
    return re.search(rf"(?<![\w-]){re.escape(flag)}(?![\w-])",
                     help_text) is not None


def _sh_blocks(lines: list[str]):
    """Yield (heading, [block lines]) for each fenced sh block."""
    heading, block, in_block = "", [], False
    for ln in lines:
        if ln.startswith("#") and not in_block:     # markdown heading, not
            heading = ln.strip("# \n")              # a shell comment
        if ln.strip().startswith("```"):
            if in_block:
                yield heading, block
                block = []
            in_block = ln.strip() == "```sh"
            continue
        if in_block:
            block.append(ln.rstrip("\n"))


def _join_continuations(block: list[str]) -> list[str]:
    cmds, cur = [], ""
    for ln in block:
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            continue
        cur += (" " if cur else "") + ln.rstrip("\\").strip()
        if not ln.endswith("\\"):
            cmds.append(cur)
            cur = ""
    if cur:
        cmds.append(cur)
    return cmds


def check_flags(lines: list[str], help_cache: dict) -> list[str]:
    errors = []
    # 1a. the "flags at a glance" tables
    module = None
    for ln in lines:
        label = TABLE_LABEL_RE.search(ln)
        if label:
            module = f"repro.launch.{label.group(1)}"
            continue
        if module and ln.startswith("|"):
            cells = ln.split("|")
            if len(cells) < 3 or set(cells[1].strip()) <= {"-", " "}:
                continue
            for flag in FLAG_RE.findall(cells[1]):
                if not _flag_exists(flag, _help_text(module, help_cache)):
                    errors.append(f"README documents `{flag}` for {module} "
                                  f"but --help does not list it")
        elif module and ln.strip() and not ln.startswith("|"):
            module = None                      # table ended
    # 1b. every flag used inside quickstart snippets
    for heading, block in _sh_blocks(lines):
        for cmd in _join_continuations(block):
            m = MODULE_RE.search(cmd)
            if not m or not m.group(1).startswith("repro.launch."):
                continue
            for flag in FLAG_RE.findall(cmd.split(m.group(1), 1)[1]):
                if not _flag_exists(flag,
                                    _help_text(m.group(1), help_cache)):
                    errors.append(f"quickstart under {heading!r} uses "
                                  f"`{flag}` but `{m.group(1)} --help` "
                                  f"does not list it")
    return errors


def run_quickstarts(lines: list[str]) -> list[str]:
    errors = []
    with tempfile.TemporaryDirectory(prefix="docs_check_") as scratch:
        for heading, block in _sh_blocks(lines):
            if not heading.lower().startswith("quickstart"):
                continue
            for cmd in _join_continuations(block):
                m = MODULE_RE.search(cmd)
                if not m or "<" in cmd:
                    continue
                module = m.group(1)
                if not module.startswith(RUNNABLE_PREFIXES):
                    continue
                argv = ([sys.executable, "-m"]
                        + cmd.split("python -m ", 1)[1].split()
                        + SMOKE_OVERRIDES.get(module, []))
                if "socket" in argv:
                    # generous wall-clock heartbeat: the docs gate checks
                    # that commands RUN, not that death-detection timing
                    # holds on a loaded CI box (tests + the slow job's
                    # elastic e2e own that).  Socket runs only — the flag
                    # perturbs sim resilience paths.
                    argv += ["--heartbeat-timeout", "15"]
                print(f"[docs_check] $ {' '.join(argv[2:])}", flush=True)
                try:
                    proc = subprocess.run(argv, capture_output=True,
                                          text=True, cwd=scratch,
                                          timeout=PER_COMMAND_TIMEOUT_S,
                                          env=_env())
                except subprocess.TimeoutExpired:
                    errors.append(f"quickstart timed out: {cmd}")
                    continue
                if proc.returncode != 0:
                    tail = (proc.stdout + proc.stderr)[-2000:]
                    errors.append(f"quickstart failed (rc "
                                  f"{proc.returncode}): {cmd}\n{tail}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--readme", default=os.path.join(REPO, "README.md"))
    ap.add_argument("--skip-run", action="store_true",
                    help="flag-existence check only (fast)")
    args = ap.parse_args()
    with open(args.readme) as f:
        lines = f.readlines()

    help_cache: dict[str, str] = {}
    errors = check_flags(lines, help_cache)
    n_flags = "OK" if not errors else f"{len(errors)} stale"
    print(f"[docs_check] flag tables + snippets vs --help: {n_flags}")
    if not args.skip_run:
        errors += run_quickstarts(lines)
    for e in errors:
        print(f"[docs_check] FAIL: {e}", file=sys.stderr)
    print(f"[docs_check] {'PASS' if not errors else 'FAIL'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
