"""Sigmoid surrogate (paper §3.3): fit quality, unbiasedness, scale algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field, quantize, sigmoid_poly as sp


def test_fit_quality():
    # deg 2 == deg 1 on a symmetric interval (sigmoid-0.5 is odd, c2 = 0)
    for r, tol in [(1, 0.15), (2, 0.15), (3, 0.05)]:
        c = sp.fit_sigmoid(r)
        z = np.linspace(sp.FIT_LO, sp.FIT_HI, 500)
        err = np.abs(np.polyval(list(reversed(c)), z) - 1 / (1 + np.exp(-z)))
        assert err.max() < tol, (r, err.max())


def test_lc_zero_degenerates():
    """Documents the paper's implicit-scale gap: at lc=0 the linear
    coefficient underflows to 0 (gradient signal vanishes)."""
    c = sp.quantized_coeffs(r=1, lx=2, lw=4, lc=0)
    assert c[1] == 0
    c6 = sp.quantized_coeffs(r=1, lx=2, lw=4, lc=6)
    assert c6[1] > 0


def test_gbar_unbiased(key):
    """E[ḡ(X̄, W̄)] = ĝ(X̄ w) over quantization draws (Eq. 18)."""
    d, m, r, lx, lw = 16, 32, 2, 2, 4
    x = jax.random.uniform(key, (m, d), minval=0, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,)) * 0.3
    xq = quantize.dequantize(quantize.quantize_data(x, lx), lx)
    coeffs = sp.fit_sigmoid(r)
    want = sp.poly_eval_real(coeffs, xq @ w)
    acc = jnp.zeros(m)
    reps = 600
    for i in range(reps):
        wbar = quantize.quantize_weights(jax.random.PRNGKey(i + 10), w, lw, r)
        acc = acc + sp.gbar_real(xq, wbar, coeffs, lx, lw)
    est = acc / reps
    assert float(jnp.abs(est - want).max()) < 0.02


def test_field_real_consistency(key):
    """gbar_field at the aligned scale == gbar_real up to coeff rounding."""
    d, m, r, lx, lw, lc = 8, 20, 1, 2, 4, 8
    p = field.P30
    x = jax.random.uniform(key, (m, d), minval=0, maxval=1)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,)) * 0.2
    xq = quantize.quantize_data(x, lx, p)
    wbar = quantize.quantize_weights(jax.random.PRNGKey(2), w, lw, r, p)
    xw = field.matmul(xq, wbar, p)
    cbar = jnp.asarray(sp.quantized_coeffs(r, lx, lw, lc, p), jnp.int32)
    got = quantize.dequantize(sp.gbar_field(xw, cbar, p), lc + r * (lx + lw), p)
    coeffs = sp.fit_sigmoid(r)
    want = sp.gbar_real(quantize.dequantize(xq, lx, p), wbar, coeffs, lx, lw,
                        p)
    assert float(jnp.abs(got - want).max()) < 1e-2


def test_gradient_scale_poly():
    assert sp.gradient_scale_poly(2, 4, 1, 6) == 6 + 2 + 6
    assert sp.gradient_scale_poly(2, 4, 2, 0) == 0 + 2 + 12
