"""Property-based wire-format law: deserialize(serialize(m)) == m.

Follows the repo's optional-hypothesis pattern (DESIGN.md §8): this module
skips cleanly when hypothesis is absent; the deterministic round-trip cases
in tests/test_wire.py always run.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.cluster import wire  # noqa: E402
from repro.cluster.messages import (  # noqa: E402
    CombineResult,
    EncodeShare,
    Heartbeat,
    SubShare,
    WorkerResult,
)
from repro.core import field  # noqa: E402


def field_arrays(p):
    return st.tuples(st.integers(0, 6), st.integers(0, 4)).flatmap(
        lambda dims: st.lists(
            st.integers(0, p - 1),
            min_size=dims[0] * dims[1], max_size=dims[0] * dims[1],
        ).map(lambda v: np.array(v, dtype=np.int64)
              .astype(np.int32).reshape(dims)))


scalars = st.one_of(
    st.none(),
    st.booleans(),
    st.integers(-(10 ** 30), 10 ** 30),
    st.floats(allow_nan=True),          # NaN: values_equal is reflexive
    st.text(max_size=12),
    st.binary(max_size=12),
)

values = st.recursive(
    st.one_of(scalars, field_arrays(field.P), field_arrays(field.P30)),
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.lists(children, max_size=4).map(tuple),
        st.dictionaries(st.text(max_size=6), children, max_size=4),
    ),
    max_leaves=8,
)

messages = st.one_of(
    st.builds(EncodeShare, round=st.integers(-2, 10 ** 6),
              worker=st.integers(0, 10 ** 4), payload=values),
    st.builds(WorkerResult, round=st.integers(-2, 10 ** 6),
              worker=st.integers(0, 10 ** 4),
              compute_s=st.floats(allow_nan=False), payload=values),
    st.builds(Heartbeat, worker=st.integers(0, 10 ** 4),
              sent_at=st.floats(allow_nan=False)),
    st.builds(SubShare, round=st.integers(0, 10 ** 6),
              phase=st.integers(0, 16), src=st.integers(0, 10 ** 4),
              dst=st.integers(0, 10 ** 4), payload=values),
    st.builds(CombineResult, round=st.integers(0, 10 ** 6),
              worker=st.integers(0, 10 ** 4),
              compute_s=st.floats(allow_nan=False), payload=values),
)


@settings(max_examples=150, deadline=None)
@given(messages)
def test_serialize_roundtrip_identity(msg):
    assert wire.messages_equal(wire.deserialize(wire.serialize(msg)), msg)


@settings(max_examples=100, deadline=None)
@given(messages, st.integers(1, 64))
def test_frame_reader_any_chunking(msg, chunk):
    stream = wire.serialize(msg) * 2        # two frames back to back
    reader = wire.FrameReader()
    got = []
    for i in range(0, len(stream), chunk):
        got += reader.feed(stream[i: i + chunk])
    assert len(got) == 2
    assert all(wire.messages_equal(g, msg) for g in got)


@settings(max_examples=100, deadline=None)
@given(messages, st.data())
def test_truncation_always_raises(msg, data):
    frame = wire.serialize(msg)
    cut = data.draw(st.integers(0, len(frame) - 1))
    with pytest.raises(wire.WireError):
        wire.deserialize(frame[:cut])


# ---------------------------------------------------------------------------
# Wire v2 laws (DESIGN.md §10): the packed/coalesced encodings are pure
# byte-savers — every v2 frame decodes messages_equal to its v1 twin, for
# field arrays under BOTH primes, under any chunking, and truncation of a
# v2 frame fails exactly as loudly as a v1 one.
# ---------------------------------------------------------------------------

def round_payloads(p):
    """The scheduler's coalescible {w_share, batch, next_batch} payloads,
    each member independently an array or None (absent batch = full-batch
    round; absent next_batch = unpipelined master)."""
    opt = st.one_of(st.none(), field_arrays(p))
    return st.fixed_dictionaries(
        {"w_share": opt, "batch": opt, "next_batch": opt})


round_messages = st.one_of(
    st.builds(EncodeShare, round=st.integers(-2, 10 ** 6),
              worker=st.integers(0, 10 ** 4), payload=round_payloads(field.P)),
    st.builds(EncodeShare, round=st.integers(-2, 10 ** 6),
              worker=st.integers(0, 10 ** 4),
              payload=round_payloads(field.P30)),
)


@settings(max_examples=150, deadline=None)
@given(st.one_of(messages, round_messages))
def test_v2_serialize_roundtrip_identity(msg):
    """v2 encode -> v2 decode is the identity for generic messages AND
    coalesced round frames, whatever mix of packable (P) and unpackable
    (P30) arrays the payload holds."""
    assert wire.messages_equal(
        wire.deserialize(wire.serialize(msg, wire.WIRE_V2)), msg)


@settings(max_examples=100, deadline=None)
@given(st.one_of(messages, round_messages))
def test_v2_never_beats_v1_on_correctness_only_on_bytes(msg):
    """The v2 frame for a message is never LARGER than the v1 frame, and
    the two decode to equal messages — narrowing is free, not a trade."""
    v1 = wire.serialize(msg, wire.WIRE_V1)
    v2 = wire.serialize(msg, wire.WIRE_V2)
    assert len(v2) <= len(v1)
    assert wire.messages_equal(wire.deserialize(v2), wire.deserialize(v1))


@settings(max_examples=100, deadline=None)
@given(st.one_of(messages, round_messages), st.integers(1, 64))
def test_v2_frame_reader_any_chunking(msg, chunk):
    stream = wire.serialize(msg, wire.WIRE_V2) * 2
    reader = wire.FrameReader(version=wire.WIRE_V2)
    got = []
    for i in range(0, len(stream), chunk):
        got += reader.feed(stream[i: i + chunk])
    assert len(got) == 2
    assert all(wire.messages_equal(g, msg) for g in got)


@settings(max_examples=100, deadline=None)
@given(st.one_of(messages, round_messages), st.data())
def test_v2_truncation_always_raises(msg, data):
    frame = wire.serialize(msg, wire.WIRE_V2)
    cut = data.draw(st.integers(0, len(frame) - 1))
    with pytest.raises(wire.WireError):
        wire.deserialize(frame[:cut])


@settings(max_examples=100, deadline=None)
@given(st.one_of(messages, round_messages))
def test_iovec_join_equals_serialize(msg):
    """The scatter-gather emission is byte-identical to the joined frame at
    both versions — sendmsg and sendall peers see the same stream."""
    for version in (wire.WIRE_V1, wire.WIRE_V2):
        bufs = wire.serialize_iovec(msg, version)
        frame = wire.serialize(msg, version)
        assert b"".join(bufs) == frame
        assert wire.iovec_nbytes(bufs) == len(frame)
