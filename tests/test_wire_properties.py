"""Property-based wire-format law: deserialize(serialize(m)) == m.

Follows the repo's optional-hypothesis pattern (DESIGN.md §8): this module
skips cleanly when hypothesis is absent; the deterministic round-trip cases
in tests/test_wire.py always run.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.cluster import wire  # noqa: E402
from repro.cluster.messages import (  # noqa: E402
    CombineResult,
    EncodeShare,
    Heartbeat,
    SubShare,
    WorkerResult,
)
from repro.core import field  # noqa: E402


def field_arrays(p):
    return st.tuples(st.integers(0, 6), st.integers(0, 4)).flatmap(
        lambda dims: st.lists(
            st.integers(0, p - 1),
            min_size=dims[0] * dims[1], max_size=dims[0] * dims[1],
        ).map(lambda v: np.array(v, dtype=np.int64)
              .astype(np.int32).reshape(dims)))


scalars = st.one_of(
    st.none(),
    st.booleans(),
    st.integers(-(10 ** 30), 10 ** 30),
    st.floats(allow_nan=True),          # NaN: values_equal is reflexive
    st.text(max_size=12),
    st.binary(max_size=12),
)

values = st.recursive(
    st.one_of(scalars, field_arrays(field.P), field_arrays(field.P30)),
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.lists(children, max_size=4).map(tuple),
        st.dictionaries(st.text(max_size=6), children, max_size=4),
    ),
    max_leaves=8,
)

messages = st.one_of(
    st.builds(EncodeShare, round=st.integers(-2, 10 ** 6),
              worker=st.integers(0, 10 ** 4), payload=values),
    st.builds(WorkerResult, round=st.integers(-2, 10 ** 6),
              worker=st.integers(0, 10 ** 4),
              compute_s=st.floats(allow_nan=False), payload=values),
    st.builds(Heartbeat, worker=st.integers(0, 10 ** 4),
              sent_at=st.floats(allow_nan=False)),
    st.builds(SubShare, round=st.integers(0, 10 ** 6),
              phase=st.integers(0, 16), src=st.integers(0, 10 ** 4),
              dst=st.integers(0, 10 ** 4), payload=values),
    st.builds(CombineResult, round=st.integers(0, 10 ** 6),
              worker=st.integers(0, 10 ** 4),
              compute_s=st.floats(allow_nan=False), payload=values),
)


@settings(max_examples=150, deadline=None)
@given(messages)
def test_serialize_roundtrip_identity(msg):
    assert wire.messages_equal(wire.deserialize(wire.serialize(msg)), msg)


@settings(max_examples=100, deadline=None)
@given(messages, st.integers(1, 64))
def test_frame_reader_any_chunking(msg, chunk):
    stream = wire.serialize(msg) * 2        # two frames back to back
    reader = wire.FrameReader()
    got = []
    for i in range(0, len(stream), chunk):
        got += reader.feed(stream[i: i + chunk])
    assert len(got) == 2
    assert all(wire.messages_equal(g, msg) for g in got)


@settings(max_examples=100, deadline=None)
@given(messages, st.data())
def test_truncation_always_raises(msg, data):
    frame = wire.serialize(msg)
    cut = data.draw(st.integers(0, len(frame) - 1))
    with pytest.raises(wire.WireError):
        wire.deserialize(frame[:cut])
