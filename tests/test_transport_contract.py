"""Backend-shared Transport contract suite (DESIGN.md §7).

One parameterized test class runs the SAME contract against both backends:
``InProcessTransport`` on a simulated clock and ``SocketTransport`` over a
real loopback TCP pair.  The contract is written in terms a wall clock can
satisfy too — delivery ORDER by effective delay, FIFO tiebreak for
simultaneous sends, ``math.inf`` = lost message, ``recv`` draining only
messages due by ``now``, and ``next_delivery`` returning None on an empty
queue — so the scheduler can be retargeted across backends without changing
semantics.  Before this suite the contract was only pinned for the
in-process backend (tests/test_cluster.py).
"""
from __future__ import annotations

import math
import time

import pytest

from repro.cluster.messages import MASTER
from repro.cluster.socket_transport import SocketTransport
from repro.cluster.transport import InProcessTransport

# one "delay unit" per backend: abstract seconds for the simulation, real
# (but short) seconds for loopback TCP
SIM_UNIT = 1.0
REAL_UNIT = 0.15
WAIT_S = 10.0          # generous real-clock bound; sim never waits


class Chan:
    """A directed producer->consumer channel, the shape both backends share.

    For the in-process backend producer and consumer are the same transport
    object; for the socket backend the producer is a connected client and
    the consumer the master endpoint — the pair IS the transport.
    """

    def __init__(self, backend: str):
        self.backend = backend
        if backend == "inprocess":
            self.unit = SIM_UNIT
            tr = InProcessTransport()
            self.producer = self.consumer = tr
            self.dst = MASTER
            self._to_close = []
        else:
            self.unit = REAL_UNIT
            master = SocketTransport.master(poll_interval_s=0.02)
            client = SocketTransport.connect("127.0.0.1", master.port,
                                             "worker/0",
                                             poll_interval_s=0.02)
            master.wait_for_endpoints(["worker/0"], timeout_s=WAIT_S)
            self.producer, self.consumer = client, master
            self.dst = MASTER
            self._to_close = [client, master]

    @property
    def real(self) -> bool:
        return self.consumer.real

    def now(self) -> float:
        return time.monotonic() if self.real else 0.0

    def send(self, msg, delay: float = 0.0):
        self.producer.send(self.dst, msg, at=self.now(), delay=delay)

    def next_delivery(self, wait: bool = True) -> float | None:
        """The contract call, plus the real-clock polling the scheduler does:
        on a wall clock None means "nothing YET", so callers poll."""
        nxt = self.consumer.next_delivery(self.dst)
        if nxt is None and self.real and wait:
            deadline = time.monotonic() + WAIT_S
            while nxt is None and time.monotonic() < deadline:
                nxt = self.consumer.next_delivery(self.dst)
        return nxt

    def recv(self, now: float):
        return [m for _, m in self.consumer.recv(self.dst, now)]

    def close(self):
        for tr in self._to_close:
            tr.close()


@pytest.fixture(params=["inprocess", "socket"])
def chan(request):
    c = Chan(request.param)
    yield c
    c.close()


class TestTransportContract:
    def test_orders_by_delivery_time(self, chan):
        chan.send("slow", delay=3 * chan.unit)
        chan.send("fast", delay=1 * chan.unit)
        t_fast = chan.next_delivery()
        assert t_fast is not None
        assert chan.recv(now=t_fast) == ["fast"]
        t_slow = chan.next_delivery()
        assert t_slow is not None and t_slow >= t_fast
        assert chan.recv(now=t_slow) == ["slow"]

    def test_fifo_tiebreak_at_equal_times(self, chan):
        for i in range(6):
            chan.send(i, delay=0.0)
        got = []
        deadline = time.monotonic() + WAIT_S
        while len(got) < 6:
            nxt = chan.next_delivery()
            assert nxt is not None, f"only {got} arrived"
            got += chan.recv(now=nxt)
            assert time.monotonic() < deadline
        # equal send instant (sim: identical deliver_at; socket: one stream)
        # must preserve send order
        assert got == [0, 1, 2, 3, 4, 5]

    def test_inf_delay_is_lost(self, chan):
        chan.send("never", delay=math.inf)
        chan.send("real", delay=1 * chan.unit)
        nxt = chan.next_delivery()
        assert chan.recv(now=nxt) == ["real"]
        # the lost message must never surface, even after its "delay" would
        # have elapsed many times over
        assert chan.next_delivery(wait=False) is None
        assert chan.recv(now=math.inf) == []

    def test_recv_drains_due_only(self, chan):
        chan.send("m", delay=0.0)
        stamp = chan.next_delivery()
        assert stamp is not None
        # not due strictly before its delivery stamp...
        assert chan.recv(now=stamp - 1e-4) == []
        # ...due exactly at it (and the queue then reports empty)
        assert chan.recv(now=stamp) == ["m"]
        assert chan.next_delivery(wait=False) is None

    def test_next_delivery_empty_queue_is_none(self, chan):
        assert chan.next_delivery(wait=False) is None
        chan.send("x", delay=0.0)
        nxt = chan.next_delivery()
        assert nxt is not None
        chan.recv(now=nxt)
        assert chan.next_delivery(wait=False) is None
