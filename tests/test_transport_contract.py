"""Backend-shared Transport contract suite (DESIGN.md §7).

One parameterized test class runs the SAME contract against both backends
and both TOPOLOGIES: ``InProcessTransport`` on a simulated clock and
``SocketTransport`` over a real loopback TCP pair, each as a worker->master
channel AND as a worker->worker PEER channel (the MPC reshare path — on the
socket backend peer frames relay through the master inside Forward
envelopes, so the master must be pumped like its collect loop would).  The
contract is written in terms a wall clock can satisfy too — delivery ORDER
by effective delay, FIFO tiebreak for simultaneous sends, ``math.inf`` =
lost message, ``recv`` draining only messages due by ``now``, and
``next_delivery`` returning None on an empty queue — so the scheduler can
be retargeted across backends without changing semantics.
"""
from __future__ import annotations

import math
import time

import numpy as np
import pytest

from repro.cluster.messages import MASTER, SubShare
from repro.cluster.socket_transport import SocketTransport
from repro.cluster.transport import InProcessTransport
from repro.cluster import wire

# one "delay unit" per backend: abstract seconds for the simulation, real
# (but short) seconds for loopback TCP
SIM_UNIT = 1.0
REAL_UNIT = 0.15
WAIT_S = 10.0          # generous real-clock bound; sim never waits


class Chan:
    """A directed producer->consumer channel, the shape both backends share.

    For the in-process backend producer and consumer are the same transport
    object; for the socket backend the producer is a connected client and
    the consumer the master endpoint — the pair IS the transport.  The
    ``peer-*`` variants make BOTH ends workers: in-process that is just a
    different destination name; on the socket backend the frames hop
    through the master's relay, which only forwards while the master polls
    (as its collect loop perpetually does) — ``_pump`` stands in for that.
    """

    def __init__(self, backend: str):
        self.backend = backend
        self._pump = None
        if backend == "inprocess":
            self.unit = SIM_UNIT
            tr = InProcessTransport()
            self.producer = self.consumer = tr
            self.dst = MASTER
            self._to_close = []
        elif backend == "peer-inprocess":
            self.unit = SIM_UNIT
            tr = InProcessTransport()
            self.producer = self.consumer = tr
            self.dst = "worker/1"
            self._to_close = []
        elif backend == "peer-socket":
            self.unit = REAL_UNIT
            master = SocketTransport.master(poll_interval_s=0.02)
            w0 = SocketTransport.connect("127.0.0.1", master.port,
                                         "worker/0", poll_interval_s=0.02)
            w1 = SocketTransport.connect("127.0.0.1", master.port,
                                         "worker/1", poll_interval_s=0.02)
            master.wait_for_endpoints(["worker/0", "worker/1"],
                                      timeout_s=WAIT_S)
            self.producer, self.consumer = w0, w1
            self.dst = "worker/1"
            self._pump = lambda: master.recv(MASTER, time.monotonic())
            self._to_close = [w0, w1, master]
        else:
            # "socket" = a current v2 client; "socket-v1" = a legacy client
            # that only speaks wire v1 — the whole contract must hold on the
            # negotiated-down stream too (DESIGN.md §10)
            self.unit = REAL_UNIT
            master = SocketTransport.master(poll_interval_s=0.02)
            client = SocketTransport.connect(
                "127.0.0.1", master.port, "worker/0", poll_interval_s=0.02,
                wire_version=(wire.WIRE_V1 if backend == "socket-v1"
                              else wire.WIRE_VERSION))
            master.wait_for_endpoints(["worker/0"], timeout_s=WAIT_S)
            self.producer, self.consumer = client, master
            self.dst = MASTER
            self._to_close = [client, master]

    @property
    def real(self) -> bool:
        return self.consumer.real

    def now(self) -> float:
        return time.monotonic() if self.real else 0.0

    def send(self, msg, delay: float = 0.0):
        self.producer.send(self.dst, msg, at=self.now(), delay=delay)

    def next_delivery(self, wait: bool = True) -> float | None:
        """The contract call, plus the real-clock polling the scheduler does:
        on a wall clock None means "nothing YET", so callers poll."""
        if self._pump is not None:
            self._pump()
        nxt = self.consumer.next_delivery(self.dst)
        if nxt is None and self.real and wait:
            deadline = time.monotonic() + WAIT_S
            while nxt is None and time.monotonic() < deadline:
                if self._pump is not None:
                    self._pump()
                nxt = self.consumer.next_delivery(self.dst)
        return nxt

    def recv(self, now: float):
        return [m for _, m in self.consumer.recv(self.dst, now)]

    def close(self):
        for tr in self._to_close:
            tr.close()


@pytest.fixture(params=["inprocess", "socket", "socket-v1",
                        "peer-inprocess", "peer-socket"])
def chan(request):
    c = Chan(request.param)
    yield c
    c.close()


class TestTransportContract:
    def test_orders_by_delivery_time(self, chan):
        chan.send("slow", delay=3 * chan.unit)
        chan.send("fast", delay=1 * chan.unit)
        t_fast = chan.next_delivery()
        assert t_fast is not None
        assert chan.recv(now=t_fast) == ["fast"]
        t_slow = chan.next_delivery()
        assert t_slow is not None and t_slow >= t_fast
        assert chan.recv(now=t_slow) == ["slow"]

    def test_fifo_tiebreak_at_equal_times(self, chan):
        for i in range(6):
            chan.send(i, delay=0.0)
        got = []
        deadline = time.monotonic() + WAIT_S
        while len(got) < 6:
            nxt = chan.next_delivery()
            assert nxt is not None, f"only {got} arrived"
            got += chan.recv(now=nxt)
            assert time.monotonic() < deadline
        # equal send instant (sim: identical deliver_at; socket: one stream)
        # must preserve send order
        assert got == [0, 1, 2, 3, 4, 5]

    def test_inf_delay_is_lost(self, chan):
        chan.send("never", delay=math.inf)
        chan.send("real", delay=1 * chan.unit)
        nxt = chan.next_delivery()
        assert chan.recv(now=nxt) == ["real"]
        # the lost message must never surface, even after its "delay" would
        # have elapsed many times over
        assert chan.next_delivery(wait=False) is None
        assert chan.recv(now=math.inf) == []

    def test_recv_drains_due_only(self, chan):
        chan.send("m", delay=0.0)
        stamp = chan.next_delivery()
        assert stamp is not None
        # not due strictly before its delivery stamp...
        assert chan.recv(now=stamp - 1e-4) == []
        # ...due exactly at it (and the queue then reports empty)
        assert chan.recv(now=stamp) == ["m"]
        assert chan.next_delivery(wait=False) is None

    def test_next_delivery_empty_queue_is_none(self, chan):
        assert chan.next_delivery(wait=False) is None
        chan.send("x", delay=0.0)
        nxt = chan.next_delivery()
        assert nxt is not None
        chan.recv(now=nxt)
        assert chan.next_delivery(wait=False) is None

    def test_subshare_payload_survives_peer_delivery(self, chan):
        """The MPC reshare unit: a SubShare with a field-array payload must
        arrive intact over every channel (on peer-socket that includes the
        Forward-envelope relay hop through the master)."""
        payload = np.arange(24, dtype=np.int32).reshape(6, 4)
        chan.send(SubShare(3, 0, src=0, dst=1, payload=payload))
        nxt = chan.next_delivery()
        assert nxt is not None
        (got,) = chan.recv(now=nxt)
        assert isinstance(got, SubShare)
        assert (got.round, got.phase, got.src, got.dst) == (3, 0, 0, 1)
        assert got.payload.dtype == np.int32
        assert (got.payload == payload).all()


def test_forward_envelope_round_trips():
    """The relay envelope itself is a wire frame: dst + verbatim inner
    frame bytes."""
    inner = wire.serialize(SubShare(1, 0, 2, 3,
                                    np.arange(4, dtype=np.int32)))
    fwd = wire.deserialize(wire.serialize(wire.Forward("worker/3", inner)))
    assert isinstance(fwd, wire.Forward)
    assert fwd.dst == "worker/3" and fwd.frame == inner
    got = wire.deserialize(fwd.frame)
    assert isinstance(got, SubShare) and got.dst == 3


def test_relay_survives_slow_reader_beyond_socket_buffers():
    """A recipient that stops reading (an alive MPC straggler mid-sleep)
    must only DELAY its relayed frames, never lose or corrupt them: the
    per-destination outbox parks whole frames the destination socket won't
    accept and flushes on later polls — a drop-after-stall heuristic here
    would turn a tolerable straggle into a starved reshare barrier, and a
    mid-frame drop would desynchronize the stream permanently."""
    master = SocketTransport.master(poll_interval_s=0.02)
    w0 = SocketTransport.connect("127.0.0.1", master.port, "worker/0",
                                 poll_interval_s=0.02)
    w1 = SocketTransport.connect("127.0.0.1", master.port, "worker/1",
                                 poll_interval_s=0.02)
    try:
        master.wait_for_endpoints(["worker/0", "worker/1"], timeout_s=WAIT_S)
        # several MB of relayed frames — far beyond default kernel socket
        # buffers — while worker/1 never touches its transport.  The master
        # is pumped during the sends (as its collect loop always would be),
        # so the w0->master leg drains and the backlog piles up on the
        # master->w1 leg, which is exactly the relay's responsibility.
        n, payload = 16, np.zeros(1 << 16, dtype=np.int32)
        for i in range(n):
            w0.send("worker/1", SubShare(0, 0, 0, 1, payload + i))
            for _ in range(12):
                master.recv(MASTER, time.monotonic())  # pump: relay + flush
        # a DIRECT master send while relayed frames sit (possibly half-
        # flushed) in the outbox: it must queue BEHIND them, whole — never
        # interleave into the middle of a partially written frame
        from repro.cluster.messages import EncodeShare
        master.send("worker/1", EncodeShare(9, 1, None))
        got = []
        deadline = time.monotonic() + 60.0
        while len(got) < n + 1 and time.monotonic() < deadline:
            master.recv(MASTER, time.monotonic())     # pump: flush outbox
            got += [m for _, m in w1.recv("worker/1", time.monotonic())]
        assert len(got) == n + 1, f"dropped {n + 1 - len(got)} frames"
        subs, rest = got[:n], got[n:]
        assert [int(m.payload[0]) for m in subs] == list(range(n))  # in order
        assert isinstance(rest[0], EncodeShare) and rest[0].round == 9
    finally:
        w0.close()
        w1.close()
        master.close()


def test_wire_version_negotiation_mixed_fleet():
    """A legacy v1 worker and a current v2 worker on the SAME master
    (DESIGN.md §10): the master speaks v1 to the one that sent plain HELLO
    and v2 to the one whose HELLO2 it acked — and the round-shaped
    EncodeShare (coalesced+packed on the v2 stream, generic on v1) arrives
    bit-identical on both, as do the results coming back."""
    from repro.cluster.messages import EncodeShare, WorkerResult

    master = SocketTransport.master(poll_interval_s=0.02)
    legacy = SocketTransport.connect("127.0.0.1", master.port, "worker/0",
                                     poll_interval_s=0.02,
                                     wire_version=wire.WIRE_V1)
    modern = SocketTransport.connect("127.0.0.1", master.port, "worker/1",
                                     poll_interval_s=0.02)
    try:
        master.wait_for_endpoints(["worker/0", "worker/1"], timeout_s=WAIT_S)
        assert master.peer_version("worker/0") == wire.WIRE_V1
        assert master.peer_version("worker/1") == wire.WIRE_V2
        # the legacy client never upgrades; the modern one does once the
        # master's HELLO2 ack lands (the client pumps its socket whenever
        # the serve loop touches the transport, as next_delivery does here)
        assert legacy.peer_version(MASTER) == wire.WIRE_V1
        deadline = time.monotonic() + WAIT_S
        while (modern.peer_version(MASTER) != wire.WIRE_V2
               and time.monotonic() < deadline):
            modern.next_delivery("worker/1")
        assert modern.peer_version(MASTER) == wire.WIRE_V2

        rng = np.random.default_rng(0)
        payload = {
            "w_share": rng.integers(0, 1 << 24, (32, 2, 2)).astype(np.int32),
            "batch": np.arange(48, dtype=np.int32),
            "next_batch": None,
        }
        before = master.wire_stats()        # after handshake: round traffic
        for i, w in enumerate((legacy, modern)):
            master.send(f"worker/{i}", EncodeShare(0, i, dict(payload)))
            got = []
            deadline = time.monotonic() + WAIT_S
            while not got and time.monotonic() < deadline:
                master.recv(MASTER, time.monotonic())
                got = [m for _, m in w.recv(f"worker/{i}", time.monotonic())]
            (msg,) = got
            assert (msg.payload["w_share"] == payload["w_share"]).all()
            assert (msg.payload["batch"] == payload["batch"]).all()
            assert msg.payload["next_batch"] is None
            w.send(MASTER, WorkerResult(0, i, 0.5, payload["w_share"] + i))
        got = []
        deadline = time.monotonic() + WAIT_S
        while len(got) < 2 and time.monotonic() < deadline:
            got += [m for _, m in master.recv(MASTER, time.monotonic())]
        for m in got:
            assert (m.payload == payload["w_share"] + m.worker).all()
        # the v2 stream carried the same round share in fewer bytes
        after = master.wire_stats()
        tx = {ep: {k: after[ep][k] - before[ep][k] for k in after[ep]}
              for ep in ("worker/0", "worker/1")}
        assert tx["worker/0"]["tx_frames"] == tx["worker/1"]["tx_frames"] == 1
        assert tx["worker/1"]["tx_bytes"] < tx["worker/0"]["tx_bytes"]
    finally:
        legacy.close()
        modern.close()
        master.close()


def test_wire_stats_count_both_directions():
    """Satellite telemetry contract: per-endpoint tx/rx byte & frame
    counters advance on every leg and sum into wire_totals()."""
    master = SocketTransport.master(poll_interval_s=0.02)
    w0 = SocketTransport.connect("127.0.0.1", master.port, "worker/0",
                                 poll_interval_s=0.02)
    try:
        master.wait_for_endpoints(["worker/0"], timeout_s=WAIT_S)
        base = master.wire_totals()
        w0.send(MASTER, "ping")
        deadline = time.monotonic() + WAIT_S
        got = []
        while not got and time.monotonic() < deadline:
            got = [m for _, m in master.recv(MASTER, time.monotonic())]
        assert got == ["ping"]
        master.send("worker/0", "pong")
        deadline = time.monotonic() + WAIT_S
        got = []
        while not got and time.monotonic() < deadline:
            master.recv(MASTER, time.monotonic())      # pump the flush
            got = [m for _, m in w0.recv("worker/0", time.monotonic())]
        assert got == ["pong"]
        stats = master.wire_stats()["worker/0"]
        assert stats["rx_frames"] >= 1 and stats["rx_bytes"] > 0
        assert stats["tx_frames"] >= 1 and stats["tx_bytes"] > 0
        tot = master.wire_totals()
        assert tot["tx_bytes"] > base["tx_bytes"]
        assert tot["rx_bytes"] > base["rx_bytes"]
        wstats = w0.wire_stats()[MASTER]
        assert wstats["tx_frames"] >= 2          # HELLO2 + ping
        assert wstats["rx_frames"] >= 2          # HELLO2 ack + pong
    finally:
        w0.close()
        master.close()


def test_forward_to_unknown_endpoint_is_lost_not_fatal():
    """A Forward to a never-registered (or dead) endpoint vanishes — the
    same lost-in-the-void semantics as any send to a dead peer — and must
    not wedge or crash the relaying master."""
    master = SocketTransport.master(poll_interval_s=0.02)
    w0 = SocketTransport.connect("127.0.0.1", master.port, "worker/0",
                                 poll_interval_s=0.02)
    try:
        master.wait_for_endpoints(["worker/0"], timeout_s=WAIT_S)
        w0.send("worker/9", "into the void")
        w0.send(MASTER, "still alive")
        deadline = time.monotonic() + WAIT_S
        got = []
        while not got and time.monotonic() < deadline:
            got = [m for _, m in master.recv(MASTER, time.monotonic())]
        assert got == ["still alive"]
    finally:
        w0.close()
        master.close()


# ---------------------------------------------------------------------------
# Late HELLO: a peer registering AFTER provisioning completed (the elastic
# JOIN transport prerequisite, DESIGN.md §13)
# ---------------------------------------------------------------------------

def test_late_peer_needs_no_registration_inprocess():
    """The in-process backend has no registration step at all: a slot name
    first used mid-run delivers both ways — which is exactly why the sim
    runner can admit a spare at any fence with pure bookkeeping."""
    from repro.cluster.messages import EncodeShare, Join

    tr = InProcessTransport()
    # "provisioning": traffic only for the base worker
    tr.send("worker/0", EncodeShare(-1, 0, None), at=0.0)
    assert [m for _, m in tr.recv("worker/0", now=0.0)]
    # a late joiner's first-ever frame arrives with nobody told in advance
    tr.send(MASTER, Join(worker=8, at_round=3), at=5.0)
    (got,) = [m for _, m in tr.recv(MASTER, now=5.0)]
    assert isinstance(got, Join) and (got.worker, got.at_round) == (8, 3)
    # and the master can immediately dispatch to the new slot
    tr.send("worker/8", EncodeShare(3, 8, None), at=5.0)
    (share,) = [m for _, m in tr.recv("worker/8", now=5.0)]
    assert share.worker == 8


def test_late_hello_registers_after_provisioning_socket():
    """A SocketTransport client that connects after the base fleet finished
    provisioning: the master's poll loop registers the new endpoint from
    its HELLO, ``endpoints()``/``wait_for_endpoints`` observe it, the
    joiner waits for the HELLO2 ack before speaking v2 (the Join frame is
    v2-only), and traffic then flows both ways — the whole transport-level
    admission path a ``--join-at-round`` worker exercises."""
    from repro.cluster.messages import EncodeShare, Join

    master = SocketTransport.master(poll_interval_s=0.02)
    w0 = SocketTransport.connect("127.0.0.1", master.port, "worker/0",
                                 poll_interval_s=0.02)
    late = None
    try:
        master.wait_for_endpoints(["worker/0"], timeout_s=WAIT_S)
        # base-fleet "provisioning" completes first
        master.send("worker/0", EncodeShare(-1, 0, None))
        deadline = time.monotonic() + WAIT_S
        got = []
        while not got and time.monotonic() < deadline:
            master.recv(MASTER, time.monotonic())
            got = [m for _, m in w0.recv("worker/0", time.monotonic())]
        assert got and got[0].worker == 0
        assert set(master.endpoints()) == {"worker/0"}

        # NOW a joiner dials in — nothing about it was pre-arranged
        late = SocketTransport.connect("127.0.0.1", master.port, "worker/8",
                                       poll_interval_s=0.02)
        master.wait_for_endpoints(["worker/8"], timeout_s=WAIT_S)
        assert "worker/8" in master.endpoints()
        # Join is a v2 frame: the joiner must see the master's HELLO2 ack
        # before sending it (the race cpml_worker guards against)
        deadline = time.monotonic() + WAIT_S
        while (late.peer_version(MASTER) < wire.WIRE_V2
               and time.monotonic() < deadline):
            late.next_delivery("worker/8")
        assert late.peer_version(MASTER) == wire.WIRE_V2
        late.send(MASTER, Join(worker=8, at_round=5))
        deadline = time.monotonic() + WAIT_S
        got = []
        while not got and time.monotonic() < deadline:
            got = [m for _, m in master.recv(MASTER, time.monotonic())
                   if isinstance(m, Join)]
        assert got and (got[0].worker, got[0].at_round) == (8, 5)

        # admission dispatch: the master can now provision/dispatch to it
        master.send("worker/8", EncodeShare(5, 8, None))
        deadline = time.monotonic() + WAIT_S
        got = []
        while not got and time.monotonic() < deadline:
            master.recv(MASTER, time.monotonic())
            got = [m for _, m in late.recv("worker/8", time.monotonic())]
        assert got and got[0].round == 5 and got[0].worker == 8
    finally:
        if late is not None:
            late.close()
        w0.close()
        master.close()
