"""Model substrate: per-arch smoke, attention/mamba/moe refs, decode parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.models import layers, mamba, model as M, moe

RC = RunConfig(q_block=16, kv_block=16, loss_chunk=16, scan_chunk=8)


# ---------------------------------------------------------------------------
# per-arch smoke: REDUCED config, one forward+grad step, shapes + finiteness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(registry.ARCHS))
def test_arch_smoke(arch, key):
    cfg = registry.reduced_config(registry.get_config(arch))
    params = M.init_params(cfg, key)
    B, S = 2, 32
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                          dtype=jnp.int32)}
    if cfg.frontend in ("vision", "audio") and not cfg.is_encoder_decoder:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                             dtype=jnp.int32)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, RC, p, batch))(params)
    assert jnp.isfinite(loss), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), arch
    # full (non-reduced) config param count sanity vs the advertised size
    full = registry.get_config(arch)
    n = full.param_count()
    assert n > 0


@pytest.mark.parametrize("arch,expected_b", [
    ("tinyllama-1.1b", 1.1e9), ("qwen2-72b", 72e9),
    ("mistral-large-123b", 123e9), ("falcon-mamba-7b", 7e9),
    ("arctic-480b", 480e9), ("hymba-1.5b", 1.5e9),
])
def test_param_counts_match_advertised(arch, expected_b):
    n = registry.get_config(arch).param_count()
    assert 0.75 * expected_b < n < 1.35 * expected_b, (arch, n / 1e9)


# ---------------------------------------------------------------------------
# attention refs
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, causal=True, window=None):
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qf = q.astype(jnp.float32).reshape(B, S, KH, G, D) * D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    pos = jnp.arange(S)
    valid = jnp.ones((S, S), bool)
    if causal:
        valid &= pos[:, None] >= pos[None, :]
    if window is not None:
        valid &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D)


@pytest.mark.parametrize("causal,window,S", [
    (True, None, 64), (True, 16, 64), (False, None, 48), (True, 24, 50),
])
def test_blockwise_attention_vs_naive(key, causal, window, S):
    B, H, KH, D = 2, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    got = layers.blockwise_attention(q, k, v, causal=causal, window=window,
                                     q_block=16, kv_block=16)
    want = naive_attention(q, k, v, causal, window)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=2e-5), (
        float(jnp.abs(got - want).max()))


def test_blockwise_attention_block_invariance(key):
    B, S, H, KH, D = 1, 60, 2, 1, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    outs = [layers.blockwise_attention(q, k, v, q_block=qb, kv_block=kb)
            for qb, kb in [(8, 8), (16, 32), (60, 60), (13, 7)]]
    for o in outs[1:]:
        assert np.allclose(np.asarray(outs[0]), np.asarray(o), atol=2e-5)


def test_decode_attention_matches_last_row(key):
    B, S, H, KH, D = 2, 33, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    full = naive_attention(q, k, v, causal=True)
    got = layers.decode_attention(q[:, -1:], k, v, jnp.int32(S))
    assert np.allclose(np.asarray(got[:, 0]), np.asarray(full[:, -1]),
                       atol=2e-5)


# ---------------------------------------------------------------------------
# mamba refs
# ---------------------------------------------------------------------------

def test_mamba_chunked_equals_sequential(key):
    """Chunked associative scan == naive per-step recurrence."""
    cfg = registry.reduced_config(registry.get_config("falcon-mamba-7b"))
    tmpl = mamba.mamba_template(cfg)
    p = {k: jnp.ones(v.shape, v.dtype) * 0.1 if v.init != "zeros"
         else jnp.zeros(v.shape, v.dtype) for k, v in tmpl.items()}
    p["A_log"] = jnp.log(jnp.ones((cfg.d_inner, cfg.ssm_state)) * 0.5)
    B, S = 2, 37
    x_in = jax.random.normal(key, (B, S, cfg.d_inner), jnp.float32) * 0.3
    y_chunk, h_chunk = mamba.mamba_mix(cfg, RC, p, x_in)
    # sequential reference via the decode core
    cache = {"conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_inner)),
             "ssm": jnp.zeros((B, cfg.d_inner, cfg.ssm_state))}
    ys = []
    for t in range(S):
        y, cache = mamba.mamba_decode_core(cfg, p, x_in[:, t:t + 1], cache)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    err = float(jnp.abs(y_chunk - y_seq).max())
    assert err < 1e-3, err
    assert np.allclose(np.asarray(h_chunk), np.asarray(cache["ssm"]),
                       atol=1e-3)


# ---------------------------------------------------------------------------
# MoE refs
# ---------------------------------------------------------------------------

def test_moe_sort_equals_einsum_no_drops(key):
    cfg = dataclasses.replace(
        registry.reduced_config(registry.get_config("phi3.5-moe-42b-a6.6b")),
        capacity_factor=8.0)
    tmpl = moe.moe_template(cfg)
    ks = jax.random.split(key, len(tmpl))
    p = {name: (jax.random.normal(k, t.shape, jnp.float32) * 0.2).astype(t.dtype)
         for k, (name, t) in zip(ks, tmpl.items())}
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32) * 0.5
    rce = dataclasses.replace(RC, moe_impl="einsum")
    rcs = dataclasses.replace(RC, moe_impl="sort")
    ye = moe.moe_forward(cfg, rce, p, x)
    ys = moe.moe_forward(cfg, rcs, p, x)
    err = float(jnp.abs(ye - ys).max() / (jnp.abs(ye).max() + 1e-9))
    assert err < 2e-2, err


def test_moe_capacity_drops_tokens(key):
    cfg = dataclasses.replace(
        registry.reduced_config(registry.get_config("phi3.5-moe-42b-a6.6b")),
        capacity_factor=0.25)
    tmpl = moe.moe_template(cfg)
    p = {name: jnp.ones(t.shape, t.dtype) * 0.05 for name, t in tmpl.items()}
    x = jax.random.normal(key, (1, 32, cfg.d_model), jnp.float32)
    y = moe.moe_forward_einsum(cfg, RC, p, x)
    assert jnp.isfinite(y).all()


# ---------------------------------------------------------------------------
# loss / decode parity
# ---------------------------------------------------------------------------

def test_chunked_loss_equals_full(key):
    cfg = registry.reduced_config(registry.get_config("tinyllama-1.1b"))
    params = M.init_params(cfg, key, dtype=jnp.float32)
    params = jax.tree.map(lambda t: t.astype(jnp.float32), params)
    B, S = 2, 24
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                          dtype=jnp.int32),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                          dtype=jnp.int32)}
    h, _ = M.backbone(cfg, RC, params, batch)
    loss_chunked = M.chunked_loss(cfg, RC, params, h, batch["labels"])
    logits = M.lm_head(cfg, params, h).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
    want = (logz - gold).mean()
    assert abs(float(loss_chunked) - float(want)) < 1e-4


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "falcon-mamba-7b",
                                  "hymba-1.5b", "whisper-tiny"])
def test_decode_matches_full_forward(arch, key):
    cfg = dataclasses.replace(
        registry.reduced_config(registry.get_config(arch)),
        capacity_factor=8.0)
    params = jax.tree.map(lambda t: t.astype(jnp.float32),
                          M.init_params(cfg, key))
    B, S, EXTRA = 2, 16, 3
    toks = jax.random.randint(key, (B, S + EXTRA), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    batch = {"tokens": toks[:, :S]}
    full = {"tokens": toks}
    enc_out = None
    if cfg.is_encoder_decoder:
        e = jax.random.normal(key, (B, cfg.encoder_seq_len, cfg.d_model),
                              jnp.float32)
        batch["enc_embeds"] = e
        full["enc_embeds"] = e
        from repro.models import layers as L
        epos = jnp.broadcast_to(
            jnp.arange(cfg.encoder_seq_len, dtype=jnp.int32),
            (B, cfg.encoder_seq_len))
        eh, _ = M._segment_forward(cfg, RC, "enc", cfg.num_encoder_layers,
                                   params["enc"]["params"], e, epos)
        enc_out = L.rmsnorm(eh, params["enc_norm"], cfg.norm_eps)
    h, _ = M.backbone(cfg, RC, params, full)
    want = M.lm_head(cfg, params, h[:, -1:])
    logits, cache = M.prefill(cfg, RC, params, batch, cache_len=S + EXTRA)
    for t in range(EXTRA):
        db = {"tokens": toks[:, S + t: S + t + 1]}
        if enc_out is not None:
            db["enc_out"] = enc_out
        logits, cache = M.decode_step(cfg, RC, params, cache, db)
    err = float(jnp.abs(logits - want).max())
    assert err < 1e-3, (arch, err)


def test_swa_ring_buffer_decode(key):
    """SWA arch decoding past the window: ring cache == full-context SWA."""
    cfg = registry.reduced_config(registry.get_config("h2o-danube-3-4b"))
    assert cfg.sliding_window == 32
    params = jax.tree.map(lambda t: t.astype(jnp.float32),
                          M.init_params(cfg, key))
    B, S, EXTRA = 1, 40, 4            # prefill exceeds the 32-token window
    toks = jax.random.randint(key, (B, S + EXTRA), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    h, _ = M.backbone(cfg, RC, params, {"tokens": toks})
    want = M.lm_head(cfg, params, h[:, -1:])
    logits, cache = M.prefill(cfg, RC, params, {"tokens": toks[:, :S]},
                              cache_len=S + EXTRA)
    for t in range(EXTRA):
        logits, cache = M.decode_step(cfg, RC, params, cache,
                                      {"tokens": toks[:, S + t: S + t + 1]})
    err = float(jnp.abs(logits - want).max())
    assert err < 1e-3, err
