"""Multi-class + mini-batch protocol tests (acceptance: exact over F_p).

The coded pipeline must reproduce the cleartext quantized computation
EXACTLY in the field domain: decode_parts(worker results) == the per-part
sub-gradient X̄_kᵀ ḡ(X̄_k, W̄) mod p computed directly on the quantized data.
No tolerance — these are integers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field, protocol, quantize, sigmoid_poly
from repro.data import synthetic


def mc_cfg(**kw):
    base = dict(N=8, K=2, T=1, r=1, c=3, backend="vmap")
    base.update(kw)
    return protocol.CPMLConfig(**base)


@pytest.fixture(scope="module")
def dataset():
    return synthetic.multiclass_mnist_like(jax.random.PRNGKey(42), m=300,
                                           d=24, c=3)


def _clear_field_subgradients(cfg, xq_parts_field, wbar):
    """Direct F_p computation of h_k = X̄_kᵀ ḡ(X̄_k, W̄) for every part."""
    d, c, r = wbar.shape
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(
        cfg.r, cfg.lx, cfg.lw, cfg.lc, cfg.p), jnp.int32)
    out = []
    for k in range(cfg.K):
        z = field.matmul(xq_parts_field[k], wbar.reshape(d, c * r), cfg.p)
        s = sigmoid_poly.gbar_field(
            z.reshape(z.shape[0], c, r), cbar, cfg.p)            # (mk, c)
        out.append(field.matmul(xq_parts_field[k].T, s, cfg.p))  # (d, c)
    return jnp.stack(out)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("pattern", [np.arange(8),
                                     np.array([7, 5, 3, 1, 0, 2, 4])])
def test_multiclass_gradient_exact_over_field(dataset, use_kernel, pattern):
    """c=3 coded step decodes the EXACT field sub-gradients of the
    cleartext quantized baseline, for any valid survivor pattern."""
    x, y = dataset
    cfg = mc_cfg(use_kernel=use_kernel)
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x_shares, ctx = protocol.encode_dataset(cfg, kx, x)
    d = x.shape[1]
    w2 = jax.random.normal(jax.random.PRNGKey(3), (d, cfg.c)) * 0.1

    w_shares = protocol.encode_weights(cfg, kw, w2)      # (N, d, c, r)
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(
        cfg.r, cfg.lx, cfg.lw, cfg.lc, cfg.p), jnp.int32)
    results = protocol.all_worker_results(cfg, cbar, x_shares, w_shares)

    surv = pattern[: cfg.threshold]
    dmat = protocol.make_decode_matrix(cfg, surv)
    decoded = protocol.decode_parts(cfg, results[jnp.asarray(surv)], dmat)

    # cleartext replica: same W̄ draw (same key path as encode_weights)
    kq, _ = jax.random.split(kw)
    wbar = quantize.quantize_weights(kq, w2, cfg.lw, cfg.r, cfg.p)
    xq = protocol.pad_rows(quantize.quantize_data(x, cfg.lx, cfg.p), cfg.K)
    xq_parts = xq.reshape(cfg.K, -1, d)
    want = _clear_field_subgradients(cfg, xq_parts, wbar)

    assert np.array_equal(np.asarray(decoded), np.asarray(want))


def test_minibatch_gradient_exact_over_field(dataset):
    """Row-subset of the ONCE-encoded shares decodes the exact field
    sub-gradients of the same row-subset of the cleartext parts — the
    property that makes coded mini-batch SGD sound (DESIGN.md §6)."""
    x, y = dataset
    b = 48
    cfg = mc_cfg(batch_rows=b)
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x_shares, ctx = protocol.encode_dataset(cfg, kx, x)
    mk = ctx["m_padded"] // cfg.K
    d = x.shape[1]
    idx = jax.random.choice(jax.random.PRNGKey(9), mk, (b,), replace=False)
    w2 = jax.random.normal(jax.random.PRNGKey(4), (d, cfg.c)) * 0.1

    w_shares = protocol.encode_weights(cfg, kw, w2)
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(
        cfg.r, cfg.lx, cfg.lw, cfg.lc, cfg.p), jnp.int32)
    xb = jnp.take(x_shares, idx, axis=1)                 # (N, b, d)
    results = protocol.all_worker_results(cfg, cbar, xb, w_shares)
    dmat = protocol.make_decode_matrix(cfg, np.arange(cfg.N))
    decoded = protocol.decode_parts(cfg, results[: cfg.threshold], dmat)

    kq, _ = jax.random.split(kw)
    wbar = quantize.quantize_weights(kq, w2, cfg.lw, cfg.r, cfg.p)
    xq = protocol.pad_rows(quantize.quantize_data(x, cfg.lx, cfg.p), cfg.K)
    xq_parts = jnp.take(xq.reshape(cfg.K, mk, d), idx, axis=1)
    want = _clear_field_subgradients(cfg, xq_parts, wbar)

    assert np.array_equal(np.asarray(decoded), np.asarray(want))


def test_multiclass_step_matches_cleartext_real(dataset):
    """Full real-domain step: coded (d, c) update == cleartext surrogate
    update on the quantized data, up to sigmoid-coefficient quantization."""
    x, y = dataset
    cfg = mc_cfg()
    state = protocol.setup(cfg, jax.random.PRNGKey(0), x, y)
    eta = 0.5
    new = protocol.step(cfg, jax.random.PRNGKey(9), state, eta)
    assert new.w.shape == (x.shape[1], cfg.c)

    kq, _ = jax.random.split(jax.random.split(jax.random.PRNGKey(9))[0])
    w0 = jnp.zeros((x.shape[1], cfg.c))
    wbar = quantize.quantize_weights(
        jax.random.split(jax.random.PRNGKey(9))[0], w0, cfg.lw, cfg.r, cfg.p)
    coeffs = sigmoid_poly.fit_sigmoid(cfg.r)
    onehot = jax.nn.one_hot(state.y[: state.m], cfg.c)
    gb = jnp.stack([
        sigmoid_poly.gbar_real(state.xq_real, wbar[:, cls], coeffs,
                               cfg.lx, cfg.lw, cfg.p)
        for cls in range(cfg.c)], axis=1)                # (m_padded, c)
    grad = (state.xq_real.T @ gb - state.xty) / state.m
    want = w0 - eta * grad
    err = float(jnp.abs(new.w - want).max())
    assert err < 2e-2, err


def test_multiclass_straggler_tolerance(dataset):
    """Any threshold-sized survivor set yields the SAME (d, c) update."""
    x, y = dataset
    cfg = mc_cfg()
    state = protocol.setup(cfg, jax.random.PRNGKey(0), x, y)
    full = protocol.step(cfg, jax.random.PRNGKey(1), state, 0.5)
    part = protocol.step(cfg, jax.random.PRNGKey(1), state, 0.5,
                         survivors=np.array([6, 4, 2, 0, 1, 3, 5]))
    assert np.allclose(np.asarray(full.w), np.asarray(part.w), atol=1e-6)


def test_multiclass_convergence(dataset):
    """10-class-style training beats the uniform-prediction loss and tracks
    the cleartext baseline (paper Fig. 4, generalized)."""
    x, y = dataset
    cfg = mc_cfg()
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=15,
                             eval_every=15)
    state = protocol.setup(cfg, jax.random.PRNGKey(7), x, y)
    eta = protocol.lipschitz_eta(state.xq_real)
    xq = state.xq_real[: state.m]
    onehot = jax.nn.one_hot(y, cfg.c)
    wc = jnp.zeros((x.shape[1], cfg.c))
    for _ in range(15):
        wc = wc - eta * (xq.T @ (protocol.sigmoid(xq @ wc) - onehot)) / state.m
    l_clear, _ = protocol.multiclass_loss_and_accuracy(wc, xq, y)
    assert hist[-1]["loss"] < 0.6365        # improved from -log sigmoid(0)
    assert abs(hist[-1]["loss"] - float(l_clear)) < 2e-2
