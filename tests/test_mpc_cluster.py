"""MPC-on-the-cluster-runtime acceptance (DESIGN.md §7).

The load-bearing invariant mirrors tests/test_cluster.py's for the coded
path: MPCClusterRunner — multi-phase rounds through the event scheduler,
reconstruction at the OBSERVED first 2T+1 arrivals — must produce exactly
the weights of the single-host ``mpc_baseline`` oracle with the same key,
on both backends, stragglers included.  The runtime changes the timing of
a BGW iteration, never what it computes.

The structural claims of the paper's comparison are pinned too: every
reshare phase is a wait-for-all barrier (a straggler stalls EVERYONE even
when reconstruction doesn't need its share), and a dead worker starves the
round outright (no erasure decoding in BGW).

Socket tests spawn N real worker processes and are marked ``slow``.
"""
import math
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (
    ClusterDecodeError,
    DeadWorkerLatency,
    DeterministicLatency,
    LognormalTailLatency,
    MPCClusterRunner,
    mpc_phase_models,
)
from repro.core import field, mpc_baseline as mpc
from repro.data import synthetic


@pytest.fixture(scope="module")
def binary_data():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=200, d=16)


class OneSlow(DeterministicLatency):
    """Worker ``slow`` always takes ``slow_s``; everyone else ``base``."""

    def __init__(self, slow: int, slow_s: float, base: float = 1.0):
        super().__init__(base=base, skew=0.01)
        self.slow = slow
        self.slow_s = slow_s

    def sample(self, round: int, worker: int) -> float:
        return self.slow_s if worker == self.slow else super().sample(
            round, worker)


# ---------------------------------------------------------------------------
# Numerics: subset reconstruction
# ---------------------------------------------------------------------------

def test_reconstruct_at_any_subset_matches_prefix(key):
    """Any 2T+1 shares of a degree-2T sharing interpolate to the SAME field
    element as the first 2T+1 — the exactness that lets the master decode
    at arrival order."""
    cfg = mpc.MPCConfig(N=8, T=3)
    k1, k2 = jax.random.split(key)
    a = jax.random.randint(k1, (6,), 0, field.P, dtype=jnp.int32)
    b = jax.random.randint(k2, (6,), 0, field.P, dtype=jnp.int32)
    prod = field.mulmod(mpc.share(cfg, k1, a), mpc.share(cfg, k2, b),
                        field.P)                         # degree 2T
    ref = mpc.reconstruct(cfg, prod, 2 * cfg.T)
    rng = np.random.default_rng(0)
    for _ in range(5):
        subset = rng.permutation(8)[: 2 * cfg.T + 1]
        got = mpc.reconstruct_at(cfg, prod[jnp.asarray(subset)], subset)
        assert np.array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# In-process simulation: THE acceptance criterion
# ---------------------------------------------------------------------------

def test_mpc_cluster_bit_identical_with_straggler(binary_data):
    """N=8 T=3, >= 10 rounds, one injected (alive) straggler: weights
    bit-identical to the single-host oracle, and the straggler — though
    never part of the 2T+1 reconstruction — gates every reshare barrier."""
    x, y = binary_data
    cfg = mpc.MPCConfig(N=8, T=3, r=1)
    key = jax.random.PRNGKey(7)
    slow = 7
    models = [OneSlow(slow, 6.0), OneSlow(slow, 6.0, base=0.5)]
    runner = MPCClusterRunner(cfg, key, x, y, models)
    w = runner.run(10)

    w_ref, _ = mpc.train(cfg, key, x, y, iters=10)
    assert (np.asarray(w) == np.asarray(w_ref)).all()

    for t, trace in runner.traces.items():
        order = list(map(int, trace.responders[: 2 * cfg.T + 1]))
        assert slow not in order               # last arrival, never decoded
        # the barrier waited for the straggler anyway: wait-for-all
        assert trace.barriers[0] - trace.t_start >= 6.0
        assert trace.mpc_wait_s >= 6.0 + 0.5   # barrier + fastest final leg


def test_mpc_cluster_bit_identical_lognormal_orders_shuffle(binary_data):
    """Heavy-tailed latency shuffles the arrival order across rounds; the
    subset reconstruction must track it exactly."""
    x, y = binary_data
    cfg = mpc.MPCConfig(N=8, T=3, r=1)
    key = jax.random.PRNGKey(11)
    runner = MPCClusterRunner(
        cfg, key, x, y, mpc_phase_models("lognormal", seed=3, r=cfg.r))
    w = runner.run(12)
    orders = {tuple(t.responders[: 7]) for t in runner.traces.values()}
    assert len(orders) > 1, "latency model produced a constant order"
    w_ref, _ = mpc.train(cfg, key, x, y, iters=12)
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_mpc_cluster_r2_has_one_barrier_per_reduction(binary_data):
    """r=2: two degree reductions -> two reshare barriers per round, each
    gated on the slowest worker, and bit-identity still holds."""
    x, y = binary_data
    cfg = mpc.MPCConfig(N=8, T=2, r=2, p=field.P30)
    key = jax.random.PRNGKey(5)
    runner = MPCClusterRunner(
        cfg, key, x, y, mpc_phase_models("deterministic", r=cfg.r))
    w = runner.run(3)
    w_ref, _ = mpc.train(cfg, key, x, y, iters=3)
    assert (np.asarray(w) == np.asarray(w_ref)).all()
    for trace in runner.traces.values():
        assert len(trace.barriers) == cfg.r
        assert trace.barriers[0] < trace.barriers[1] <= trace.t_done


def test_mpc_cluster_dead_worker_starves_the_round(binary_data):
    """BGW cannot treat a dead worker as an erasure: the reshare barrier
    never completes and the round starves — even though 2T+1 < N live
    workers could have reconstructed, they never get past the barrier."""
    x, y = binary_data
    cfg = mpc.MPCConfig(N=8, T=1, r=1)                   # 2T+1 = 3 << 8
    models = [DeadWorkerLatency(DeterministicLatency(), {5: 2}),
              DeterministicLatency(base=0.5)]
    runner = MPCClusterRunner(cfg, jax.random.PRNGKey(7), x, y, models,
                              round_timeout_s=60.0)
    with pytest.raises(ClusterDecodeError):
        runner.run(10)
    assert 0 in runner.traces and 1 in runner.traces     # pre-death rounds ok
    assert 2 not in runner.traces


def test_mpc_waits_exceed_coded_waits_under_same_tail(binary_data):
    """The measured head-to-head the benchmarks aggregate: under the same
    lognormal tail, BGW's r+1 wait-for-all barriers cost strictly more per
    round than the coded first-T decode."""
    from repro.cluster import ClusterRunner
    from repro.core import protocol

    x, y = binary_data
    key = jax.random.PRNGKey(7)
    coded = ClusterRunner(protocol.CPMLConfig(N=8, K=2, T=1, r=1), key, x, y,
                          LognormalTailLatency(seed=0, tail_prob=0.2,
                                               tail_scale=10.0))
    coded.run(10)
    bgw = MPCClusterRunner(mpc.MPCConfig(N=8, T=1, r=1), key, x, y,
                           mpc_phase_models("lognormal", seed=0, r=1))
    bgw.run(10)
    assert (bgw.wait_stats()["mpc"]["mean"]
            > coded.wait_stats()["coded_T"]["mean"])


# ---------------------------------------------------------------------------
# Socket backend: real worker processes, relayed reshares (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_socket_mpc_bit_identical_with_straggler(binary_data):
    """THE socket acceptance criterion: N=8 T=3, 10 rounds over real TCP
    with one worker process that really sleeps before every phase — the
    reshare traffic relays through the master, every barrier waits for the
    sleeper, and the weights are bit-identical to the single-host oracle."""
    from repro.launch.cpml_cluster import local_socket_cluster

    x, y = binary_data
    cfg = mpc.MPCConfig(N=8, T=3, r=1)
    key = jax.random.PRNGKey(7)
    sleep = 0.3
    with local_socket_cluster(cfg.N, sleep_s={7: sleep}) as tr:
        runner = MPCClusterRunner(cfg, key, x, y, None, transport=tr,
                                  round_timeout_s=300.0)
        runner.provision()
        w = runner.run(10)
        runner.shutdown_workers()

    assert len(runner.traces) == 10
    w_ref, _ = mpc.train(cfg, key, x, y, iters=10)
    assert (np.asarray(w) == np.asarray(w_ref)).all()
    # steady-state rounds (0 is jit warmup) are gated on the sleeper: it
    # sleeps before its sub-share send AND its final send, so every round
    # costs at least both sleeps even though 2T+1 = 7 arrivals suffice.
    for t, trace in runner.traces.items():
        if t == 0:
            continue
        assert trace.mpc_wait_s >= 2 * sleep


@pytest.mark.slow
def test_socket_collect_all_exits_when_worker_dies(binary_data):
    """Regression (pre-fix: infinite spin): dispatch_round(collect_all=True,
    timeout_s=inf) on a real transport with a worker that died mid-run must
    exit once the heartbeat monitor declares the silent worker dead, not
    re-poll forever on `len(arrivals) < len(dispatched)`."""
    from repro.cluster import ClusterRunner
    from repro.core import protocol
    from repro.core.protocol import engine
    from repro.launch.cpml_cluster import local_socket_cluster

    x, y = binary_data
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)        # threshold 4
    with local_socket_cluster(cfg.N, die_at_round={0: 1}) as tr:
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                               latency=None, transport=tr,
                               round_timeout_s=120.0,
                               heartbeat_timeout_s=3.0)
        runner.provision()
        runner.step_round(0, 3)                          # all alive
        # round 1: worker 0 crashes on receipt — dispatch by hand with the
        # pathological arguments (the runner itself would clamp timeout)
        key_t = engine.round_key(runner.kloop, 1)
        w_shares = np.asarray(engine.encode_round_shares(cfg, key_t,
                                                         runner.w2))
        payloads = {w: {"w_share": w_shares[w], "batch": None}
                    for w in range(cfg.N)}
        result = {}

        def go():
            result["trace"] = runner.scheduler.dispatch_round(
                1, cfg.threshold, monitor=runner.monitor,
                timeout_s=math.inf, payloads=payloads, collect_all=True)

        th = threading.Thread(target=go, daemon=True)
        th.start()
        th.join(timeout=90.0)
        assert not th.is_alive(), \
            "collect_all spun forever waiting for a dead worker"
        trace = result["trace"]
        assert len(trace.responders) >= cfg.threshold    # decode was fine
        assert 0 not in trace.arrivals                   # the corpse
        assert math.isinf(trace.t_all)                   # unobservable
        runner.shutdown_workers()


def test_collect_all_inf_timeout_without_detector_is_refused():
    """The unfixable configuration is rejected up front: a real-transport
    collect-all with timeout_s=inf and no (finite) failure detector could
    never conclude a dead worker's response isn't coming."""
    from repro.cluster import EventScheduler, SocketTransport

    master = SocketTransport.master(poll_interval_s=0.02)
    try:
        sched = EventScheduler(2, latency=None, transport=master)
        with pytest.raises(ValueError, match="collect_all"):
            sched.dispatch_round(0, threshold=1, timeout_s=math.inf,
                                 collect_all=True)
    finally:
        master.close()
