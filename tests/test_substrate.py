"""Optimizer, compression, checkpoint, resilience, data, sharding rules."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.loader import LMBatchLoader
from repro.optim import compress, optimizers as opt
from repro.runtime.resilience import (FailureInjector, HeartbeatMonitor,
                                      ResilientLoop)


# ----------------------------- optimizers ---------------------------------

def test_adamw_minimizes_quadratic(key):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = opt.OptimizerConfig(learning_rate=0.1, warmup_steps=0,
                              total_steps=200, weight_decay=0.0)
    state = opt.init_state(cfg, params)
    for _ in range(150):
        grads = {"w": params["w"] - target}
        params, state, _ = opt.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"] - target).max()) < 0.15


def test_sgd_momentum(key):
    params = {"w": jnp.array([4.0])}
    cfg = opt.OptimizerConfig(name="sgd", learning_rate=0.05, warmup_steps=0,
                              momentum=0.9, grad_clip=100.0)
    state = opt.init_state(cfg, params)
    for _ in range(100):
        params, state, _ = opt.apply_updates(cfg, params, {"w": params["w"]},
                                             state)
    assert abs(float(params["w"][0])) < 0.2


def test_grad_clip():
    g = {"a": jnp.ones(4) * 100.0}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(opt.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_lr_schedule():
    cfg = opt.OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                              total_steps=100)
    assert float(opt.lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5, rel=0.01)
    assert float(opt.lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1,
                                                                  rel=0.01)


def test_compression_unbiased(key):
    g = jax.random.normal(key, (2048,))
    acc = jnp.zeros_like(g)
    reps = 300
    for i in range(reps):
        q, s = compress.quantize_grad(jax.random.PRNGKey(i), g, bits=8)
        acc = acc + compress.dequantize_grad(q, s)
    err = float(jnp.abs(acc / reps - g).max())
    assert err < 0.02, err


def test_compress_tree_roundtrip(key):
    grads = {"a": jax.random.normal(key, (64,)),
             "b": {"c": jax.random.normal(key, (8, 8))}}
    q, s = compress.compress_tree(key, grads, bits=8)
    back = compress.decompress_tree(q, s)
    for x, y in zip(jax.tree.leaves(grads), jax.tree.leaves(back)):
        assert float(jnp.abs(x - y).max()) < 0.02


# ----------------------------- checkpoint ---------------------------------

def test_checkpoint_roundtrip(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt_state": {"step": jnp.int32(7)}}
    mgr.save(7, state)
    out = mgr.restore()
    assert out["step"] == 7
    assert np.allclose(out["params"]["w"], np.arange(6.0).reshape(2, 3))


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": {"w": jnp.ones(1) * s}})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4
    assert float(mgr.restore()["params"]["w"][0]) == 4.0


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(1, {"params": {"w": jnp.zeros(4)}})
    mgr.wait()
    assert mgr.latest_step() == 1


def test_elastic_restore_with_sharding(tmp_path):
    """Restore places leaves with provided shardings (1-device 'mesh')."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1,), ("data",))
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(2, {"params": {"w": jnp.ones((4, 4))}})
    sh = {"params": {"w": NamedSharding(mesh, P("data", None))}}
    out = mgr.restore(shardings=sh)
    assert out["params"]["w"].sharding == sh["params"]["w"]


# ----------------------------- resilience ---------------------------------

def test_heartbeat_survivors():
    mon = HeartbeatMonitor(6)
    for i in range(6):
        mon.heartbeat(i, latency_s=1.0)
    mon.mark_failed(2)
    mon.heartbeat(4, latency_s=50.0)   # straggler
    surv = mon.survivors()
    assert 2 not in surv and 4 not in surv
    assert len(surv) == 4


def test_failure_injection_deterministic():
    mon1, mon2 = HeartbeatMonitor(8), HeartbeatMonitor(8)
    for mon in (mon1, mon2):
        inj = FailureInjector(seed=3, fail_prob=0.2, straggle_prob=0.2)
        for _ in range(5):
            inj.step(mon)
    assert list(mon1.survivors()) == list(mon2.survivors())


def test_resilient_loop_restores(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(0, {"params": {"w": jnp.zeros(1)}})
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if calls["n"] == 3:           # one transient failure
            raise RuntimeError("injected node failure")
        return {"params": {"w": state["params"]["w"] + 1}}

    loop = ResilientLoop(mgr, checkpoint_every=2, max_retries=2)
    out = loop.run({"params": {"w": jnp.zeros(1)}}, step_fn, 0, 4)
    assert loop.restarts == 1
    assert float(out["params"]["w"][0]) == 4.0   # replayed to completion


# ----------------------------- data ---------------------------------------

def test_loader_deterministic_and_shaped():
    with LMBatchLoader(None, batch=4, seq=16, vocab=100, seed=5) as l1, \
            LMBatchLoader(None, batch=4, seq=16, vocab=100, seed=5) as l2:
        b1, b2 = next(iter(l1)), next(iter(l2))
    assert b1["tokens"].shape == (4, 16)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert np.array_equal(np.asarray(b1["tokens"][:, 1:]),
                          np.asarray(b1["labels"][:, :-1]))


def test_loader_close_joins_prefetch_thread():
    """close() must actually END the daemon producer — even when it is
    blocked on a full prefetch queue — and be idempotent."""
    loader = LMBatchLoader(None, batch=2, seq=8, vocab=50, prefetch=1)
    deadline = time.time() + 5.0
    while not loader._q.full() and time.time() < deadline:
        time.sleep(0.01)                 # producer now blocked in put()
    loader.close()
    assert not loader._thread.is_alive()
    loader.close()                       # idempotent


# ----------------------------- sharding rules ------------------------------

def test_divisible_or_replicate():
    from jax.sharding import PartitionSpec as P
    from repro.parallel import rules

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    mesh = FakeMesh()
    # divisible head dim -> sharded on model
    assert rules.spec_for(mesh, (2048, 4096), ("embed", "heads")) == \
        P("data", "model")
    # 25 heads stacked dim not divisible -> replicated
    assert rules.spec_for(mesh, (25, 64), ("heads", None)) == P()
    # odd vocab replicates, embed still sharded
    assert rules.spec_for(mesh, (32001, 1600), ("vocab", "embed")) == \
        P(None, "data")
    # batch over (pod, data) on multi-pod mesh
    class PodMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert rules.spec_for(PodMesh(), (256, 4096), ("batch", "seq")) == \
        P(("pod", "data"))
    # batch=1 cannot shard
    assert rules.spec_for(PodMesh(), (1, 4096), ("batch", "seq")) == P()
