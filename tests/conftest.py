"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def exact_modmatmul(a, b, p):
    """Python-int (object dtype) oracle — immune to int64 overflow."""
    ao = np.asarray(a).astype(object)
    bo = np.asarray(b).astype(object)
    return (ao @ bo) % p
