"""Lagrange coded computing: correctness, thresholds, privacy (paper §3.2/A.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field, lagrange


def _scheme(N=9, K=3, T=2):
    return lagrange.CodingScheme(N=N, K=K, T=T)


def test_encode_decode_identity(key):
    s = _scheme()
    parts = jax.random.randint(key, (3, 4, 5), 0, field.P, dtype=jnp.int32)
    masks = lagrange.draw_masks(jax.random.PRNGKey(1), 2, (4, 5))
    shares = lagrange.encode(s, parts, masks)
    assert shares.shape == (9, 4, 5)
    dec = lagrange.decode(s, shares, np.arange(9), deg_f=1)
    assert np.array_equal(np.asarray(dec), np.asarray(parts))


@pytest.mark.parametrize("survivor_seed", [0, 1, 2, 3])
def test_decode_from_any_threshold_subset(key, survivor_seed):
    """ANY deg_f*(K+T-1)+1 workers suffice — the straggler property."""
    s = _scheme(N=9, K=3, T=2)
    parts = jax.random.randint(key, (3, 6), 0, field.P, dtype=jnp.int32)
    masks = lagrange.draw_masks(jax.random.PRNGKey(1), 2, (6,))
    shares = lagrange.encode(s, parts, masks)
    need = lagrange.degree_threshold(3, 2, 1)     # = 5
    rng = np.random.default_rng(survivor_seed)
    surv = rng.choice(9, size=need, replace=False)
    dec = lagrange.decode(s, shares[jnp.asarray(surv)], surv, deg_f=1)
    assert np.array_equal(np.asarray(dec), np.asarray(parts))


def test_decode_polynomial_computation(key):
    """Workers compute f(x) = x*x elementwise (deg 2); decode recovers
    f(parts) from (2)(K+T-1)+1 results — the h(z)=f(u(z)) argument."""
    s = _scheme(N=9, K=2, T=1)
    parts = jax.random.randint(key, (2, 8), 0, field.P, dtype=jnp.int32)
    masks = lagrange.draw_masks(jax.random.PRNGKey(1), 1, (8,))
    shares = lagrange.encode(s, parts, masks)
    results = field.mulmod(shares, shares, field.P)       # per-worker f
    need = lagrange.degree_threshold(2, 1, 2)             # 2*(2)+1 = 5
    surv = np.array([8, 3, 5, 0, 6])
    dec = lagrange.decode(s, results[jnp.asarray(surv)], surv, deg_f=2)
    want = field.mulmod(parts, parts, field.P)
    assert np.array_equal(np.asarray(dec), np.asarray(want))


def test_below_threshold_fails():
    s = _scheme(N=9, K=3, T=2)
    with pytest.raises(AssertionError):
        lagrange.decode(s, jnp.zeros((4, 2), jnp.int32), np.arange(4), 1)


def test_recovery_threshold_formula():
    assert lagrange.recovery_threshold(K=13, T=1, r=1) == 3 * 13 + 1
    assert lagrange.recovery_threshold(K=7, T=7, r=1) == 3 * 13 + 1
    assert lagrange.recovery_threshold(K=2, T=1, r=2) == 5 * 2 + 1


def test_mds_bottom_block():
    """Privacy (App. A.4): every T x T submatrix of U_bottom is invertible,
    so T shares are one-time-padded by the uniform masks."""
    s = _scheme(N=8, K=3, T=2)
    U = s.encode_matrix                      # (K+T, N)
    bottom = U[3:, :]                        # (T, N)
    from itertools import combinations
    p = field.P
    for cols in combinations(range(8), 2):
        sub = bottom[:, cols].astype(object)
        det = (sub[0, 0] * sub[1, 1] - sub[0, 1] * sub[1, 0]) % p
        assert det != 0, f"singular T x T block at {cols}"


def test_shares_uniform_given_masks(key):
    """With T=1, a single worker's share of ZERO data is exactly
    (mask * u_i) — uniform.  Check the map mask -> share is a bijection
    (distribution-preserving), i.e. the coefficient is nonzero."""
    s = _scheme(N=5, K=2, T=1)
    U = s.encode_matrix
    assert (U[2, :] != 0).all()   # mask row coefficient never vanishes


def test_t_collusion_independence(key):
    """Empirical privacy: encode the SAME dataset with fresh masks; any
    single worker's share distribution should cover the field uniformly.
    (chi^2-lite: bucket means close to uniform.)"""
    s = _scheme(N=5, K=2, T=1)
    parts = jnp.ones((2, 16), jnp.int32)     # constant data
    samples = []
    for i in range(200):
        masks = lagrange.draw_masks(jax.random.PRNGKey(i), 1, (16,))
        shares = lagrange.encode(s, parts, masks)
        samples.append(np.asarray(shares[0]).ravel())
    vals = np.concatenate(samples).astype(np.float64) / field.P
    # uniform on [0,1): mean ~ 0.5, var ~ 1/12
    assert abs(vals.mean() - 0.5) < 0.02
    assert abs(vals.var() - 1 / 12) < 0.005
