"""Smoke coverage for the launch/ CLIs (previously untested).

Fast cases call ``main(argv)`` in-process on tiny shapes: parser wiring,
config plumbing, stats/JSON output.  Multi-process cases (the socket
cluster CLI, which spawns N worker processes) are marked ``slow`` per
DESIGN.md §8.  Deeper socket-runtime behavior (bit-identity, kill-a-worker)
lives in tests/test_socket_cluster.py.
"""
import json
import math
import subprocess
import sys

import pytest

from repro.launch import cpml_cluster, cpml_train, cpml_worker

TINY = ["--m", "96", "--d", "12", "--iters", "3"]


def test_cpml_train_smoke(tmp_path):
    out = tmp_path / "train.json"
    rc = cpml_train.main(TINY + ["--eval-every", "3",
                                 "--json-out", str(out)])
    assert rc == 0
    blob = json.loads(out.read_text())
    assert blob["config"]["N"] == 8
    assert 0.0 <= blob["acc_coded"] <= 1.0
    assert blob["history"] and blob["history"][-1]["iter"] == 3


def test_cpml_train_multiclass_minibatch_smoke():
    assert cpml_train.main(TINY + ["--classes", "3", "--batch-rows", "8",
                                   "--eval-every", "0"]) == 0


def test_cpml_cluster_inprocess_smoke(tmp_path):
    out = tmp_path / "cluster.json"
    rc = cpml_cluster.main(TINY + ["--latency", "lognormal",
                                   "--json-out", str(out)])
    assert rc == 0
    blob = json.loads(out.read_text())
    assert blob["config"]["transport"] == "inprocess"
    assert blob["wait_stats"]["rounds"]["n"] == 3.0
    assert math.isfinite(blob["wait_stats"]["coded_T"]["mean"])


def test_cpml_cluster_dead_resilient_smoke():
    # the README's recovery demo path: worker deaths below the decode
    # threshold force a checkpoint restore + reprovision mid-run
    rc = cpml_cluster.main(["--m", "96", "--d", "12", "--iters", "6",
                            "--latency", "dead", "--resilient",
                            "--checkpoint-every", "2"])
    assert rc == 0


def test_cpml_worker_parser_and_unreachable_master():
    # parser contract
    args = cpml_worker.build_parser().parse_args(
        ["--port", "1", "--worker", "3", "--die-at-round", "5"])
    assert args.worker == 3 and args.die_at_round == 5
    with pytest.raises(SystemExit):        # --port/--worker are required
        cpml_worker.build_parser().parse_args([])
    # nothing listens on the port: a clean nonzero exit, not a hang
    rc = cpml_worker.main(["--host", "127.0.0.1", "--port", "1",
                           "--worker", "0", "--connect-timeout", "2"])
    assert rc == 1


@pytest.mark.slow
def test_cpml_cluster_socket_cli_end_to_end(tmp_path):
    """The full multi-process path through the CLI itself: spawn N real
    workers, train over TCP, kill one mid-run, verify bit-identity."""
    out = tmp_path / "socket.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.cpml_cluster",
         "--transport", "socket", "-N", "5", "-K", "1", "-T", "1",
         "--m", "96", "--d", "12", "--iters", "4",
         "--kill-worker", "4", "--kill-at-round", "2",
         "--json-out", str(out)],
        capture_output=True, text=True, timeout=600,
        env=_env_with_src())
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "bit-identical to train_reference" in proc.stdout
    assert "True" in proc.stdout
    blob = json.loads(out.read_text())
    assert blob["config"]["transport"] == "socket"
    assert blob["wait_stats"]["rounds"]["n"] == 4.0


def _env_with_src():
    import os
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env
