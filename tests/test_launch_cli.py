"""Smoke coverage for the launch/ CLIs (previously untested).

Fast cases call ``main(argv)`` in-process on tiny shapes: parser wiring,
config plumbing, stats/JSON output.  Multi-process cases (the socket
cluster CLI, which spawns N worker processes) are marked ``slow`` per
DESIGN.md §8.  Deeper socket-runtime behavior (bit-identity, kill-a-worker)
lives in tests/test_socket_cluster.py.
"""
import json
import math
import subprocess
import sys

import pytest

from repro.launch import cpml_cluster, cpml_serve, cpml_train, cpml_worker

TINY = ["--m", "96", "--d", "12", "--iters", "3"]
SERVE_TINY = ["-N", "6", "-K", "2", "-T", "1", "--d", "12", "--classes", "5",
              "--max-batch", "8"]


def test_cpml_train_smoke(tmp_path):
    out = tmp_path / "train.json"
    rc = cpml_train.main(TINY + ["--eval-every", "3",
                                 "--json-out", str(out)])
    assert rc == 0
    blob = json.loads(out.read_text())
    assert blob["config"]["N"] == 8
    assert 0.0 <= blob["acc_coded"] <= 1.0
    assert blob["history"] and blob["history"][-1]["iter"] == 3


def test_cpml_train_multiclass_minibatch_smoke():
    assert cpml_train.main(TINY + ["--classes", "3", "--batch-rows", "8",
                                   "--eval-every", "0"]) == 0


def test_cpml_cluster_inprocess_smoke(tmp_path):
    out = tmp_path / "cluster.json"
    rc = cpml_cluster.main(TINY + ["--latency", "lognormal",
                                   "--json-out", str(out)])
    assert rc == 0
    blob = json.loads(out.read_text())
    assert blob["config"]["transport"] == "inprocess"
    assert blob["wait_stats"]["rounds"]["n"] == 3.0
    assert math.isfinite(blob["wait_stats"]["coded_T"]["mean"])


def test_cpml_cluster_dead_resilient_smoke():
    # the README's recovery demo path: worker deaths below the decode
    # threshold force a checkpoint restore + reprovision mid-run
    rc = cpml_cluster.main(["--m", "96", "--d", "12", "--iters", "6",
                            "--latency", "dead", "--resilient",
                            "--checkpoint-every", "2"])
    assert rc == 0


def test_cpml_serve_inprocess_smoke(tmp_path):
    out = tmp_path / "serve.json"
    rc = cpml_serve.main(SERVE_TINY + ["--queries", "8", "--rows", "3",
                                       "--rate", "300",
                                       "--json-out", str(out)])
    assert rc == 0
    blob = json.loads(out.read_text())
    assert blob["config"]["threshold"] == 5
    assert blob["stats"]["queries"] == 8
    assert blob["stats"]["oracle"]["bit_identical"] is True
    assert blob["stats"]["latency_first"]["p99"] >= 0.0


def test_cpml_serve_closed_loop_straggler_smoke(tmp_path):
    # closed loop + simulated sleeper + collect_all: both wait policies
    # measured, predictions still bit-identical to the oracle
    rc = cpml_serve.main(SERVE_TINY + ["--mode", "closed", "--queries", "3",
                                       "--straggle-worker", "5",
                                       "--straggle-sleep", "0.2",
                                       "--collect-all",
                                       "--trace-out",
                                       str(tmp_path / "serve.trace.json"),
                                       "--metrics-out",
                                       str(tmp_path / "serve.prom")])
    assert rc == 0
    assert (tmp_path / "serve.trace.json").exists()
    assert "serve_rounds_total" in (tmp_path / "serve.prom").read_text()


# ---------------------------------------------------------------------------
# regressions: the coded-head decode path in launch/serve.py (both bugs
# shipped in the seed — these fail there)
# ---------------------------------------------------------------------------

def test_serve_coded_head_runs_decode_loop(capsys):
    """Regression: ``--coded-head`` used to return after the one-shot
    accuracy check, silently ignoring ``--gen`` — generation must run,
    with the coded head projecting every step's real hidden state."""
    from repro.launch import serve
    rc = serve.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "1",
                     "--prompt-len", "8", "--gen", "2", "--coded-head",
                     "--coded-k", "4", "--coded-t", "1", "--coded-n", "6"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "coded head: rel err" in out
    assert "generated (1, 2)" in out       # the seed returned before this


def test_greedy_decode_coded_path_returns_tokens():
    """Regression: greedy_decode's coded branch indexed ``logits`` like a
    dict of activations (TypeError on a jax array) — it must consume the
    post-final-norm hidden state and return (B, steps) tokens."""
    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.core import coded_linear as CL
    from repro.launch import serve
    from repro.models import model as M

    cfg = registry.reduced_config(registry.get_config("tinyllama-1.1b"))
    rc = RunConfig(q_block=8, kv_block=8, scan_chunk=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    ccfg = CL.CodedLinearConfig(N=6, K=4, T=1)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(jnp.float32)
    w = w[:, : w.shape[1] - (w.shape[1] % 4)]
    shares = CL.encode_weights(ccfg, jax.random.PRNGKey(2), w)
    toks = serve.greedy_decode(cfg, rc, params, prompt, 2,
                               coded={"cfg": ccfg, "shares": shares})
    assert toks.shape == (1, 2)
    assert int(toks.max()) < cfg.vocab_size


def test_example_coded_head_serving_propagates_failure(monkeypatch):
    """Regression: the example swallowed serve.main's return code, so CI
    smoked it green even when serving failed."""
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "coded_head_serving.py")
    spec = importlib.util.spec_from_file_location("coded_head_serving", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    monkeypatch.setattr(mod.serve, "main", lambda argv: 17)
    assert mod.main() == 17


def test_cpml_worker_parser_and_unreachable_master():
    # parser contract
    args = cpml_worker.build_parser().parse_args(
        ["--port", "1", "--worker", "3", "--die-at-round", "5"])
    assert args.worker == 3 and args.die_at_round == 5
    with pytest.raises(SystemExit):        # --port/--worker are required
        cpml_worker.build_parser().parse_args([])
    # nothing listens on the port: a clean nonzero exit, not a hang
    rc = cpml_worker.main(["--host", "127.0.0.1", "--port", "1",
                           "--worker", "0", "--connect-timeout", "2"])
    assert rc == 1


@pytest.mark.slow
def test_cpml_serve_socket_cli_end_to_end(tmp_path):
    """The serving CLI's multi-process path: N real workers provisioned
    with model shares, open-loop queries over TCP, one worker killed
    mid-service, predictions verified against the plaintext oracle."""
    out = tmp_path / "serve.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.cpml_serve",
         "--transport", "socket", "-N", "6", "-K", "2", "-T", "1",
         "--d", "12", "--classes", "5", "--max-batch", "8",
         "--queries", "8", "--rows", "4", "--rate", "100",
         "--kill-worker", "5", "--kill-at-round", "1",
         "--round-timeout", "120", "--json-out", str(out)],
        capture_output=True, text=True, timeout=600,
        env=_env_with_src())
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "bit-identical to the uncoded plaintext oracle: True" \
        in proc.stdout
    blob = json.loads(out.read_text())
    assert blob["config"]["transport"] == "socket"
    assert blob["stats"]["queries"] == 8


@pytest.mark.slow
def test_cpml_cluster_socket_cli_end_to_end(tmp_path):
    """The full multi-process path through the CLI itself: spawn N real
    workers, train over TCP, kill one mid-run, verify bit-identity."""
    out = tmp_path / "socket.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.cpml_cluster",
         "--transport", "socket", "-N", "5", "-K", "1", "-T", "1",
         "--m", "96", "--d", "12", "--iters", "4",
         "--kill-worker", "4", "--kill-at-round", "2",
         "--json-out", str(out)],
        capture_output=True, text=True, timeout=600,
        env=_env_with_src())
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "bit-identical to train_reference" in proc.stdout
    assert "True" in proc.stdout
    blob = json.loads(out.read_text())
    assert blob["config"]["transport"] == "socket"
    assert blob["wait_stats"]["rounds"]["n"] == 4.0


@pytest.mark.slow
def test_cpml_cluster_alcc_socket_cli_end_to_end(tmp_path):
    """ALCC float engine over real sockets: FROUND/FRESULT v2 frames, float
    worker compute under jit, decode-conditioning stats in wait_stats, and
    the replay-within-tolerance verification contract (sim is bit-exact;
    socket workers sum in XLA order, so the replay gap is bounded by the
    decode error budget, not zero)."""
    out = tmp_path / "alcc_socket.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.cpml_cluster",
         "--engine", "alcc", "--transport", "socket",
         "-N", "8", "-K", "2", "-T", "1",
         "--m", "96", "--d", "12", "--iters", "3",
         "--json-out", str(out)],
        capture_output=True, text=True, timeout=600,
        env=_env_with_src())
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
    blob = json.loads(out.read_text())
    assert blob["config"]["engine"] == "alcc"
    assert blob["wait_stats"]["alcc"]["fallbacks"]["n"] == 0.0


@pytest.mark.slow
def test_cpml_cluster_alcc_mlp_socket_cli_end_to_end(tmp_path):
    """The dormant MLP, trained end-to-end over TCP under ALCC: two coded
    phases per step through real worker processes, master-side gelu/softmax
    between them, loss within the documented tolerance of the jax.grad
    oracle."""
    out = tmp_path / "alcc_mlp_socket.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.cpml_cluster",
         "--engine", "alcc", "--model", "mlp", "--transport", "socket",
         "-N", "8", "-K", "2", "-T", "1", "--classes", "4",
         "--hidden", "8", "--m", "96", "--d", "12", "--iters", "3",
         "--json-out", str(out)],
        capture_output=True, text=True, timeout=600,
        env=_env_with_src())
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
    blob = json.loads(out.read_text())
    assert blob["config"]["model"] == "mlp"
    assert abs(blob["loss_coded"] - blob["loss_oracle"]) <= 0.05


def _env_with_src():
    import os
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env
