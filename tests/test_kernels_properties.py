"""Property tests for the Pallas field kernels (hypothesis).

hypothesis is an optional dev dependency (DESIGN.md §8): this module skips
cleanly when it is absent; deterministic fallbacks live in test_kernels.py.
"""
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import field
from repro.kernels import ops
from conftest import exact_modmatmul


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(1, 120), n=st.integers(1, 60),
       seed=st.integers(0, 2 ** 20))
def test_modmatmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, field.P, (m, k)), jnp.int32)
    b = jnp.asarray(rng.integers(0, field.P, (k, n)), jnp.int32)
    got = np.asarray(ops.modmatmul(a, b, use_pallas=True)).astype(object)
    assert (got == exact_modmatmul(a, b, field.P)).all()
