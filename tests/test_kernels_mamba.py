"""Fused selective-scan Pallas kernel vs oracle + vs models.mamba path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import mamba_scan as K


def make_inputs(key, B, S, di, n, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, S, di), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di), dtype))
    bm = jax.random.normal(ks[2], (B, S, n), dtype) * 0.5
    cm = jax.random.normal(ks[3], (B, S, n), dtype) * 0.5
    a_log = jnp.log(jax.random.uniform(ks[4], (di, n), minval=0.3, maxval=2.0))
    d = jax.random.normal(ks[5], (di,))
    h0 = jnp.zeros((B, di, n), jnp.float32)
    return x, dt, bm, cm, a_log, d, h0


@pytest.mark.parametrize("B,S,di,n,blk_di,blk_s", [
    (1, 16, 8, 4, 8, 8),
    (2, 33, 16, 4, 8, 16),     # uneven S -> padded identity steps
    (2, 64, 32, 8, 16, 32),
])
def test_fused_scan_vs_ref(key, B, S, di, n, blk_di, blk_s):
    args = make_inputs(key, B, S, di, n)
    y, h = K.selective_scan(*args, blk_di=blk_di, blk_s=blk_s,
                            interpret=True)
    y_ref, h_ref = K.ref_selective_scan(*args)
    assert np.allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4), \
        float(jnp.abs(y - y_ref).max())
    assert np.allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def test_fused_scan_vs_model_mamba(key):
    """The kernel computes the same recurrence as models.mamba chunked scan
    (which tests against the step-by-step decode path elsewhere)."""
    from repro.configs import registry
    from repro.configs.base import RunConfig
    from repro.models import mamba as M
    cfg = registry.reduced_config(registry.get_config("falcon-mamba-7b"))
    B, S = 2, 24
    x, dt, bm, cm, a_log, d, h0 = make_inputs(key, B, S, cfg.d_inner,
                                              cfg.ssm_state)
    y, h = K.selective_scan(x, dt, bm, cm, a_log, d, h0, blk_di=32, blk_s=8,
                            interpret=True)
    # replicate with the model's chunked scan pieces
    p = {"A_log": a_log, "D": d}
    a, b = M._discretize(p, dt, bm, x)
    rc = RunConfig(scan_chunk=8)

    def chunk_step(hc, inputs):
        a_c, b_c, C_c, x_c = inputs
        h_all, h_last = M._chunk_scan(a_c, b_c, hc)
        yy = jnp.einsum("blin,bln->bli", h_all, C_c.astype(jnp.float32))
        yy = yy + d[None, None] * x_c.astype(jnp.float32)
        return h_last, yy

    nch = S // 8
    to = lambda t: t.reshape(B, nch, 8, *t.shape[2:]).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(chunk_step, h0, (to(a), to(b), to(cm), to(x)))
    y_model = ys.swapaxes(0, 1).reshape(B, S, cfg.d_inner)
    assert np.allclose(np.asarray(y), np.asarray(y_model), atol=1e-3)
    assert np.allclose(np.asarray(h), np.asarray(h_last), atol=1e-3)


def test_io_bytes_model():
    got = K.io_bytes(B=32, S=32768, di=8192, n=16)
    # dominated by x/dt in + y out: (2*2 + 4) * B*S*di
    approx = 8 * 32 * 32768 * 8192
    assert 0.9 * approx < got < 1.3 * approx
