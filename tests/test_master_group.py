"""Sharded master group: bit-identity against the single-master path
(DESIGN.md §13).

The contract under test is the module's one rule: randomness at FULL
shape, only the deterministic linear algebra per d-shard.  Every surface
the runner swaps out — dataset encode, per-round weight encode (whole and
split), streaming decode — must produce byte-identical field arrays for
ANY group size, because a deployment choice of S must never change what
the protocol computes.
"""
import numpy as np
import pytest

import jax

from repro.cluster.master_group import (MasterGroup, ShardedStreamingDecoder,
                                        d_shard_slices)
from repro.core import field, protocol
from repro.core.protocol import decode, encode, engine
from repro.data import synthetic


@pytest.fixture(scope="module")
def cfg():
    return protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3)


@pytest.fixture(scope="module")
def data():
    return synthetic.multiclass_mnist_like(jax.random.PRNGKey(0), m=96,
                                           d=22, c=3)


# ---------------------------------------------------------------------------
# Shard placement
# ---------------------------------------------------------------------------

def test_d_shard_slices_cover_d_exactly_and_balanced(cfg):
    for d, size in [(24, 2), (24, 3), (22, 2), (22, 3), (7, 4), (5, 1)]:
        slices = d_shard_slices(cfg, d, size)
        assert len(slices) == min(size, d)
        covered = np.concatenate([np.arange(s.start, s.stop) for s in slices])
        assert (covered == np.arange(d)).all()          # contiguous cover
        widths = [s.stop - s.start for s in slices]
        assert max(widths) - min(widths) <= 1           # within one column


def test_d_shard_slices_clamp_degenerate_sizes(cfg):
    assert d_shard_slices(cfg, 6, 0) == [slice(0, 6)]
    assert len(d_shard_slices(cfg, 3, 10)) == 3         # never empty shards


# ---------------------------------------------------------------------------
# Encode surfaces: bit-identical to the unsharded references
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("size", [1, 2, 3])
def test_encode_dataset_bit_identical(cfg, data, size):
    x, _ = data
    key = jax.random.PRNGKey(11)
    ref_shares, ref_ctx = encode.encode_dataset(cfg, key, x)
    with MasterGroup(cfg, size) as grp:
        shares, ctx = grp.encode_dataset(cfg, key, x)
    assert (np.asarray(shares) == np.asarray(ref_shares)).all()
    assert (np.asarray(ctx["xq"]) == np.asarray(ref_ctx["xq"])).all()
    assert ctx["m_padded"] == int(ref_ctx["m_padded"])


@pytest.mark.parametrize("size", [2, 3])
def test_encode_round_shares_bit_identical(cfg, size):
    key = jax.random.PRNGKey(5)
    w2 = jax.random.normal(jax.random.PRNGKey(6), (22, cfg.c))
    ref = engine.encode_round_shares(cfg, key, w2)
    with MasterGroup(cfg, size) as grp:
        out = grp.encode_round_shares(key, w2)
    assert out.shape == np.asarray(ref).shape           # (N, d, c, r)
    assert (np.asarray(out) == np.asarray(ref)).all()


@pytest.mark.parametrize("size", [2, 3])
def test_encode_round_shares_split_bit_identical(cfg, size):
    """The pipelined half-encode: group split == engine split == whole."""
    key = jax.random.PRNGKey(9)
    w2 = jax.random.normal(jax.random.PRNGKey(10), (22, cfg.c))
    kq, mask_shares = engine.round_mask_context(cfg, key, (22, cfg.c))
    ref = engine.encode_round_shares_split(cfg, kq, mask_shares, w2)
    whole = engine.encode_round_shares(cfg, key, w2)
    with MasterGroup(cfg, size) as grp:
        out = grp.encode_round_shares_split(kq, mask_shares, w2)
    assert (np.asarray(ref) == np.asarray(whole)).all()
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_t0_no_mask_encode_bit_identical(data):
    """T=0 drops the mask rows entirely — the sharded stack must handle
    the data-only branch too."""
    cfg0 = protocol.CPMLConfig(N=8, K=2, T=0, r=1)
    x, _ = data
    key = jax.random.PRNGKey(3)
    ref, _ = encode.encode_dataset(cfg0, key, x)
    with MasterGroup(cfg0, 2) as grp:
        out, _ = grp.encode_dataset(cfg0, key, x)
    assert (np.asarray(out) == np.asarray(ref)).all()


# ---------------------------------------------------------------------------
# Sharded streaming decode
# ---------------------------------------------------------------------------

def _fake_results(cfg, d, seed=0):
    rng = np.random.default_rng(seed)
    return {w: rng.integers(0, cfg.p, size=(d, cfg.c)).astype(np.int32)
            for w in range(cfg.N)}


@pytest.mark.parametrize("size", [2, 3])
def test_sharded_decoder_streams_on_plan_hit(cfg, size):
    d = 22
    results = _fake_results(cfg, d)
    order = np.arange(cfg.N)
    plan = decode.prefix_decode_plan(cfg, order)
    ref_dec = decode.StreamingDecoder(cfg, plan)
    with MasterGroup(cfg, size) as grp:
        dec = grp.make_decoder(plan, d)
        assert isinstance(dec, ShardedStreamingDecoder)
        for w in order[: cfg.threshold]:
            ref_dec.fold(w, results[w])
            dec.fold(w, results[w])
        parts = dec.finish(order)
        ref = ref_dec.finish(order)
        assert dec.streamed and ref_dec.streamed
        assert parts.shape == (cfg.K, d, cfg.c)
        assert (parts == np.asarray(ref)).all()
        # and both equal the one-shot batch decode over the observed order
        stacked = np.stack([results[w] for w in order[: cfg.threshold]])
        dmat = decode.make_decode_matrix(cfg, order)
        batch = decode.decode_parts(cfg, stacked, dmat)
        assert (parts == np.asarray(batch)).all()


def test_sharded_decoder_fallback_on_plan_miss_matches_batch(cfg):
    """Arrivals off the predicted subset: every shard falls back to the
    batch decode over the observed order, still bit-identical."""
    d = 22
    results = _fake_results(cfg, d, seed=1)
    plan = decode.prefix_decode_plan(cfg, np.arange(cfg.N))
    observed = np.array([7, 6, 5, 4, 3, 2, 1, 0])[: cfg.threshold]
    with MasterGroup(cfg, 2) as grp:
        dec = grp.make_decoder(plan, d)
        for w in observed:
            dec.fold(w, results[w])
        parts = dec.finish(observed)
        assert not dec.streamed
    stacked = np.stack([results[w] for w in observed])
    dmat = decode.make_decode_matrix(cfg, observed)
    batch = decode.decode_parts(cfg, stacked, dmat)
    assert (parts == np.asarray(batch)).all()


def test_group_stats_track_per_master_walls(cfg, data):
    x, _ = data
    with MasterGroup(cfg, 2) as grp:
        grp.encode_dataset(cfg, jax.random.PRNGKey(0), x)
        plan = decode.prefix_decode_plan(cfg, np.arange(cfg.N))
        dec = grp.make_decoder(plan, 22)
        results = _fake_results(cfg, 22)
        for w in range(cfg.threshold):
            dec.fold(w, results[w])
        dec.finish(np.arange(cfg.N))
        stats = grp.group_stats()
    assert stats["size"] == 2 and len(stats["per_master"]) == 2
    assert stats["encode_total_s"] > 0 and stats["decode_total_s"] > 0
    # the critical path is one master's wall: bounded by the serial total
    assert stats["critical_path_s"] <= (stats["encode_total_s"]
                                        + stats["decode_total_s"])
    assert stats["critical_path_s"] >= max(
        w["encode_s"] + w["decode_s"] for w in stats["per_master"]) * 0.999


def test_host_encode_matches_device_lagrange_for_both_primes(data):
    """The host int64 mod-p matmul against the device field.matmul for the
    24-bit P and the 30-bit P30 — the overflow-discipline regression."""
    from repro.core import lagrange, quantize
    x, _ = data
    for p in (field.P, field.P30):
        cfg_p = protocol.CPMLConfig(N=8, K=2, T=1, r=1, p=p)
        key = jax.random.PRNGKey(2)
        ref, _ = encode.encode_dataset(cfg_p, key, x)
        with MasterGroup(cfg_p, 3) as grp:
            out, _ = grp.encode_dataset(cfg_p, key, x)
        assert (np.asarray(out) == np.asarray(ref)).all()
