"""Elastic membership: epoch state machine + elastic-runner acceptance
(DESIGN.md §13).

Two layers.  The unit layer pins the ClusterMembership state machine
(epoch bumps, spare pool, scheduled joins, monitor coupling) and the
HeartbeatMonitor's stall credit.  The acceptance layer is the elastic
twin of tests/test_cluster.py's invariant: a run where a member DIES and
a spare replaces it, or a scheduled joiner enters mid-run, must stay
bit-identical to train_reference replaying the observed responder trace
on the spare-extended config — elasticity changes who computes, never
what is computed.
"""
import jax
import numpy as np
import pytest

from repro.cluster import (
    ClusterMembership,
    ClusterRunner,
    DeadWorkerLatency,
    DeterministicLatency,
    LognormalTailLatency,
)
from repro.core import protocol
from repro.data import synthetic
from repro.runtime.resilience import HeartbeatMonitor


@pytest.fixture(scope="module")
def binary_data():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=300, d=24)


# ---------------------------------------------------------------------------
# ClusterMembership state machine
# ---------------------------------------------------------------------------

def test_view_is_an_immutable_epoch_snapshot():
    ms = ClusterMembership(range(4), spares=[4, 5])
    v0 = ms.view()
    assert v0.epoch == 0
    assert v0.members == (0, 1, 2, 3)
    assert 2 in v0 and 4 not in v0 and len(v0) == 4
    ms.admit(4, round=3)
    # the old snapshot is untouched — the epoch fence contract
    assert v0.epoch == 0 and v0.members == (0, 1, 2, 3)
    v1 = ms.view()
    assert v1.epoch == 1 and v1.members == (0, 1, 2, 3, 4)


def test_spares_must_be_disjoint_from_members():
    with pytest.raises(AssertionError):
        ClusterMembership(range(4), spares=[3])


def test_schedule_join_is_idempotent_and_due_at_fence():
    ms = ClusterMembership(range(3), spares=[3, 4])
    ms.schedule_join(3, at_round=5)
    ms.schedule_join(3, at_round=9)          # duplicate request: ignored
    ms.schedule_join(1, at_round=0)          # already a member: ignored
    ms.schedule_join(4, at_round=2)
    assert ms.due_joins(1) == []
    assert ms.due_joins(2) == [4]
    assert ms.due_joins(7) == [3, 4]         # request order, both due
    ms.admit(4, round=2)
    assert ms.due_joins(7) == [3]            # admission clears the request


def test_take_spare_pops_lowest_until_dry():
    ms = ClusterMembership(range(2), spares=[5, 3])
    assert ms.spares == (3, 5)
    assert ms.take_spare() == 3
    assert ms.take_spare() == 5
    assert ms.take_spare() is None


def test_admit_and_leave_bump_epoch_and_drive_monitor():
    mon = HeartbeatMonitor(3, timeout_s=10.0, now=0.0)
    ms = ClusterMembership(range(3), monitor=mon, spares=[3])
    v = ms.admit(3, round=4, now=7.0)
    assert v.epoch == 1 and 3 in v
    assert ms.spares == ()
    assert 3 in mon.workers                  # monitor tracks the joiner
    assert mon.workers[3].last_heartbeat == 7.0
    v = ms.leave(1, round=6, now=9.0)
    assert v.epoch == 2 and 1 not in v
    assert 1 not in mon.workers              # retired slot untracked
    assert ms.departed == frozenset({1})
    # a heartbeat from the retired slot is liveness evidence for nobody
    mon.heartbeat(1, now=9.5)
    assert 1 not in mon.workers


def test_leave_then_spare_replacement_sequence():
    ms = ClusterMembership(range(4), spares=[4])
    ms.leave(2, round=3, now=1.0)
    spare = ms.take_spare()
    assert spare == 4
    v = ms.admit(spare, round=3, now=1.0)
    assert v.epoch == 2
    assert v.members == (0, 1, 3, 4)
    kinds = [(tr.kind, tr.worker, tr.epoch) for tr in ms.transitions]
    assert kinds == [("leave", 2, 1), ("join", 4, 2)]
    assert all(tr.round == 3 for tr in ms.transitions)


def test_double_admit_and_unknown_leave_are_caller_bugs():
    ms = ClusterMembership(range(2), spares=[2])
    ms.admit(2, round=0)
    with pytest.raises(AssertionError):
        ms.admit(2, round=1)
    with pytest.raises(AssertionError):
        ms.leave(7, round=1)


def test_departed_slot_may_rejoin_after_resilient_restore():
    ms = ClusterMembership(range(3))
    ms.leave(0, round=2, now=0.0)
    ms.schedule_join(0, at_round=5)
    assert ms.due_joins(5) == [0]
    v = ms.admit(0, round=5, now=3.0)
    assert 0 in v and ms.departed == frozenset()


# ---------------------------------------------------------------------------
# HeartbeatMonitor stall credit
# ---------------------------------------------------------------------------

def test_credit_stall_keeps_live_fleet_alive_through_barrier():
    """A master-side barrier (joiner provisioning, respawn) suspends the
    per-round acks that are the detector's only heartbeat source: credit
    shifts every previously-live worker past the silent window."""
    mon = HeartbeatMonitor(3, timeout_s=2.0, now=0.0)
    mon.heartbeat(0, now=1.0)
    mon.heartbeat(1, now=1.0)
    # a 5-second admission barrier: without credit everyone looks dead
    assert mon.is_dead(0, now=6.0)
    mon.credit_stall(5.0, now=6.0)
    assert not mon.is_dead(0, now=6.0)
    assert not mon.is_dead(1, now=6.0)
    assert mon.workers[0].last_heartbeat == pytest.approx(6.0)


def test_credit_stall_does_not_resurrect_the_already_dead():
    """A worker whose silence predates the stall was dead on its own
    merits — the credit must not mask a real failure."""
    mon = HeartbeatMonitor(2, timeout_s=1.0, now=0.0)
    mon.heartbeat(0, now=10.0)
    # worker 1 last heartbeated at 0.0: already past the timeout when the
    # stall began at t=10
    mon.credit_stall(3.0, now=13.0)
    assert not mon.is_dead(0, now=13.0)
    assert mon.is_dead(1, now=13.0)
    assert mon.workers[1].last_heartbeat == 0.0


def test_credit_stall_never_stamps_the_future():
    mon = HeartbeatMonitor(1, timeout_s=5.0, now=0.0)
    mon.heartbeat(0, now=4.0)
    mon.credit_stall(3.0, now=5.0)           # 4 + 3 would be t=7 > now
    assert mon.workers[0].last_heartbeat == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# Elastic ClusterRunner acceptance: bit-identity through transitions
# ---------------------------------------------------------------------------

def test_elastic_leave_with_spare_replacement_bit_identical(binary_data):
    """A member dies mid-run; the failure detector retires it at a round
    fence and the pre-provisioned spare slot is admitted as its permanent
    replacement.  The weights must equal train_reference on the
    spare-EXTENDED config replaying the observed trace — the consecutive
    evaluation points make shares 0..N-1 and every decode over them
    bit-identical to the fixed-N scheme."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)        # threshold 7
    lat = DeadWorkerLatency(DeterministicLatency(base=1.0, skew=0.1),
                            deaths={2: 3})
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat,
                           heartbeat_timeout_s=4.0, round_timeout_s=60.0,
                           spares=1)
    assert runner.cfg.N == 9                 # extended; threshold unchanged
    assert runner.cfg.threshold == cfg.threshold
    w = runner.run(16)

    ms = runner.membership
    kinds = [(tr.kind, tr.worker) for tr in ms.transitions]
    assert ("leave", 2) in kinds and ("join", 8) in kinds
    assert ms.epoch == 2 and ms.spares == ()
    assert 2 not in ms.view() and 8 in ms.view()
    # after the transition round the retired slot is NEVER dispatched again
    # and the spare slot answers in its place
    fence = max(tr.round for tr in ms.transitions)
    for t, rec in runner.records.items():
        if t >= fence:
            assert 2 not in set(map(int, rec.dispatched))
            assert 8 in set(map(int, rec.dispatched))
    stats = runner.wait_stats()
    assert stats["membership"]["leaves"] == 1.0
    assert stats["membership"]["joins"] == 1.0

    w_ref, _ = protocol.train_reference(runner.cfg, jax.random.PRNGKey(7),
                                        x, y, iters=16,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_elastic_scheduled_join_bit_identical(binary_data):
    """A joiner scheduled for round 3 (the sim twin of a late worker's
    Join frame): rounds before the fence run on the base fleet, rounds
    after include the spare slot — all bit-identical to the reference."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    lat = LognormalTailLatency(seed=3, tail_prob=0.3, tail_scale=25.0)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat,
                           spares=1, join_schedule={8: 3})
    w = runner.run(12)

    ms = runner.membership
    assert ms.epoch == 1
    assert [(tr.kind, tr.worker, tr.round) for tr in ms.transitions] == [
        ("join", 8, 3)]
    for t, rec in runner.records.items():
        assert (8 in set(map(int, rec.dispatched))) == (t >= 3)
    w_ref, _ = protocol.train_reference(runner.cfg, jax.random.PRNGKey(7),
                                        x, y, iters=12,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_non_elastic_runner_is_bit_identical_to_fixed_fleet(binary_data):
    """spares=0 and no join schedule keep the historical fixed-fleet
    behavior EXACTLY: epoch parked at 0, no transitions, same weights as a
    pre-elastic run (the reference on the unextended config)."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    lat = LognormalTailLatency(seed=3, tail_prob=0.3, tail_scale=25.0)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat)
    w = runner.run(12)
    assert not runner.elastic
    assert runner.membership.epoch == 0
    assert runner.membership.transitions == []
    assert runner.cfg.N == 8
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=12,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_spare_extension_leaves_base_shares_bit_identical():
    """The coding-scheme fact elasticity rests on: CodingScheme points are
    consecutive, so the N+spares encode matrix's first N columns — hence
    shares 0..N-1 — equal the fixed-N scheme's exactly."""
    import dataclasses
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    ext = dataclasses.replace(cfg, N=9)
    u = np.asarray(cfg.scheme.encode_matrix)
    u_ext = np.asarray(ext.scheme.encode_matrix)
    assert u_ext.shape[1] == u.shape[1] + 1
    assert (u_ext[:, : u.shape[1]] == u).all()
