"""End-to-end CodedPrivateML protocol tests (paper Alg. 1, Thm. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field, protocol, sigmoid_poly, quantize
from repro.data import synthetic


def small_cfg(**kw):
    base = dict(N=8, K=2, T=1, r=1, backend="vmap")
    base.update(kw)
    return protocol.CPMLConfig(**base)


@pytest.fixture(scope="module")
def dataset():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=600, d=50)


def test_threshold_enforced():
    with pytest.raises(AssertionError):
        protocol.CPMLConfig(N=6, K=2, T=1, r=1)   # needs (3)(2)+1 = 7


def test_gradient_matches_cleartext(dataset):
    """One coded step == the same update computed in the clear (on the
    quantized data with the polynomial surrogate), up to quantization noise
    in the W̄ draw (eliminated by fixing the key)."""
    x, y = dataset
    cfg = small_cfg()
    key = jax.random.PRNGKey(3)
    state = protocol.setup(cfg, key, x, y)
    w0 = jnp.zeros(x.shape[1])
    eta = 0.5
    new = protocol.step(cfg, jax.random.PRNGKey(9), state, eta)
    # cleartext replica: same quantized weights, same surrogate
    kq, km = jax.random.split(jax.random.PRNGKey(9))
    kq2, _ = jax.random.split(kq)
    wbar = quantize.quantize_weights(kq2, w0, cfg.lw, cfg.r, cfg.p)
    coeffs = sigmoid_poly.fit_sigmoid(cfg.r)
    gb = sigmoid_poly.gbar_real(state.xq_real, wbar, coeffs, cfg.lx, cfg.lw)
    grad = (state.xq_real.T @ gb - state.xty) / state.m
    want = w0 - eta * grad
    got = new.w
    err = float(jnp.abs(got - want).max())
    # residual = coefficient quantization of c_i (lc bits) only
    assert err < 2e-2, err


def test_convergence_matches_uncoded(dataset):
    x, y = dataset
    cfg = small_cfg()
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=10,
                             eval_every=10)
    state = protocol.setup(cfg, jax.random.PRNGKey(7), x, y)
    eta = protocol.lipschitz_eta(state.xq_real)
    w2 = jnp.zeros(x.shape[1])
    xq, yy = state.xq_real[:600], y
    for _ in range(10):
        w2 = w2 - eta * (xq.T @ (protocol.sigmoid(xq @ w2) - yy)) / 600
    l_coded, _ = protocol.loss_and_accuracy(w, xq, yy)
    l_clear, _ = protocol.loss_and_accuracy(w2, xq, yy)
    # "comparable convergence" (paper Fig. 4): surrogate slope differs from
    # the true sigmoid derivative, so a small trajectory gap is expected.
    assert abs(float(l_coded) - float(l_clear)) < 2e-2
    assert hist[-1]["loss"] < 0.69   # improved from ln 2


@pytest.mark.parametrize("pattern", [
    np.arange(7),                      # exactly threshold, drop worker 7
    np.array([7, 6, 5, 4, 3, 2, 1]),   # reversed order, drop worker 0
    np.array([0, 2, 3, 5, 6, 7, 1]),   # shuffled
])
def test_straggler_tolerance(dataset, pattern):
    """K=2,T=1,r=1 -> threshold 7 of N=8: any 7 workers give the SAME w."""
    x, y = dataset
    cfg = small_cfg()
    state0 = protocol.setup(cfg, jax.random.PRNGKey(0), x, y)
    full = protocol.step(cfg, jax.random.PRNGKey(1), state0, 0.5)
    part = protocol.step(cfg, jax.random.PRNGKey(1), state0, 0.5,
                         survivors=pattern)
    assert np.allclose(np.asarray(full.w), np.asarray(part.w), atol=1e-6)


def test_too_few_survivors(dataset):
    x, y = dataset
    cfg = small_cfg()
    state = protocol.setup(cfg, jax.random.PRNGKey(0), x, y)
    with pytest.raises(AssertionError):
        protocol.step(cfg, jax.random.PRNGKey(1), state, 0.5,
                      survivors=np.arange(6))


def test_kernel_path_equals_jnp_path(dataset):
    x, y = dataset
    c1 = small_cfg(use_kernel=False)
    c2 = small_cfg(use_kernel=True)
    s1 = protocol.setup(c1, jax.random.PRNGKey(0), x, y)
    s2 = protocol.setup(c2, jax.random.PRNGKey(0), x, y)
    w1 = protocol.step(c1, jax.random.PRNGKey(1), s1, 0.5).w
    w2 = protocol.step(c2, jax.random.PRNGKey(1), s2, 0.5).w
    assert np.allclose(np.asarray(w1), np.asarray(w2), atol=1e-7)


def test_r2_polynomial(dataset):
    """Degree-2 surrogate: threshold (5)(K+T-1)+1; still converges.

    r=2 at the paper's 24-bit prime WRAPS (headroom < 0) — documented
    overflow trade-off (§3.1); the P30 extension restores correctness."""
    x, y = dataset
    cfg24 = protocol.CPMLConfig(N=11, K=2, T=1, r=2)
    assert cfg24.headroom_bits(x_max=1.0, m=600) < 0     # would overflow
    cfg = protocol.CPMLConfig(N=11, K=2, T=1, r=2, p=field.P30)
    assert cfg.headroom_bits(x_max=1.0, m=600) > 0
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=8,
                             eval_every=8)
    assert hist[-1]["loss"] < 0.69


def test_extended_prime(dataset):
    """P30 run: more headroom, same convergence."""
    x, y = dataset
    cfg = small_cfg(p=field.P30, lc=10)
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=8,
                             eval_every=8)
    assert hist[-1]["loss"] < 0.69
