"""Stochastic quantization (paper §3.1): unbiasedness + roundtrip bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field, quantize


def test_deterministic_roundtrip(key):
    x = jax.random.uniform(key, (64, 8), minval=-1.0, maxval=1.0)
    for lx in (2, 4, 8):
        q = quantize.quantize_data(x, lx)
        back = quantize.dequantize(q, lx)
        assert float(jnp.abs(back - x).max()) <= 2.0 ** (-lx - 1) + 1e-6


def test_stochastic_unbiased(key):
    """E[Round_stoc(x)] = x — the core of Lemma 1."""
    w = jnp.array([0.3, -0.7, 1.25, -2.6], jnp.float32)
    lw = 2
    reps = 4000
    qs = quantize.quantize_weights(key, jnp.tile(w, (reps, 1)).T.reshape(-1),
                                   lw, 1)[..., 0]
    back = quantize.dequantize(qs, lw).reshape(4, reps)
    est = back.mean(axis=1)
    assert np.allclose(np.asarray(est), np.asarray(w), atol=4e-3)


def test_independent_quantizations_differ(key):
    w = jax.random.uniform(key, (256,))
    q = quantize.quantize_weights(key, w, 4, 2)
    assert q.shape == (256, 2)
    assert (np.asarray(q[:, 0]) != np.asarray(q[:, 1])).any()


def test_negative_embedding(key):
    x = jnp.array([-3.7, -0.1, 0.0, 2.2])
    q = quantize.quantize_data(x, 2)
    assert (np.asarray(q) >= 0).all() and (np.asarray(q) < field.P).all()
    assert np.allclose(np.asarray(quantize.dequantize(q, 2)),
                       [-3.75, 0.0, 0.0, 2.25])


def test_gradient_scale():
    assert quantize.gradient_scale(lx=2, lw=4, r=1) == 2 + 6
    assert quantize.gradient_scale(lx=2, lw=4, r=2) == 2 + 12


def test_required_prime_bits():
    # paper: p >= 2^(lx+1) max|X| + 1 = 9 for lx=2, |X|<=1 -> 4 bits
    assert quantize.required_prime_bits(1.0, 2) == 4
    assert quantize.required_prime_bits(255.0, 8) == 17
