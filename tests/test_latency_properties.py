"""Latency-model order-independence property (DESIGN.md §7).

The checkpoint-replay invariant the cluster runtime leans on: sampling
``(round, worker)`` pairs in ANY permutation — with any interleaving
history — yields identical values for all four models, because each draw
derives a private RNG stream from ``(seed, round, worker)``.  DESIGN.md §7
asserts this; tests/test_cluster.py pins one fixed forward/reverse pair for
two models; this module pins the full property for all four, under
arbitrary hypothesis-chosen permutations.  Skips cleanly when hypothesis is
absent (DESIGN.md §8).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.cluster.latency import LATENCY_MODELS, make_latency  # noqa: E402

pairs = st.lists(
    st.tuples(st.integers(0, 40), st.integers(0, 7)),
    min_size=1, max_size=40, unique=True,
)


@settings(max_examples=60, deadline=None)
@given(pairs=pairs, perm=st.randoms(use_true_random=False),
       seed=st.integers(0, 2 ** 16))
@pytest.mark.parametrize("name", LATENCY_MODELS)
def test_sampling_is_order_independent(name, pairs, perm, seed):
    a = make_latency(name, seed=seed)
    b = make_latency(name, seed=seed)
    forward = {pw: a.sample(*pw) for pw in pairs}
    shuffled = list(pairs)
    perm.shuffle(shuffled)
    assert {pw: b.sample(*pw) for pw in shuffled} == forward
    # and re-sampling the SAME instance again (replay after arbitrary
    # history) still agrees — no hidden stream state
    assert {pw: a.sample(*pw) for pw in shuffled} == forward


@settings(max_examples=30, deadline=None)
@given(pairs=pairs, seed=st.integers(0, 2 ** 16),
       deaths=st.dictionaries(st.integers(0, 7), st.integers(0, 40),
                              max_size=3))
def test_dead_worker_wrapper_preserves_order_independence(pairs, seed, deaths):
    a = make_latency("dead", seed=seed, deaths=deaths)
    b = make_latency("dead", seed=seed, deaths=deaths)
    forward = {pw: a.sample(*pw) for pw in pairs}
    assert {pw: b.sample(*pw) for pw in reversed(pairs)} == forward
