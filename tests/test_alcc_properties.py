"""ALCC encode∘decode error-bound properties (DESIGN.md §14).

The float engine's whole correctness story is an ERROR MODEL, not exact
recovery: decode error must stay inside ``error_budget`` — the condition
number of the solved system times the float32 quantum times the largest
evaluation magnitude (which the Gaussian masks inflate by O(sigma)).
These properties pin that bound over hypothesis-chosen (K, T, sigma,
beta_scale) combinations, including the ill-conditioned large-N /
high-degree regime where the square solve exceeds ``cond_max`` and the
overdetermined pseudo-inverse fallback takes over.  Skips cleanly when
hypothesis is absent (DESIGN.md §8).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.core import alcc  # noqa: E402

# budget is a first-order bound (cond * eps32 * max|h|); the solve can
# shuffle elementwise roundoff by a small constant factor on unlucky draws
BUDGET_SLACK = 10.0


def _scheme_or_skip(N, K, T, **kw):
    """Build a scheme, discarding draws whose Chebyshev sets collide at 0
    (both orders odd) — the constructor refuses those by design."""
    s = alcc.AnalogScheme(N=N, K=K, T=T, **kw)
    try:
        s.betas
    except AssertionError:
        assume(False)
    return s


@settings(max_examples=80, deadline=None)
@given(K=st.integers(1, 4), T=st.integers(0, 3), extra=st.integers(1, 4),
       sigma=st.floats(0.0, 10.0),
       beta_scale=st.floats(0.2, 0.8),
       seed=st.integers(0, 2 ** 16))
def test_decode_error_within_budget(K, T, extra, sigma, beta_scale, seed):
    """Identity worker (deg 1), float32 evaluations: the decoded parts err
    by at most BUDGET_SLACK * error_budget, for ANY (K, T, sigma, spread).
    """
    N = K + T + extra
    s = _scheme_or_skip(N, K, T, sigma=sigma, beta_scale=beta_scale)
    rng = np.random.default_rng(seed)
    parts = rng.normal(size=(K, 6))
    masks = rng.normal(size=(T, 6)) * sigma
    results = alcc.encode(s, parts, masks).astype(np.float32)
    dec, info = s.decode(results, np.arange(N), deg_f=1)
    err = float(np.max(np.abs(dec - parts)))
    assert err <= max(BUDGET_SLACK * info["abs_err_budget"], 1e-10)


@settings(max_examples=60, deadline=None)
@given(K=st.integers(1, 3), T=st.integers(1, 3), extra=st.integers(1, 3),
       sigma=st.floats(0.0, 100.0), seed=st.integers(0, 2 ** 16))
def test_masks_cancel_in_float64(K, T, extra, sigma, seed):
    """In (near-)exact arithmetic the masks cancel at the data betas no
    matter how large sigma is: float64 end-to-end decode error stays at
    solver-roundoff scale, NOT at O(sigma)."""
    N = K + T + extra
    s = _scheme_or_skip(N, K, T, sigma=sigma)
    rng = np.random.default_rng(seed)
    parts = rng.normal(size=(K, 5))
    masks = rng.normal(size=(T, 5)) * sigma
    shares = alcc.encode(s, parts, masks)          # float64 throughout
    dec, info = s.decode(shares, np.arange(N), deg_f=1)
    err = float(np.max(np.abs(dec - parts)))
    # float64 eps replaces the budget's eps32: ~1e-16 * cond * magnitude
    f64_budget = alcc.error_budget(info["cond"],
                                   float(np.max(np.abs(shares))),
                                   eps=float(np.finfo(np.float64).eps))
    assert err <= max(BUDGET_SLACK * f64_budget, 1e-12)


@settings(max_examples=40, deadline=None)
@given(K=st.integers(2, 4), T=st.integers(1, 3), extra=st.integers(2, 5),
       seed=st.integers(0, 2 ** 16))
def test_fallback_regime_still_reconstructs(K, T, extra, seed):
    """Ill-conditioned regime: deg-2 workers push the product-polynomial
    degree to 2(K+T-1); with ``cond_max`` forced to 1 the square solve is
    always "too ill-conditioned" and the pinv fallback over ALL responders
    must still reconstruct h(beta_k) = parts_k^2 within its own budget."""
    N = 2 * (K + T - 1) + 1 + extra
    s = _scheme_or_skip(N, K, T, cond_max=1.0)
    rng = np.random.default_rng(seed)
    parts = rng.normal(size=(K, 4))
    masks = rng.normal(size=(T, 4))
    shares = alcc.encode(s, parts, masks)
    dec, info = s.decode(shares ** 2, np.arange(N), deg_f=2)
    assert info["fallback"] and info["rows"] == N
    err = float(np.max(np.abs(dec - parts ** 2)))
    f64_budget = alcc.error_budget(info["cond"],
                                   float(np.max(np.abs(shares ** 2))),
                                   eps=float(np.finfo(np.float64).eps))
    assert err <= max(BUDGET_SLACK * f64_budget, 1e-10)


@settings(max_examples=30, deadline=None)
@given(K=st.integers(1, 3), T=st.integers(0, 2), extra=st.integers(1, 3),
       seed=st.integers(0, 2 ** 16), deg=st.integers(1, 2))
def test_decode_subset_independence(K, T, extra, seed, deg):
    """Any two survivor sets of the same size decode to values that agree
    within the sum of their budgets — no privileged worker subset."""
    need = alcc.degree_threshold(K, T, deg)
    N = need + extra
    s = _scheme_or_skip(N, K, T)
    rng = np.random.default_rng(seed)
    parts = rng.normal(size=(K, 4))
    masks = rng.normal(size=(T, 4))
    shares = alcc.encode(s, parts, masks) ** deg
    sa = np.sort(rng.permutation(N)[:need])
    sb = np.sort(rng.permutation(N)[:need])
    da, ia = s.decode(shares[sa], sa, deg_f=deg)
    db, ib = s.decode(shares[sb], sb, deg_f=deg)
    f64 = float(np.finfo(np.float64).eps)
    tol = BUDGET_SLACK * (
        alcc.error_budget(ia["cond"], float(np.max(np.abs(shares))), f64)
        + alcc.error_budget(ib["cond"], float(np.max(np.abs(shares))), f64))
    assert float(np.max(np.abs(da - db))) <= max(tol, 1e-10)
