"""Socket cluster acceptance (DESIGN.md §7, socket backend).

The load-bearing test mirrors tests/test_cluster.py's invariant on REAL
infrastructure: N worker processes, coded shares shipped as wire frames
over localhost TCP, one worker killed mid-run — and the trained weights
must still be bit-identical to ``engine.train_reference`` replaying the
observed responder trace.  The runtime layer changes when and where rounds
execute, never what they compute.

All tests here spawn subprocesses and are marked ``slow`` (DESIGN.md §8).
"""
import math
import time

import jax
import numpy as np
import pytest

from repro.cluster import ClusterRunner
from repro.core import protocol
from repro.data import synthetic
from repro.launch.cpml_cluster import local_socket_cluster

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def binary_data():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=256, d=20)


def _run_socket(cfg, x, y, *, iters, die_at_round=None, sleep_s=None,
                collect_all=False, heartbeat_timeout_s=math.inf,
                seed=7, pipeline="off"):
    with local_socket_cluster(cfg.N, die_at_round=die_at_round,
                              sleep_s=sleep_s) as tr:
        runner = ClusterRunner(cfg, jax.random.PRNGKey(seed), x, y,
                               latency=None, transport=tr,
                               round_timeout_s=120.0,
                               heartbeat_timeout_s=heartbeat_timeout_s,
                               collect_all=collect_all,
                               pipeline=pipeline)
        runner.provision()
        w = runner.run(iters)
        runner.shutdown_workers()
    return runner, w


def test_socket_bit_identical_with_worker_killed_mid_run(binary_data):
    """THE acceptance criterion: N=8 K=2 T=1, >= 10 rounds over real TCP,
    one worker crashing mid-run — weights bit-identical to train_reference
    replaying the observed responder trace."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)        # threshold 7
    runner, w = _run_socket(cfg, x, y, iters=10, die_at_round={5: 4})

    assert len(runner.records) == 10
    # the killed worker vanishes from every decode after its crash round
    for t, rec in runner.records.items():
        if t >= 4:
            assert 5 not in set(map(int, rec.survivors))
    # post-kill rounds ran at EXACTLY the threshold: the erasure decode is
    # what rode through the death, no retry, no restart
    assert runner.records[9].n_responders == cfg.threshold

    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=10,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_socket_bit_identical_minibatch_multiclass():
    """Mini-batch + multi-class over the wire: the shipped batch indices and
    weight shares must reproduce make_schedule's derivations exactly."""
    x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(42), m=256,
                                           d=20, c=3)
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1, c=3, batch_rows=16)
    runner, w = _run_socket(cfg, x, y, iters=6)
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=6,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_socket_first_T_beats_wait_all_under_real_straggler(binary_data):
    """A worker that really sleeps before replying: collect_all observes
    both completion times per round, and waiting for the fastest threshold
    must beat waiting for everyone — the paper's effect on a wall clock."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)        # threshold 4
    sleep = 0.4
    runner, _ = _run_socket(cfg, x, y, iters=5, sleep_s={2: sleep},
                            collect_all=True)
    stats = runner.wait_stats()
    assert math.isfinite(stats["wait_all"]["mean"])
    assert stats["coded_T"]["mean"] < stats["wait_all"]["mean"]
    # structural, load-robust claims for the steady-state rounds (round 0 is
    # jit warmup: compile time can dwarf the sleep): the sleeper is the LAST
    # arrival of every round, never decoded from, and waiting for it always
    # costs extra.  (Magnitude is deliberately not asserted — under CPU
    # contention the fast workers' compute eats into the nominal 0.4s gap.)
    for t, rec in runner.records.items():
        if t == 0:
            continue
        assert 2 not in set(map(int, rec.survivors))
        assert rec.all_wait_s > rec.coded_wait_s
        assert int(runner.traces[t].responders[-1]) == 2


def test_socket_pipelined_bit_identical_with_dead_worker(binary_data):
    """Pipelined-vs-sequential bit-identity through a REAL mid-run crash
    (DESIGN.md §9): the full pipeline (prefetch thread + streaming decode)
    over live TCP with a worker dying at round 4 must still equal
    train_reference on the observed trace — the sequential twin of this
    run is test_socket_bit_identical_with_worker_killed_mid_run, pinned to
    the same oracle."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)        # threshold 7
    runner, w = _run_socket(cfg, x, y, iters=10, die_at_round={5: 4},
                            pipeline="full")
    assert len(runner.records) == 10
    for t, rec in runner.records.items():
        if t >= 4:
            assert 5 not in set(map(int, rec.survivors))
        assert rec.prefetched                     # every round used the
                                                  # prefetched W-independent
                                                  # context
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=10,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_socket_pipelined_bit_identical_with_real_straggler(binary_data):
    """Full pipeline vs a worker process that REALLY sleeps: the stable
    fast subset makes the streaming prediction hit, the sleeper never
    enters a decode, and the weights stay bit-identical to the
    reference."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)        # threshold 4
    runner, w = _run_socket(cfg, x, y, iters=8, sleep_s={2: 0.4},
                            pipeline="full")
    stats = runner.wait_stats()
    for t, rec in runner.records.items():
        if t >= 1:                                # round 0 is jit warmup
            assert 2 not in set(map(int, rec.survivors))
    # with the sleeper pinned outside the fast set, the predicted subset
    # repeats and the incremental fold actually fires (round 0 has no
    # prediction; round 1's plan may lag in the prefetch thread)
    assert stats["rounds"]["streamed"] >= 4.0
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=8,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_socket_pipelined_minibatch_ships_next_batch(binary_data):
    """Mini-batch + pipeline over the wire: the master ships round t+1's
    batch indices ahead (worker pre-slices its coded sub-batch) and the
    result must still reproduce make_schedule's derivations exactly."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1, batch_rows=16)
    runner, w = _run_socket(cfg, x, y, iters=6, pipeline="full")
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=6,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_socket_heartbeats_feed_monitor_on_wall_clock(binary_data):
    """Real heartbeats land with wall-clock stamps; a killed worker's
    heartbeat trail goes cold while survivors stay fresh — the signal
    heartbeat-driven dispatch exclusion keys on."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)
    runner, _ = _run_socket(cfg, x, y, iters=6, die_at_round={0: 2},
                            heartbeat_timeout_s=3600.0)
    now = time.monotonic()
    dead = runner.monitor.workers[0]
    alive = [runner.monitor.workers[i] for i in range(1, 5)]
    # survivors heartbeated within the run's last rounds; the dead worker
    # stopped at its crash
    assert all(a.last_heartbeat > dead.last_heartbeat for a in alive)
    assert all(now - a.last_heartbeat < 120.0 for a in alive)
    # the wall-clock _alive filter drops exactly the cold worker under a
    # timeout between "since the crash" and "since the survivors' last ack"
    # (computed from the OBSERVED stalenesses: under CPU contention the
    # teardown overhead can rival the post-death round span, so a fixed
    # fraction of the dead worker's staleness may undershoot the living)
    stale_s = now - dead.last_heartbeat
    alive_stale_s = max(now - a.last_heartbeat for a in alive)
    assert alive_stale_s < stale_s
    runner.monitor.timeout_s = (alive_stale_s + stale_s) / 2
    assert 0 not in set(map(int, runner._alive(now)))
    assert set(map(int, runner._alive(now))) == {1, 2, 3, 4}


def test_socket_resilient_restore_respawns_dead_workers(binary_data):
    """Satellite regression for the resilient-restore path over REAL TCP:
    two workers die in the same round (below the decode threshold — coded
    tolerance alone cannot ride through), the starved round trips a
    checkpoint restore, and the ``respawn`` hook spawns replacement
    processes for the dead slots; the runner reprovisions them over the
    wire and the replay completes — bit-identical to the reference on the
    observed responder trace."""
    import tempfile

    from repro.checkpoint.manager import CheckpointManager
    from repro.cluster.messages import worker_endpoint
    from repro.launch.cpml_cluster import _worker_env, spawn_worker

    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)        # threshold 7
    env = _worker_env()
    with local_socket_cluster(cfg.N, die_at_round={0: 4, 1: 4}) as tr:
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                               latency=None, transport=tr,
                               round_timeout_s=6.0)
        runner.provision()

        def respawn(worker, step):
            # fresh process for the dead slot; reaped with the others via
            # the tr.procs list the context manager owns
            tr.procs.append(spawn_worker(tr.port, worker, env=env))
            tr.wait_for_endpoints([worker_endpoint(worker)], timeout_s=60.0)

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_write=False)
            w = runner.run_resilient(10, mgr, checkpoint_every=2,
                                     respawn=respawn)
        runner.shutdown_workers()

    assert runner.restarts == 1
    assert len(runner.records) == 10
    # the replacements actually answered: post-restore rounds decode at the
    # full threshold again
    assert runner.records[9].n_responders >= cfg.threshold
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=10,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_socket_elastic_kill_join_sharded_masters_bit_identical():
    """THE elastic acceptance over real TCP (DESIGN.md §13): one worker
    killed mid-run (heartbeat death -> LEAVE at a fence), one late worker
    admitted from the spare evaluation point (Join frame -> JOIN at its
    fence), the master role sharded S=2 over d — and the weights must be
    bit-identical to train_reference on the spare-extended config replaying
    the observed responder trace."""
    x, y = synthetic.mnist_like(jax.random.PRNGKey(42), m=400, d=32)
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)        # threshold 7
    with local_socket_cluster(cfg.N, die_at_round={2: 2},
                              join_at_round={8: 4}) as tr:
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                               latency=None, transport=tr,
                               round_timeout_s=120.0,
                               heartbeat_timeout_s=0.5,
                               spares=1, masters=2)
        runner.provision()
        w = runner.run(60)
        runner.shutdown_workers()

    assert runner.cfg.N == 9                 # spare-extended config
    ms = runner.membership
    kinds = {(tr_.kind, tr_.worker) for tr_ in ms.transitions}
    assert ("join", 8) in kinds, "the late worker was never admitted"
    assert ("leave", 2) in kinds, "the killed worker was never retired"
    assert ms.epoch == len(ms.transitions) >= 2
    assert 2 not in ms.view() and 8 in ms.view()
    # the joiner is dispatched from its fence on; the dead slot never again
    join_round = next(t.round for t in ms.transitions if t.kind == "join")
    leave_round = next(t.round for t in ms.transitions if t.kind == "leave")
    for t, rec in runner.records.items():
        if t >= join_round:
            assert 8 in set(map(int, rec.dispatched))
        if t >= leave_round:
            assert 2 not in set(map(int, rec.dispatched))
    # sharded masters actually ran and accounted per-master wall clocks
    stats = runner.wait_stats()
    assert stats["masters"]["size"] == 2
    assert stats["masters"]["critical_path_s"] > 0
    assert stats["membership"]["joins"] >= 1.0
    assert stats["membership"]["leaves"] >= 1.0

    w_ref, _ = protocol.train_reference(runner.cfg, jax.random.PRNGKey(7),
                                        x, y, iters=60,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()
