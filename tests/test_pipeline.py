"""Pipelined round engine acceptance (DESIGN.md §9).

The load-bearing invariants:

  * ENCODE SPLIT — the W-independent half (key split + fresh masks + their
    encoded contribution) composed with the W-dependent half (quantize +
    data-row encode + addmod) is bit-identical to the one-shot
    encode_weights on the same round key, for every (K, T, r, c) shape.
  * STREAMING DECODE — folding shares into the Lagrange reconstruction as
    they arrive equals the batch decode at exactly the threshold for EVERY
    responder-subset prefix, on hit (any arrival order of the predicted
    subset) and on miss (fallback).
  * PIPELINE MODES — ClusterRunner under every ``--pipeline`` mode stays
    bit-identical to train_reference replaying the observed trace, and all
    modes produce identical weights/traces (order-independent latencies),
    including through a mid-run dead worker.
  * TIMING MODEL — the scheduler charges encode/decode components to the
    simulated clock separately and records them next to t_first_R.
"""
import itertools
import math
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.cluster import (
    ClusterRunner,
    DeadWorkerLatency,
    DeterministicLatency,
    EventScheduler,
    LognormalTailLatency,
    RoundContext,
    RoundPrefetcher,
)
from repro.core import protocol
from repro.core.protocol import decode, encode, engine
from repro.data import synthetic

PIPELINE_MODES = ("off", "prefetch", "streaming", "full")


@pytest.fixture(scope="module")
def binary_data():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=240, d=20)


# ---------------------------------------------------------------------------
# Encode split: W-independent + W-dependent halves == one-shot encode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,K,T,r,c", [
    (8, 2, 1, 1, 1),     # the paper's shape
    (10, 2, 2, 1, 3),    # more masks + multi-class heads
    (8, 3, 0, 1, 2),     # T=0: the mask half contributes zeros
    (8, 1, 1, 2, 1),     # degree-2 surrogate (r quantization draws)
])
def test_encode_split_bit_identical(N, K, T, r, c):
    cfg = protocol.CPMLConfig(N=N, K=K, T=T, r=r, c=c)
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(jax.random.PRNGKey(1), (13, c))
    full = encode.encode_weights(cfg, key, w)
    kq, mask_shares = encode.weight_mask_shares(cfg, key, w.shape)
    split = encode.encode_weights_finish(cfg, kq, mask_shares, w)
    assert (np.asarray(full) == np.asarray(split)).all()


def test_round_mask_context_matches_encode_round_shares():
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=2)
    key = engine.round_key(jax.random.PRNGKey(7), 5)
    w2 = jax.random.normal(jax.random.PRNGKey(2), (9, 2))
    a = engine.encode_round_shares(cfg, key, w2)
    kq, mask_shares = engine.round_mask_context(cfg, key, w2.shape)
    b = engine.encode_round_shares_split(cfg, kq, mask_shares, w2)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_encode_mask_shares_are_w_independent():
    """The same key yields the same mask context regardless of when (or on
    which thread) it is computed — the property the prefetcher rests on."""
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    key = engine.round_key(jax.random.PRNGKey(0), 3)
    kq1, ms1 = engine.round_mask_context(cfg, key, (5, 1))
    out = {}

    def worker():
        out["ctx"] = engine.round_mask_context(cfg, key, (5, 1))

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    kq2, ms2 = out["ctx"]
    assert (np.asarray(kq1) == np.asarray(kq2)).all()
    assert (np.asarray(ms1) == np.asarray(ms2)).all()


# ---------------------------------------------------------------------------
# Streaming decode == batch decode for every responder-subset prefix
# ---------------------------------------------------------------------------

def test_streaming_equals_batch_for_every_subset_prefix():
    """REGRESSION (the satellite invariant): streaming decode at exactly
    the threshold equals the batch decode for EVERY responder-subset
    prefix — all P(5, 4) = 120 arrival orders, hit and miss paths."""
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)        # threshold 4
    R = cfg.threshold
    rng = np.random.default_rng(0)
    H = rng.integers(0, cfg.p, (cfg.N, 6, 2)).astype(np.int32)
    hits = 0
    for perm in itertools.permutations(range(cfg.N), R):
        order = np.asarray(perm)
        dmat = protocol.make_decode_matrix(cfg, order)
        batch = np.asarray(decode.decode_parts(
            cfg, jnp.asarray(H[order, :, :]), dmat))
        # hit path: prediction is the same SUBSET in a different order
        plan = decode.prefix_decode_plan(cfg, np.asarray(sorted(perm)))
        sd = decode.StreamingDecoder(cfg, plan)
        for w in order:
            sd.fold(w, H[w])
        assert (sd.finish(order) == batch).all()
        hits += sd.streamed
        # miss path: prediction names a different subset -> exact fallback
        other = np.asarray([w for w in range(cfg.N) if w != perm[0]])
        sd2 = decode.StreamingDecoder(cfg, decode.prefix_decode_plan(
            cfg, other))
        for w in order:
            sd2.fold(w, H[w])
        assert (sd2.finish(order) == batch).all() and not sd2.streamed
        # no-plan path
        sd3 = decode.StreamingDecoder(cfg, None)
        for w in order:
            sd3.fold(w, H[w])
        assert (sd3.finish(order) == batch).all() and not sd3.streamed
    assert hits == 120     # any arrival order of the predicted subset hits


def test_streaming_ignores_arrivals_beyond_threshold():
    """collect_all keeps folding arrivals past the threshold; the decoder
    must not let them corrupt the reconstruction."""
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)
    R = cfg.threshold
    rng = np.random.default_rng(1)
    H = rng.integers(0, cfg.p, (cfg.N, 4, 1)).astype(np.int32)
    arrivals = [3, 0, 4, 1, 2]                 # all five respond
    order = np.asarray(arrivals[:R])
    plan = decode.prefix_decode_plan(cfg, np.asarray(arrivals))
    sd = decode.StreamingDecoder(cfg, plan)
    for w in arrivals:
        sd.fold(w, H[w])
    batch = np.asarray(decode.decode_parts(
        cfg, jnp.asarray(H[order, :, :]),
        protocol.make_decode_matrix(cfg, order)))
    assert sd.streamed is False                # finish() not called yet
    assert (sd.finish(order) == batch).all() and sd.streamed


def test_prefix_plan_requires_full_threshold():
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)
    assert decode.prefix_decode_plan(cfg, None) is None
    assert decode.prefix_decode_plan(cfg, np.array([1, 2])) is None


# ---------------------------------------------------------------------------
# Scheduler: encode/decode components charged + recorded separately
# ---------------------------------------------------------------------------

def test_scheduler_charges_encode_decode_components():
    sched = EventScheduler(4, DeterministicLatency(base=1.0, skew=1.0))
    trace = sched.dispatch_round(0, threshold=2, pre_s=0.5, post_s=0.25)
    # encode charged BEFORE dispatch: t_start moved, the wait did not
    assert trace.t_start == pytest.approx(0.5)
    assert trace.t_first_R == pytest.approx(2.5)         # worker 1 at +2.0
    assert trace.coded_wait_s == pytest.approx(2.0)
    assert trace.encode_s == pytest.approx(0.5)
    assert trace.decode_s == pytest.approx(0.25)
    assert trace.critical_path_s == pytest.approx(0.5 + 2.0 + 0.25)
    # decode charged AFTER the decode instant, visible on the clock
    assert sched.clock == pytest.approx(2.75)
    assert trace.t_ready == pytest.approx(2.75)


def test_scheduler_on_result_fires_in_arrival_order():
    sched = EventScheduler(4, DeterministicLatency(base=1.0, skew=1.0))
    seen = []
    sched.dispatch_round(0, threshold=3,
                         on_result=lambda w, payload: seen.append(w))
    assert seen == [0, 1, 2]


def test_runner_wait_stats_expose_components(binary_data):
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                           DeterministicLatency(base=1.0, skew=0.1),
                           pipeline="full",
                           encode_cost_s=0.3, decode_cost_s=0.14)
    runner.run(6)
    stats = runner.wait_stats()
    # prefetch leaves the K/(K+T) data fraction of the encode ...
    assert stats["encode"]["mean"] == pytest.approx(0.3 * 2 / 3)
    # ... and streaming leaves one fold of threshold on a subset-prediction
    # hit, but the FULL decode cost on a miss (honest fallback accounting)
    hits = stats["rounds"]["streamed"]
    misses = 6 - hits
    assert stats["decode"]["mean"] == pytest.approx(
        (hits * 0.14 / cfg.threshold + misses * 0.14) / 6)
    assert stats["critical_path"]["mean"] == pytest.approx(
        stats["encode"]["mean"] + stats["coded_T"]["mean"]
        + stats["decode"]["mean"])
    assert stats["rounds"]["prefetched"] == 6.0
    # prefetched plans lag a round (built while the previous round is in
    # flight), so under a CONSTANT responder order everything from round 2
    # streams; rounds 0/1 depend on producer/consumer interleaving
    assert hits >= 4.0


# ---------------------------------------------------------------------------
# RoundPrefetcher: one-ahead production, rewind, clean close
# ---------------------------------------------------------------------------

def _ctx(t):
    return RoundContext(t=t, kq=None, mask_shares=np.zeros(1),
                        batch_idx=None, plan=None)


def test_prefetcher_serves_in_order_and_rewinds():
    built = []

    def build(t):
        built.append(t)
        return _ctx(t)

    with RoundPrefetcher(build, start=0, stop=10) as pf:
        assert pf.get(0).t == 0
        assert pf.get(1).t == 1
        # checkpoint-restore rewind: an unexpected t resets the producer
        assert pf.get(0).t == 0
        assert pf.get(1).t == 1
        assert pf.get(2).t == 2
    assert built[0] == 0 and 0 in built[2:], "rewind must rebuild t=0"


def test_prefetcher_close_joins_thread():
    pf = RoundPrefetcher(_ctx, start=0, stop=5)
    assert pf.get(0).t == 0
    pf.close()
    pf.close()                                  # idempotent
    assert not pf._thread.is_alive()


# ---------------------------------------------------------------------------
# ClusterRunner pipeline modes: bit-identity, all modes, dead worker
# ---------------------------------------------------------------------------

def test_pipeline_modes_bit_identical_under_stragglers(binary_data):
    """Every pipeline mode == train_reference on the observed trace, and
    (order-independent latencies) all modes observe the SAME trace and
    produce the SAME weights as the sequential engine."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    ws, traces = {}, {}
    for mode in PIPELINE_MODES:
        lat = LognormalTailLatency(seed=3, tail_prob=0.3, tail_scale=25.0)
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat,
                               pipeline=mode,
                               encode_cost_s=0.2, decode_cost_s=0.1)
        ws[mode] = np.asarray(runner.run(10))
        traces[mode] = [tuple(map(int, r.survivors))
                        for r in runner.records.values()]
        w_ref, _ = protocol.train_reference(
            cfg, jax.random.PRNGKey(7), x, y, iters=10,
            survivor_fn=runner.survivor_fn())
        assert (ws[mode] == np.asarray(w_ref)).all(), mode
    for mode in PIPELINE_MODES[1:]:
        assert (ws[mode] == ws["off"]).all(), mode
        assert traces[mode] == traces["off"], mode


def test_pipeline_minibatch_multiclass_bit_identical():
    """Mini-batch draws ride the prefetcher: the prefetched batch indices
    must reproduce make_schedule's derivations exactly."""
    x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(42), m=240,
                                           d=20, c=3)
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3, batch_rows=16)
    lat = LognormalTailLatency(seed=5, tail_prob=0.2, tail_scale=10.0)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat,
                           pipeline="full")
    w = runner.run(8)
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=8,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_pipeline_full_rides_through_mid_run_dead_worker(binary_data):
    """Pipelined-vs-sequential bit-identity with a worker dying mid-run
    (within the erasure tolerance): same trace, same weights, and both
    equal the reference."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)        # threshold 7
    ws = {}
    for mode in ("off", "full"):
        lat = DeadWorkerLatency(DeterministicLatency(base=1.0, skew=0.1),
                                deaths={5: 4})
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat,
                               pipeline=mode,
                               encode_cost_s=0.2, decode_cost_s=0.1)
        ws[mode] = np.asarray(runner.run(12))
        assert all(5 not in set(map(int, r.survivors))
                   for t, r in runner.records.items() if t >= 4)
        w_ref, _ = protocol.train_reference(
            cfg, jax.random.PRNGKey(7), x, y, iters=12,
            survivor_fn=runner.survivor_fn())
        assert (ws[mode] == np.asarray(w_ref)).all(), mode
    assert (ws["full"] == ws["off"]).all()


def test_pipeline_full_survives_checkpoint_restore(binary_data):
    """A starved round under pipeline=full restores + replays: the
    prefetcher rewinds and the replayed contexts are identical, so the
    resilient run still completes with the usual guarantees."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    lat = DeadWorkerLatency(LognormalTailLatency(seed=5),
                            deaths={0: 4, 1: 4})
    runner = ClusterRunner(cfg, jax.random.PRNGKey(9), x, y, lat,
                           round_timeout_s=60.0, pipeline="full")
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_write=False)
        w = runner.run_resilient(12, mgr, checkpoint_every=2)
    assert runner.restarts == 1
    assert len(runner.records) == 12
    assert w.shape == (x.shape[1],)
    assert runner.records[11].n_responders >= cfg.threshold


def test_streaming_prediction_hits_under_stable_order(binary_data):
    """Deterministic latencies -> a constant responder order -> the
    subset prediction hits from round 2 on (round 0 has no history; round
    1's plan is built by the prefetch thread before round 0 completes)."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                           DeterministicLatency(base=1.0, skew=0.1),
                           pipeline="streaming")
    runner.run(8)
    stats = runner.wait_stats()
    # "streaming" without prefetch builds the plan inline from the last
    # observed order: only round 0 can miss
    assert stats["rounds"]["streamed"] >= 7.0


def test_pipeline_rejects_unknown_mode(binary_data):
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    with pytest.raises(AssertionError):
        ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                      DeterministicLatency(), pipeline="bogus")
