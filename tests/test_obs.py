"""Flight recorder acceptance (DESIGN.md §11, repro.obs).

Fast tests cover the recorder's invariants (every span closes, spans nest,
foreign-process spans never mix into master stacks), the metrics registry
and its Prometheus text format, the zeroed empty-run wait_summary contract,
and — on the simulated backend — that a traced run is bit-identical to an
untraced one while producing a Perfetto-valid trace whose per-round
critical-path sums reconcile exactly with wait_stats.

Slow tests put the same invariants on real infrastructure: a socket run
must produce the SAME span structure as a simulated run (same names, same
nesting — only the numbers differ), worker-side spans must arrive over the
v2 TRACE wire field, and a forced-v1 fleet must round-trip with worker
traces silently absent.
"""
import json
import math
import warnings

import jax
import numpy as np
import pytest

from repro.cluster import ClusterRunner, make_latency
from repro.cluster.runner import wait_summary
from repro.core import protocol
from repro.data import synthetic
from repro.obs.export import (round_summaries, straggler_report,
                              to_chrome_trace, validate_chrome_trace,
                              waterfall)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_RECORDER, NullRecorder, Recorder, structure


# ---------------------------------------------------------------------------
# Recorder invariants
# ---------------------------------------------------------------------------

def test_spans_nest_and_close():
    clock = iter(float(i) for i in range(100))
    rec = Recorder(clock_fn=lambda: next(clock))
    outer = rec.begin("round", round=0)
    with rec.span("collect", round=0):
        rec.instant("fold", worker=3)
    rec.end(outer)
    assert not rec.open_spans()
    collect = rec.find("collect")[0]
    assert collect.parent == "round"
    assert rec.find("fold")[0].parent == "collect"
    assert rec.find("round")[0].parent is None
    assert collect.duration > 0


def test_exception_unwind_closes_children():
    rec = Recorder()
    outer = rec.begin("round")
    inner = rec.begin("collect")        # never explicitly ended: an
    rec.end(outer)                      # exception unwound past it
    assert not inner.open
    assert inner.end == outer.end
    assert not rec.open_spans()


def test_tracks_have_independent_stacks():
    rec = Recorder()
    with rec.span("round"):
        with rec.span("prefetch_build", track="prefetch"):
            pass
    build = rec.find("prefetch_build")[0]
    assert build.parent is None          # different track: no nesting
    assert build.track == "prefetch"


def test_add_process_spans_stays_in_foreign_clock_domain():
    rec = Recorder()
    with rec.span("round", round=2):
        rec.add_process_spans("worker3",
                              [["recv", 0.1, 0.2], ["compute", 0.2, 0.9]],
                              round=2)
    w = [s for s in rec.spans if s.process == "worker3"]
    assert [s.name for s in w] == ["recv", "compute"]
    # foreign spans never nest under master spans (different clock epoch)
    assert all(s.parent is None for s in w)
    assert all(s.args == {"round": 2} for s in w)


def test_add_process_spans_drops_malformed_triples():
    rec = Recorder()
    rec.add_process_spans("worker0",
                          [["ok", 1.0, 2.0], ["short"], "junk", None,
                           ["bad", "x", 3.0], ["also_ok", 3, 4]])
    assert [s.name for s in rec.spans] == ["ok", "also_ok"]


def test_null_recorder_is_inert():
    n = NullRecorder()
    assert not n.enabled and NULL_RECORDER.enabled is False
    with n.span("anything", round=1) as s:
        assert s is None
    n.end(n.begin("x"))
    n.instant("y")
    n.add_span("z", 0.0, 1.0)
    n.add_process_spans("w", [["a", 0, 1]])
    assert n.spans == () and n.open_spans() == [] and n.find("x") == []
    # the context manager is a shared singleton: zero per-call allocation
    assert n.span("a") is n.span("b")


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    m = MetricsRegistry()
    c = m.counter("rounds_total", "rounds")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)                        # counters are monotone
    g = m.gauge("alive", "workers")
    g.set(7)
    g.inc(-2)
    assert g.value == 5
    h = m.histogram("wait_seconds", "waits")
    h.observe(0.05)
    h.observe(math.nan)                  # skipped, never poisons the sum
    h.observe(math.inf)                  # counted, excluded from the sum
    assert h.count == 2
    assert h.sum == pytest.approx(0.05)


def test_registry_get_or_create_and_kind_mismatch():
    m = MetricsRegistry()
    assert m.counter("a", "x") is m.counter("a", "x")
    with pytest.raises(TypeError):
        m.gauge("a", "x")


def test_snapshot_and_prometheus_format():
    m = MetricsRegistry()
    m.counter("cpml_rounds_total", "completed rounds").inc(3)
    m.gauge("cpml_workers_alive", "alive").set(8)
    h = m.histogram("cpml_wait_seconds", "waits", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    snap = m.snapshot()
    json.dumps(snap)                     # JSON-able by construction
    assert snap["cpml_rounds_total"]["value"] == 3
    text = m.to_prometheus()
    assert "# TYPE cpml_rounds_total counter" in text
    assert "cpml_rounds_total 3" in text
    assert "cpml_workers_alive 8" in text
    # cumulative buckets + the +Inf catch-all
    assert 'cpml_wait_seconds_bucket{le="0.1"} 1' in text
    assert 'cpml_wait_seconds_bucket{le="1"} 2' in text
    assert 'cpml_wait_seconds_bucket{le="+Inf"} 3' in text
    assert "cpml_wait_seconds_count 3" in text


def test_metrics_write_json_vs_prom(tmp_path):
    m = MetricsRegistry()
    m.counter("c_total", "c").inc()
    jp, pp = tmp_path / "m.json", tmp_path / "m.prom"
    m.write(str(jp))
    m.write(str(pp))
    assert json.loads(jp.read_text())["c_total"]["value"] == 1
    assert "# TYPE c_total counter" in pp.read_text()


# ---------------------------------------------------------------------------
# Empty-run wait stats: the zeroed-summary contract (satellite fix)
# ---------------------------------------------------------------------------

def test_wait_summary_empty_is_zeroed_and_warning_free():
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # numpy mean-of-empty would warn
        s = wait_summary([])
    assert s == {"mean": 0.0, "p50": 0.0, "p95": 0.0, "total": 0.0}


def test_wait_stats_on_runner_with_no_rounds():
    x, y = synthetic.mnist_like(jax.random.PRNGKey(42), m=64, d=8)
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                           make_latency("deterministic"))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        stats = runner.wait_stats()      # zero completed rounds
    for key in ("coded_T", "wait_all", "encode", "decode", "critical_path"):
        assert stats[key] == {"mean": 0.0, "p50": 0.0, "p95": 0.0,
                              "total": 0.0}
    assert stats["rounds"]["n"] == 0.0
    json.dumps(stats)                    # finite + serializable throughout


# ---------------------------------------------------------------------------
# Traced simulated runs: invariants + reconciliation + bit-identity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sim_data():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=128, d=16)


def _sim_run(x, y, recorder=None, **kw):
    cfg = protocol.CPMLConfig(N=6, K=1, T=1, r=1)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                           make_latency("lognormal", seed=3),
                           encode_cost_s=0.02, decode_cost_s=0.01,
                           recorder=recorder, **kw)
    w = runner.run(5)
    return runner, w


def test_traced_sim_run_invariants(sim_data):
    x, y = sim_data
    rec = Recorder()
    runner, w = _sim_run(x, y, recorder=rec)
    assert runner.obs is rec
    assert not rec.open_spans()          # every span closed
    names = {s.name for s in rec.spans}
    assert {"round", "dispatch", "collect", "encode", "wait",
            "decode", "flight"} <= names
    # derived spans nest under their round
    for nm in ("encode", "wait", "decode"):
        assert all(s.parent == "round" for s in rec.find(nm))
    # one flight lane per responding worker, parented to nothing (they live
    # on per-worker tracks) and stamped with the worker index
    for s in rec.find("flight"):
        assert s.track == f"worker/{s.args['worker']}"
        assert s.duration >= 0


def test_traced_sim_bit_identical_to_untraced(sim_data):
    x, y = sim_data
    _, w_off = _sim_run(x, y, recorder=None)
    _, w_on = _sim_run(x, y, recorder=Recorder())
    assert (np.asarray(w_off) == np.asarray(w_on)).all()


def test_chrome_trace_export_is_valid_and_reconciles(sim_data):
    x, y = sim_data
    rec = Recorder()
    runner, _ = _sim_run(x, y, recorder=rec)
    obj = to_chrome_trace(rec)
    assert validate_chrome_trace(obj) == []
    # the reconciliation surface: per-round critical-path components read
    # back from the SPANS must equal what wait_stats aggregated from the
    # RoundTraces (same numbers, same clock)
    rows = round_summaries(rec)
    assert [r["round"] for r in rows] == list(range(5))
    stats = runner.wait_stats()
    assert sum(r["critical_path"] for r in rows) == pytest.approx(
        stats["critical_path"]["total"], rel=1e-9)
    assert sum(r["wait"] for r in rows) == pytest.approx(
        stats["coded_T"]["total"], rel=1e-9)
    assert "round" in waterfall(rec)     # terminal view renders


def test_straggler_report_attributes_decisive_waits(sim_data):
    x, y = sim_data
    runner, _ = _sim_run(x, y, recorder=Recorder())
    text, stats = straggler_report(runner.traces, runner.cfg.threshold)
    assert "straggler attribution" in text
    assert set(stats) == set(range(runner.cfg.N))
    # exactly one decisive (threshold-th) arrival per completed round
    assert sum(s["decisive"] for s in stats.values()) == len(runner.traces)
    assert all(s["marginal_wait_s"] >= 0 for s in stats.values())


def test_metrics_populated_by_sim_run(sim_data):
    x, y = sim_data
    runner, _ = _sim_run(x, y, recorder=Recorder())
    snap = runner.metrics.snapshot()
    assert snap["cpml_rounds_total"]["value"] == 5
    assert snap["cpml_round_wait_seconds"]["count"] == 5
    assert snap["cpml_round_wait_seconds"]["sum"] == pytest.approx(
        runner.wait_stats()["coded_T"]["total"], rel=1e-9)
    assert snap["cpml_workers_alive"]["value"] == 6


def test_round_record_is_thin_view_over_trace(sim_data):
    x, y = sim_data
    runner, _ = _sim_run(x, y, recorder=None)
    for t, rec in runner.records.items():
        tr = runner.traces[t]
        assert rec.trace is tr
        assert rec.coded_wait_s == tr.coded_wait_s
        assert rec.encode_s == tr.encode_s
        assert rec.n_responders == len(tr.responders)
        assert (rec.dispatched == tr.dispatched).all()


def test_mpc_sim_run_traces_barriers():
    from repro.cluster.mpc_runner import MPCClusterRunner, mpc_phase_models
    from repro.core import mpc_baseline
    x, y = synthetic.mnist_like(jax.random.PRNGKey(42), m=64, d=8)
    cfg = mpc_baseline.MPCConfig(N=5, T=1, r=2)
    rec = Recorder()
    runner = MPCClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                              mpc_phase_models("lognormal", r=cfg.r),
                              recorder=rec)
    runner.run(3)
    assert not rec.open_spans()
    names = {s.name for s in rec.spans}
    assert {"mpc_round", "dispatch", "collect", "wait", "barrier",
            "flight"} <= names
    # r reshare barriers per round, chained on the master timeline
    assert len(rec.find("barrier")) == 3 * cfg.r
    assert validate_chrome_trace(to_chrome_trace(rec)) == []
    assert runner.metrics.snapshot()["mpc_rounds_total"]["value"] == 3


# ---------------------------------------------------------------------------
# Cross-backend structure + v1-wire degradation (real processes: slow)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def socket_data():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=256, d=20)


def _socket_run(x, y, *, wire_version=2, recorder=None, sleep_s=None):
    from repro.launch.cpml_cluster import local_socket_cluster
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)        # threshold 4
    with local_socket_cluster(cfg.N, wire_version=wire_version,
                              sleep_s=sleep_s) as tr:
        runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                               latency=None, transport=tr,
                               round_timeout_s=120.0, recorder=recorder)
        runner.provision()
        w = runner.run(5)
        runner.shutdown_workers()
    return runner, w


@pytest.mark.slow
def test_sim_and_socket_traces_share_structure(socket_data):
    """The pluggable-clock contract: SimClock and WallClock runs go through
    the same instrumented call sites, so the master-side span structure
    (names + nesting, per structure()'s track-collapsed view) is identical
    — only provisioning (meaningless in-process) is socket-only."""
    x, y = socket_data
    cfg = protocol.CPMLConfig(N=5, K=1, T=1, r=1)
    sim_rec = Recorder()
    sim = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y,
                        make_latency("deterministic"),
                        encode_cost_s=0.01, decode_cost_s=0.01,
                        recorder=sim_rec)
    sim.run(5)
    sock_rec = Recorder()
    _socket_run(x, y, recorder=sock_rec)
    sim_shape = structure(sim_rec)
    sock_shape = structure(sock_rec)
    assert sock_shape - {("master", "provision", None)} == sim_shape
    assert not sock_rec.open_spans()


@pytest.mark.slow
def test_socket_worker_spans_arrive_over_v2_wire(socket_data):
    x, y = socket_data
    rec = Recorder()
    runner, w = _socket_run(x, y, wire_version=2, recorder=rec)
    worker_procs = {s.process for s in rec.spans
                    if s.process.startswith("worker")}
    assert worker_procs                   # at least one worker shipped spans
    for p in worker_procs:
        names = {s.name for s in rec.spans if s.process == p}
        assert {"recv", "compute", "serialize"} <= names
    # warm-compile (measured in the provisioning window) reached the gauge
    snap = runner.metrics.snapshot()
    assert snap["cpml_xla_warm_compile_seconds"]["value"] > 0
    # and the export is Perfetto-valid with multiple processes
    obj = to_chrome_trace(rec)
    assert validate_chrome_trace(obj) == []
    pids = {e["pid"] for e in obj["traceEvents"]}
    assert len(pids) >= 2
    # reconciliation holds on the wall clock too
    assert sum(r["critical_path"] for r in round_summaries(rec)) == \
        pytest.approx(runner.wait_stats()["critical_path"]["total"],
                      rel=1e-9)


@pytest.mark.slow
def test_v1_fleet_roundtrips_with_traces_silently_absent(socket_data):
    """A forced-v1 fleet cannot carry the TRACE wire field: the run must
    succeed, stay bit-identical, keep all master-side spans — and simply
    have no worker-process spans (same degradation shape as HELLO2)."""
    x, y = socket_data
    rec = Recorder()
    runner, w = _socket_run(x, y, wire_version=1, recorder=rec)
    assert not any(s.process.startswith("worker") for s in rec.spans)
    assert rec.find("round") and rec.find("flight")
    w_ref, _ = protocol.train_reference(
        runner.cfg, jax.random.PRNGKey(7), x, y, iters=5,
        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()
