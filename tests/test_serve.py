"""Serving-plane acceptance (DESIGN.md §12, cluster/serve.py).

The load-bearing invariant mirrors the training plane's: the serving
runtime decides WHEN queries are batched and WHICH workers' shares are
decoded, never WHAT is computed — every served prediction must be
bit-identical to the uncoded plaintext oracle (quantize -> field matmul
-> dequantize on the master, no coding at all), on the simulated backend
and over real TCP worker processes, including with a worker killed
mid-service.  Around that: batching-policy units (size- vs deadline-
triggered flushes), bounded-queue admission control, Query/Prediction
wire round-trips, and the first-threshold vs wait-for-all tail claim.

Socket tests spawn subprocesses and are marked ``slow`` (DESIGN.md §8).
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.cluster import wire
from repro.cluster.latency import (DeterministicLatency,
                                   SleepyStragglerLatency)
from repro.cluster.messages import Prediction, Query
from repro.cluster.serve import (BatchingPolicy, PredictionServer,
                                 ServeConfig, open_loop_queries)


def tiny_cfg(**kw):
    kw.setdefault("N", 6)
    kw.setdefault("K", 2)
    kw.setdefault("T", 1)
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_wait_s", 0.02)
    return ServeConfig(**kw)


def tiny_server(cfg=None, d=12, classes=5, **kw):
    cfg = cfg or tiny_cfg()
    w = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (d, classes))
    kw.setdefault("latency", DeterministicLatency(base=1e-3, skew=0.1))
    kw.setdefault("verify", True)
    return PredictionServer(cfg, w, jax.random.PRNGKey(2), **kw)


# ---------------------------------------------------------------------------
# batching policy + config validation
# ---------------------------------------------------------------------------

def test_policy_flushes_on_size():
    pol = BatchingPolicy(max_batch=8, max_wait_s=10.0)
    assert not pol.should_flush(7, oldest_age_s=0.0)
    assert pol.should_flush(8, oldest_age_s=0.0)
    assert pol.should_flush(9, oldest_age_s=0.0)


def test_policy_flushes_on_deadline():
    pol = BatchingPolicy(max_batch=8, max_wait_s=0.05)
    assert not pol.should_flush(1, oldest_age_s=0.049)
    assert pol.should_flush(1, oldest_age_s=0.05)
    assert pol.deadline(oldest_admitted_at=2.0) == pytest.approx(2.05)


def test_policy_never_flushes_empty_queue():
    pol = BatchingPolicy(max_batch=8, max_wait_s=0.0)
    # max_wait 0 means flush immediately — but only if there ARE rows
    assert not pol.should_flush(0, oldest_age_s=math.inf)
    assert pol.should_flush(1, oldest_age_s=0.0)


def test_config_validation():
    with pytest.raises(AssertionError):
        tiny_cfg(max_batch=7)                  # K=2 must divide max_batch
    with pytest.raises(AssertionError):
        tiny_cfg(N=4)                          # N below 2(K+T-1)+1 = 5
    cfg = tiny_cfg()
    assert cfg.threshold == 5 and cfg.rows_per_part == 4


# ---------------------------------------------------------------------------
# admission control: the bounded queue rejects, never blocks or drops
# ---------------------------------------------------------------------------

def test_queue_full_rejects_at_submission():
    srv = tiny_server(tiny_cfg(queue_cap=3))
    qs = open_loop_queries(5, rows=1, d=12, rate_qps=0.0)
    accepted = [srv.submit(q, now=0.0) for q in qs]
    assert accepted == [True, True, True, False, False]
    assert srv.rejected == [3, 4]
    assert int(srv.metrics.counter("serve_rejected_total").value) == 2


def test_oversized_and_empty_queries_rejected():
    srv = tiny_server()                        # max_batch = 8
    big = Query(qid=0, client="c", sent_at=0.0,
                x=np.zeros((9, 12), np.float32))
    empty = Query(qid=1, client="c", sent_at=0.0,
                  x=np.zeros((0, 12), np.float32))
    assert not srv.submit(big, now=0.0)
    assert not srv.submit(empty, now=0.0)
    assert srv.rejected == [0, 1]


# ---------------------------------------------------------------------------
# bit-identity vs the uncoded plaintext oracle (simulated backend)
# ---------------------------------------------------------------------------

def test_open_loop_served_predictions_bit_identical():
    srv = tiny_server()
    qs = open_loop_queries(12, rows=3, d=12, rate_qps=500.0, seed=9)
    srv.run(qs)
    assert len(srv.results) == 12 and not srv.rejected
    stats = srv.stats()
    assert stats["oracle"]["checked"] >= 1
    assert stats["oracle"]["bit_identical"]
    for q in qs:
        pred = srv.results[q.qid]
        assert isinstance(pred, Prediction) and pred.client == q.client
        assert np.array_equal(np.asarray(pred.y), srv.oracle_logits(q.x))
        assert math.isfinite(pred.latency_s) and pred.latency_s >= 0.0


def test_closed_loop_full_batches_bit_identical():
    srv = tiny_server()
    qs = open_loop_queries(4, rows=8, d=12, rate_qps=0.0, seed=3)
    srv.run_closed_loop(qs)
    assert len(srv.results) == 4
    assert srv.stats()["rounds"] == 4          # one flush per full batch
    for q in qs:
        assert np.array_equal(np.asarray(srv.results[q.qid].y),
                              srv.oracle_logits(q.x))


def test_deadline_flush_serves_partial_batch():
    """A lone query never fills max_batch; the deadline must flush it."""
    srv = tiny_server()
    q = open_loop_queries(1, rows=2, d=12, rate_qps=0.0)[0]
    srv.run([q])
    assert len(srv.results) == 1
    assert np.array_equal(np.asarray(srv.results[q.qid].y),
                          srv.oracle_logits(q.x))


def test_straggler_first_threshold_beats_wait_all():
    """The serving claim on the simulated clock: same arrivals, same
    latency draws, the sleeper's delay lands on wait-all but not on the
    first-threshold service."""
    lats = {}
    for collect_all in (False, True):
        srv = tiny_server(
            latency=SleepyStragglerLatency(
                DeterministicLatency(base=1e-3, skew=0.1), {5: 0.5}),
            collect_all=collect_all, exclude_stragglers=False)
        srv.run(open_loop_queries(8, rows=4, d=12, rate_qps=200.0, seed=4))
        stats = srv.stats()
        assert stats["oracle"]["bit_identical"]
        lats[collect_all] = stats
    first = lats[False]["latency_first"]["p99"]
    wait_all = lats[True]["latency_all"]["p99"]
    assert wait_all >= 0.5                     # every flush paid the sleep
    assert first < 0.1 < wait_all


def test_weight_shares_encoded_once_and_reused():
    """The provisioned model shares are fixed per provision; only query
    masks are fresh per flush (the privacy accounting in DESIGN.md §12)."""
    srv = tiny_server()
    before = np.asarray(srv.w_shares).copy()
    srv.run(open_loop_queries(6, rows=4, d=12, rate_qps=300.0, seed=2))
    assert np.array_equal(np.asarray(srv.w_shares), before)


# ---------------------------------------------------------------------------
# Query / Prediction wire frames (v1 + v2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("version", [wire.WIRE_V1, wire.WIRE_V2])
def test_query_roundtrip(version):
    msg = Query(qid=41, client="client3", sent_at=1.25,
                x=np.arange(12, dtype=np.float32).reshape(3, 4))
    out = wire.deserialize(wire.serialize(msg, version))
    assert wire.messages_equal(out, msg), f"{out!r} != {msg!r}"


@pytest.mark.parametrize("version", [wire.WIRE_V1, wire.WIRE_V2])
def test_prediction_roundtrip(version):
    msg = Prediction(qid=41, client="client3", latency_s=0.031,
                     y=np.linspace(-2, 2, 10).reshape(2, 5))
    out = wire.deserialize(wire.serialize(msg, version))
    assert wire.messages_equal(out, msg), f"{out!r} != {msg!r}"


# ---------------------------------------------------------------------------
# live TCP serving: worker processes in "serve" protocol mode
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_socket_serving_bit_identical_with_worker_killed_mid_run():
    """THE serving acceptance on real infrastructure: N=6 worker processes
    provisioned once with model shares, open-loop queries over TCP, one
    worker crashing mid-service (N drops to exactly the threshold) — and
    every served prediction stays bit-identical to the plaintext oracle."""
    from repro.launch.cpml_cluster import local_socket_cluster
    cfg = tiny_cfg()
    w = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (12, 5))
    qs = open_loop_queries(10, rows=4, d=12, rate_qps=100.0, seed=6)
    with local_socket_cluster(cfg.N, die_at_round={4: 2}) as tr:
        srv = PredictionServer(cfg, w, jax.random.PRNGKey(2), transport=tr,
                               round_timeout_s=120.0, verify=True)
        srv.provision()
        srv.run(qs)
        srv.shutdown_workers()
    assert len(srv.results) == 10
    stats = srv.stats()
    assert stats["rounds"] >= 3                # the kill round was mid-run
    assert stats["oracle"]["bit_identical"] and stats["oracle"]["checked"]
    for q in qs:
        assert np.array_equal(np.asarray(srv.results[q.qid].y),
                              srv.oracle_logits(q.x))
    # the dead worker really dropped out of later decode sets
    late = max(srv.traces)
    assert 4 not in srv.traces[late].responders
