"""Scan-engine acceptance: the fully-jitted lax.scan train() must be
BIT-IDENTICAL to the per-step reference loop over the same schedule, for
>= 10 iterations, across backends/kernel/batching.  (The shard backend is
covered in test_system.py::test_shard_map_backend_multidevice via a forced
8-device subprocess.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol, quantize, sigmoid_poly
from repro.data import synthetic


@pytest.fixture(scope="module")
def binary_data():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=300, d=24)


@pytest.fixture(scope="module")
def mc_data():
    return synthetic.multiclass_mnist_like(jax.random.PRNGKey(42), m=300,
                                           d=24, c=3)


def rotating_survivors(n):
    return lambda t: np.roll(np.arange(n), t)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_scan_bit_identical_binary(binary_data, use_kernel):
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, use_kernel=use_kernel)
    kw = dict(iters=12, survivor_fn=rotating_survivors(cfg.N), eval_every=6)
    w1, h1 = protocol.train(cfg, jax.random.PRNGKey(7), x, y, **kw)
    w2, h2 = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y, **kw)
    assert w1.shape == (x.shape[1],)
    assert (np.asarray(w1) == np.asarray(w2)).all()
    assert len(h1) == len(h2) == 2
    for a, b in zip(h1, h2):
        assert a["iter"] == b["iter"]
        assert np.isclose(a["loss"], b["loss"], atol=1e-6)
        assert np.isclose(a["acc"], b["acc"], atol=1e-6)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_scan_bit_identical_multiclass(mc_data, use_kernel):
    x, y = mc_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3, use_kernel=use_kernel)
    w1, _ = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=10,
                           survivor_fn=rotating_survivors(cfg.N))
    w2, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                     iters=10,
                                     survivor_fn=rotating_survivors(cfg.N))
    assert w1.shape == (x.shape[1], 3)
    assert (np.asarray(w1) == np.asarray(w2)).all()


def test_scan_bit_identical_minibatch(mc_data):
    x, y = mc_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3, batch_rows=32)
    w1, _ = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=12)
    w2, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                     iters=12)
    assert (np.asarray(w1) == np.asarray(w2)).all()


def test_minibatch_trains(mc_data):
    """Mini-batch SGD actually reduces the loss over the full data."""
    x, y = mc_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3, batch_rows=32)
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=20,
                             eval_every=20)
    assert hist[-1]["loss"] < 0.6365       # improved from -log sigmoid(0)


def test_schedule_shapes(mc_data):
    x, y = mc_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3, batch_rows=16)
    sched = protocol.make_schedule(cfg, jax.random.PRNGKey(0), 5, mk=150,
                                   survivor_fn=rotating_survivors(cfg.N))
    R = cfg.threshold
    assert sched.decode_mats.shape == (5, R, cfg.K)
    assert sched.orders.shape == (5, R)
    assert sched.batch_idx.shape == (5, 16)
    # without replacement within a round
    for t in range(5):
        assert len(set(np.asarray(sched.batch_idx[t]))) == 16


def test_minibatch_padded_row_normalization():
    """m not divisible by K: a batch containing the padded tail row must
    normalize by the REAL sample count (K*b - #padded), matching the
    cleartext mini-batch update exactly."""
    x, y = synthetic.mnist_like(jax.random.PRNGKey(0), m=299, d=16)
    b = 8
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, batch_rows=b)
    state = protocol.setup(cfg, jax.random.PRNGKey(0), x, y)
    assert state.mk == 150                      # padded 299 -> 300
    idx = jnp.asarray([149, 0, 5, 10, 20, 30, 40, 50], jnp.int32)  # 149: pad
    eta = 0.5
    new = protocol.step(cfg, jax.random.PRNGKey(9), state, eta, batch_idx=idx)

    # cleartext replica over the 2*b - 1 REAL selected samples
    kq, _ = jax.random.split(jax.random.split(jax.random.PRNGKey(9))[0])
    wbar = quantize.quantize_weights(
        jax.random.split(jax.random.PRNGKey(9))[0],
        jnp.zeros((x.shape[1], 1)), cfg.lw, cfg.r, cfg.p)
    coeffs = sigmoid_poly.fit_sigmoid(cfg.r)
    rows = jnp.concatenate([state.xq_parts[k][idx] for k in range(2)])
    ys = jnp.concatenate([state.y_parts[k][idx, 0] for k in range(2)])
    gb = sigmoid_poly.gbar_real(rows, wbar[:, 0], coeffs, cfg.lx, cfg.lw,
                                cfg.p)
    n_real = 2 * b - 1                          # row 149 of part 1 is zero
    grad = (rows.T @ gb - rows.T @ ys) / n_real
    err = float(jnp.abs(new.w - (-eta * grad)).max())
    assert err < 2e-2, err


def test_minibatch_padding_spanning_parts():
    """Degenerate m << K^2: padding spills beyond the last part (m=5, K=4
    pads 3 rows over parts 2 and 3) — the real-row count must still be
    exact per batch index."""
    x = jnp.eye(5, 4) * 0.5
    y = jnp.array([0., 1., 0., 1., 0.])
    cfg = protocol.CPMLConfig(N=13, K=4, T=0, r=1, batch_rows=1)
    state = protocol.setup(cfg, jax.random.PRNGKey(0), x, y)
    assert state.mk == 2
    idx = jnp.asarray([1], jnp.int32)   # global rows 1,3,5,7 -> 5,7 padded
    eta = 1.0
    new = protocol.step(cfg, jax.random.PRNGKey(9), state, eta, batch_idx=idx)

    wbar = quantize.quantize_weights(
        jax.random.split(jax.random.PRNGKey(9))[0],
        jnp.zeros((4, 1)), cfg.lw, cfg.r, cfg.p)
    coeffs = sigmoid_poly.fit_sigmoid(cfg.r)
    rows = state.xq_real[jnp.asarray([1, 3])]            # the 2 REAL samples
    ys = state.y[jnp.asarray([1, 3])]
    gb = sigmoid_poly.gbar_real(rows, wbar[:, 0], coeffs, cfg.lx, cfg.lw,
                                cfg.p)
    grad = (rows.T @ gb - rows.T @ ys) / 2.0             # /2, not /4 or /3
    err = float(jnp.abs(new.w - (-eta * grad)).max())
    assert err < 2e-2, err


def test_step_requires_batch_idx_consistency(mc_data):
    x, y = mc_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3, batch_rows=16)
    state = protocol.setup(cfg, jax.random.PRNGKey(0), x, y)
    with pytest.raises(AssertionError):
        protocol.step(cfg, jax.random.PRNGKey(1), state, 0.5)  # no batch_idx
