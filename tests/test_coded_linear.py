"""Lagrange-coded TP linear layer (beyond-paper feature)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coded_linear as cl


def setup_layer(key, N=8, K=5, T=2, d=48, v=40, m=12):
    cfg = cl.CodedLinearConfig(N=N, K=K, T=T, lh=7, lw=7)
    kw, kh, ke = jax.random.split(key, 3)
    w = jax.random.normal(kw, (d, v)) * 0.5
    h = jax.random.normal(kh, (m, d)) * 0.5
    shares = cl.encode_weights(cfg, ke, w)
    return cfg, w, h, shares


def test_exact_vs_quantized_reference(key):
    cfg, w, h, shares = setup_layer(key)
    got = cl.coded_head_apply(cfg, h, shares)
    want = h @ w
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel < 0.02, rel     # fixed-point error only


@pytest.mark.parametrize("drop", [[0], [7]])
def test_straggler_sets_decode_identically(key, drop):
    # N=8, K=5, T=2 -> threshold 7: tolerates exactly one loss
    cfg, w, h, shares = setup_layer(key)
    base = cl.coded_head_apply(cfg, h, shares)
    surv = np.array([i for i in range(cfg.N) if i not in drop])
    got = cl.coded_head_apply(cfg, h, shares, survivors=surv)
    assert np.allclose(np.asarray(base), np.asarray(got), atol=1e-5)


def test_two_shard_losses_with_wider_code(key):
    cfg, w, h, shares = setup_layer(key, N=9, K=5, T=2)   # threshold 7 of 9
    base = cl.coded_head_apply(cfg, h, shares)
    surv = np.array([i for i in range(cfg.N) if i not in (2, 5)])
    got = cl.coded_head_apply(cfg, h, shares, survivors=surv)
    assert np.allclose(np.asarray(base), np.asarray(got), atol=1e-5)


def test_threshold_requirement(key):
    cfg, w, h, shares = setup_layer(key)
    assert cfg.threshold == 7        # K+T = 5+2
    with pytest.raises(AssertionError):
        cl.CodedLinearConfig(N=6, K=5, T=2)


def test_weight_privacy_masking(key):
    """T=2: any 2 shares of a ZERO weight matrix are pure mask — uniform."""
    cfg = cl.CodedLinearConfig(N=6, K=2, T=2)
    w = jnp.zeros((8, 10))
    samples = []
    for i in range(100):
        shares = cl.encode_weights(cfg, jax.random.PRNGKey(i), w)
        samples.append(np.asarray(shares[0]).ravel())
    vals = np.concatenate(samples).astype(np.float64) / cfg.p
    assert abs(vals.mean() - 0.5) < 0.03
    assert abs(vals.var() - 1 / 12) < 0.01
