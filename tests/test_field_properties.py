"""Property tests for the F_p arithmetic layer (hypothesis).

hypothesis is an optional dev dependency (DESIGN.md §8): this module skips
cleanly when it is absent; the deterministic fallback cases for the same
laws live in test_field.py and always run.
"""
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import field

PRIMES = [field.P, field.P30]
elem = lambda p: st.integers(min_value=0, max_value=p - 1)


@pytest.mark.parametrize("p", PRIMES)
@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_ring_laws(p, data):
    a = data.draw(elem(p))
    b = data.draw(elem(p))
    c = data.draw(elem(p))
    A, B, C = (jnp.int32(x) for x in (a, b, c))
    assert int(field.addmod(A, B, p)) == (a + b) % p
    assert int(field.submod(A, B, p)) == (a - b) % p
    assert int(field.mulmod(A, B, p)) == (a * b) % p
    # distributivity
    lhs = field.mulmod(A, field.addmod(B, C, p), p)
    rhs = field.addmod(field.mulmod(A, B, p), field.mulmod(A, C, p), p)
    assert int(lhs) == int(rhs)


@pytest.mark.parametrize("p", PRIMES)
@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_inverse_and_pow(p, data):
    a = data.draw(st.integers(min_value=1, max_value=p - 1))
    A = jnp.int32(a)
    assert int(field.mulmod(field.invmod(A, p), A, p)) == 1
    e = data.draw(st.integers(min_value=0, max_value=50))
    assert int(field.powmod(A, e, p)) == pow(a, e, p)
