"""Gradient compression (optim/compress.py): round-trip + bit-width edges.

The compressor reuses the paper's Eq. 8 stochastic quantizer on float
gradients, so the properties under test are the same two that make the
protocol's quantization sound: bounded per-element error (one level) and
exact unbiasedness in expectation over the rounding key.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compress import (compress_tree, decompress_tree,
                                  dequantize_grad, quantize_grad)


def test_roundtrip_error_bounded_by_one_level():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 7))
    q, scale = quantize_grad(jax.random.PRNGKey(1), g, bits=8)
    assert q.dtype == jnp.int32
    out = dequantize_grad(q, scale)
    # stochastic rounding moves each element at most one level
    assert float(jnp.max(jnp.abs(out - g))) <= float(scale) * (1 + 1e-6)
    # and the levels actually span the 8-bit signed range
    assert int(jnp.max(jnp.abs(q))) <= 127


def test_quantizer_is_unbiased_over_keys():
    """E[dequantize(quantize(g))] == g: average over many rounding keys
    converges to the input (the property Theorem 1's rate leans on)."""
    g = jnp.asarray([[0.3, -0.77, 0.001], [1.0, -1.0, 0.25]])
    acc = jnp.zeros_like(g)
    n = 400
    for i in range(n):
        q, s = quantize_grad(jax.random.PRNGKey(i), g, bits=4)
        acc = acc + dequantize_grad(q, s)
    mean = acc / n
    # SE of the mean is ~ scale/sqrt(12 n); 4 sigma keeps this deterministic
    tol = 4 * float(s) / np.sqrt(12 * n)
    assert float(jnp.max(jnp.abs(mean - g))) < tol


@pytest.mark.parametrize("bits", [2, 4, 8, 16])
def test_bits_edge_widths_roundtrip(bits):
    """Every width down to 2 bits (levels=1: sign-magnitude ternary) must
    quantize into range and reconstruct within one level."""
    g = jax.random.normal(jax.random.PRNGKey(7), (33,))
    q, scale = quantize_grad(jax.random.PRNGKey(8), g, bits=bits)
    levels = (1 << (bits - 1)) - 1
    assert int(jnp.max(jnp.abs(q))) <= levels
    err = jnp.abs(dequantize_grad(q, scale) - g)
    assert float(jnp.max(err)) <= float(scale) * (1 + 1e-6)
    # fewer bits -> coarser scale, monotone in the width
    assert float(scale) == pytest.approx(
        float(jnp.max(jnp.abs(g))) / levels, rel=1e-5)


def test_zero_gradient_roundtrips_to_zero():
    """The 1e-12 max-val floor guards the all-zero gradient: no NaNs, no
    spurious levels, exact zero back."""
    g = jnp.zeros((5, 3))
    q, scale = quantize_grad(jax.random.PRNGKey(0), g, bits=8)
    assert np.isfinite(float(scale))
    assert (np.asarray(q) == 0).all()
    assert (np.asarray(dequantize_grad(q, scale)) == 0).all()


def test_compress_tree_roundtrip_and_fresh_leaf_keys():
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (16, 4)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (4,)),
             "nested": [jnp.ones((3,)), jnp.linspace(-1.0, 1.0, 9)]}
    q_tree, scales = compress_tree(jax.random.PRNGKey(3), grads, bits=8)
    out = decompress_tree(q_tree, scales)
    flat_in, tdef_in = jax.tree.flatten(grads)
    flat_out, tdef_out = jax.tree.flatten(out)
    assert tdef_in == tdef_out                        # structure preserved
    flat_s, _ = jax.tree.flatten(scales)
    for gi, oi, si in zip(flat_in, flat_out, flat_s):
        assert oi.shape == gi.shape
        assert float(jnp.max(jnp.abs(oi - gi))) <= float(si) * (1 + 1e-6)
    # identical leaves under DIFFERENT per-leaf keys may still round apart:
    # the per-leaf key split is what de-correlates their rounding noise
    leaf = jnp.concatenate([jnp.ones((1,)), jnp.full((999,), 0.37)])
    same = [leaf, leaf]                 # 0.37 * 7 levels = 2.59: stochastic
    q2, _ = compress_tree(jax.random.PRNGKey(4), same, bits=4)
    assert not (np.asarray(q2[0]) == np.asarray(q2[1])).all()


def test_compress_tree_matches_per_leaf_quantize():
    """compress_tree is exactly quantize_grad per leaf with the split
    keys — no hidden coupling across leaves."""
    grads = [jax.random.normal(jax.random.PRNGKey(5), (8, 2)),
             jax.random.normal(jax.random.PRNGKey(6), (3,))]
    key = jax.random.PRNGKey(9)
    q_tree, scales = compress_tree(key, grads, bits=8)
    keys = jax.random.split(key, 2)
    for i in range(2):
        q_ref, s_ref = quantize_grad(keys[i], grads[i], bits=8)
        assert (np.asarray(q_tree[i]) == np.asarray(q_ref)).all()
        assert float(scales[i]) == float(s_ref)
