"""Validates the recorded dry-run sweeps (deliverables e/g).

These tests read the committed benchmarks/results* JSONs — they assert the
multi-pod dry-run actually succeeded for every (arch x shape) cell and that
the roofline records are complete and well-formed.  If the results are
regenerated, the same invariants must hold.
"""
import glob
import json
import os

import pytest

from repro.configs.base import SHAPES
from repro.configs import registry

ROOT = os.path.join(os.path.dirname(__file__), "..")
FINAL = os.path.join(ROOT, "benchmarks", "results_final")
MULTIPOD = os.path.join(ROOT, "benchmarks", "results")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(FINAL, "dryrun_*.json")),
    reason="dry-run sweep results not generated yet")


def _load(d, mesh):
    out = {}
    for p in glob.glob(os.path.join(d, f"dryrun_*__{mesh}.json")):
        c = json.load(open(p))
        out[(c["arch"], c["shape"])] = c
    return out


def test_all_cells_present_single_pod():
    cells = _load(FINAL, "16x16")
    for arch in registry.ARCHS:
        for shape in SHAPES:
            assert (arch, shape) in cells, f"missing cell {arch} x {shape}"
    assert len(cells) == 40


def test_no_errors_and_correct_skips():
    cells = _load(FINAL, "16x16")
    for (arch, shape), c in cells.items():
        assert c["status"] in ("ok", "skipped"), (arch, shape, c.get("error"))
        cfg = registry.get_config(arch)
        should_skip = (shape == "long_500k" and not cfg.sub_quadratic)
        assert (c["status"] == "skipped") == should_skip, (arch, shape)


def test_multipod_compiles():
    cells = _load(MULTIPOD, "2x16x16")
    assert len(cells) == 40
    n_ok = sum(c["status"] == "ok" for c in cells.values())
    n_skip = sum(c["status"] == "skipped" for c in cells.values())
    assert n_ok == 33 and n_skip == 7
    for c in cells.values():
        if c["status"] == "ok":
            assert c["chips"] == 512


def test_roofline_records_complete():
    cells = _load(FINAL, "16x16")
    for (arch, shape), c in cells.items():
        if c["status"] != "ok":
            continue
        t = c["roofline_terms_s"]
        for term in ("compute_s", "memory_s", "collective_s"):
            assert t[term] >= 0, (arch, shape, term)
        assert c["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert c["hlo_flops_per_device"] > 0
        assert c["model_flops_global"] > 0
        assert 0 < c["useful_ratio"] < 2.0, (arch, shape, c["useful_ratio"])
        # trip-count-aware dot flops must exceed XLA's once-counted number
        # for dot-dominated steps (train/prefill).  Decode steps at batch 1
        # are elementwise-heavy: XLA counts those, our analyzer counts dots.
        if shape in ("train_4k", "prefill_32k"):
            assert c["hlo_flops_per_device"] >= \
                c["xla_cost_flops_per_device"], (arch, shape)
