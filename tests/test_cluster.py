"""Cluster runtime acceptance (DESIGN.md §7).

The load-bearing test is bit-identity: ClusterRunner training — survivor
patterns discovered ONLINE from the event simulation under heavy straggler
injection — must produce exactly the same weights as engine.train_reference
replaying the observed responder trace, for >= 20 rounds.  The cluster
layer is allowed to change timing, never semantics.
"""
import math
import tempfile

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.cluster import (
    MASTER,
    BurstyStragglerLatency,
    ClusterDecodeError,
    ClusterRunner,
    DeadWorkerLatency,
    DeterministicLatency,
    EncodeShare,
    EventScheduler,
    InProcessTransport,
    LognormalTailLatency,
    make_latency,
    worker_endpoint,
)
from repro.core import protocol
from repro.data import synthetic


@pytest.fixture(scope="module")
def binary_data():
    return synthetic.mnist_like(jax.random.PRNGKey(42), m=300, d=24)


@pytest.fixture(scope="module")
def mc_data():
    return synthetic.multiclass_mnist_like(jax.random.PRNGKey(42), m=300,
                                           d=24, c=3)


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------

def test_transport_orders_by_delivery_time():
    tr = InProcessTransport()
    tr.send(MASTER, "slow", at=0.0, delay=5.0)
    tr.send(MASTER, "fast", at=0.0, delay=1.0)
    tr.send(MASTER, "never", at=0.0, delay=math.inf)   # dead worker: dropped
    assert tr.next_delivery(MASTER) == 1.0
    assert [m for _, m in tr.recv(MASTER, now=2.0)] == ["fast"]
    assert [m for _, m in tr.recv(MASTER, now=10.0)] == ["slow"]
    assert tr.next_delivery(MASTER) is None


def test_transport_fifo_on_ties():
    tr = InProcessTransport()
    for i in range(5):
        tr.send("w", i, at=1.0)
    assert [m for _, m in tr.recv("w", now=1.0)] == [0, 1, 2, 3, 4]


# ---------------------------------------------------------------------------
# Latency models: seeded, replayable, order-independent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["lognormal", "bursty"])
def test_latency_replayable_and_order_independent(name):
    a = make_latency(name, seed=11)
    b = make_latency(name, seed=11)
    fwd = [a.sample(t, w) for t in range(6) for w in range(4)]
    rev = [b.sample(t, w) for t in reversed(range(6))
           for w in reversed(range(4))]
    assert fwd == rev[::-1]
    c = make_latency(name, seed=12)
    assert fwd != [c.sample(t, w) for t in range(6) for w in range(4)]


def test_bursty_latency_has_multi_round_bursts():
    lat = BurstyStragglerLatency(seed=0, burst_prob=0.05, burst_len=4,
                                 slow_factor=50.0)
    slow = {(t, w) for t in range(200) for w in range(4)
            if lat.sample(t, w) > 10.0}
    assert slow, "no bursts in 800 draws at p=0.05"
    # bursts persist: a burst start covers burst_len consecutive rounds
    starts = {(t, w) for (t, w) in slow if (t - 1, w) not in slow}
    for t, w in starts:
        if t + 3 < 200:
            assert all((t + i, w) in slow for i in range(4))


def test_dead_worker_latency_and_revival():
    lat = DeadWorkerLatency(DeterministicLatency(base=1.0), deaths={2: 5})
    assert math.isfinite(lat.sample(4, 2))
    assert math.isinf(lat.sample(5, 2))
    assert math.isinf(lat.sample(9, 2))
    lat.revive(2, at_round=8)
    assert math.isinf(lat.sample(7, 2))      # pre-revival rounds stay dead
    assert math.isfinite(lat.sample(8, 2))   # replacement node is up


# ---------------------------------------------------------------------------
# Scheduler event loop
# ---------------------------------------------------------------------------

def test_scheduler_decodes_at_threshold_th_arrival():
    sched = EventScheduler(4, DeterministicLatency(base=1.0, skew=1.0))
    # latencies: worker i takes 1 + i seconds -> arrival order 0,1,2,3
    trace = sched.dispatch_round(0, threshold=2)
    assert list(trace.responders[:2]) == [0, 1]
    assert trace.t_first_R == pytest.approx(2.0)        # worker 1 at t=2
    assert trace.t_all == pytest.approx(4.0)            # worker 3 at t=4
    assert sched.clock == pytest.approx(2.0)            # master moved on


def test_scheduler_messages_flow_through_transport():
    tr = InProcessTransport()
    sched = EventScheduler(3, DeterministicLatency(base=1.0),
                           transport=tr)
    sched.dispatch_round(0, threshold=3)
    for w in range(3):
        msgs = [m for _, m in tr.recv(worker_endpoint(w), now=math.inf)]
        assert msgs and isinstance(msgs[0], EncodeShare)
        assert msgs[0].worker == w


def test_scheduler_worker_inboxes_stay_bounded():
    """Undelivered EncodeShares must not accumulate across rounds: the
    simulated worker consumes its previous share at the next dispatch."""
    tr = InProcessTransport()
    sched = EventScheduler(3, DeterministicLatency(base=1.0), transport=tr)
    for t in range(50):
        sched.dispatch_round(t, threshold=3)
    for w in range(3):
        pending = list(tr.pending(worker_endpoint(w)))
        assert len(pending) == 1             # only the latest round's share
        assert pending[0][1].round == 49


def test_scheduler_rejects_results_from_undispatched_workers():
    """A same-round result from a worker outside this attempt's dispatch
    set (stale message from an aborted pre-restore attempt, or an excluded
    straggler) must feed the monitor but never the responder trace."""
    from repro.cluster.messages import MASTER, WorkerResult
    tr = InProcessTransport()
    sched = EventScheduler(4, DeterministicLatency(base=1.0), transport=tr)
    tr.send(MASTER, WorkerResult(0, 3, 0.5), at=0.0, delay=0.5)  # stale: w3
    trace = sched.dispatch_round(0, threshold=2,
                                 workers=np.array([0, 1, 2]))
    assert 3 not in set(trace.responders)
    assert 3 not in trace.arrivals


def test_scheduler_starved_round_reports_inf():
    lat = DeadWorkerLatency(DeterministicLatency(base=1.0),
                            deaths={0: 0, 1: 0})
    sched = EventScheduler(3, lat)
    trace = sched.dispatch_round(0, threshold=2, timeout_s=50.0)
    assert math.isinf(trace.t_first_R)
    assert list(trace.responders) == [2]
    assert math.isinf(trace.t_all)


def test_starved_round_finite_deadline_parks_clock_at_deadline():
    """The master hoped until the timeout: the simulated clock must show
    the full wait, not just the last arrival it processed."""
    lat = DeadWorkerLatency(DeterministicLatency(base=1.0),
                            deaths={0: 0, 1: 0})
    sched = EventScheduler(3, lat)
    trace = sched.dispatch_round(0, threshold=2, timeout_s=50.0)
    assert math.isinf(trace.t_first_R)
    assert sched.clock == pytest.approx(50.0)


def test_starved_round_inf_deadline_parks_clock_at_monitor_timeout():
    """Regression: with timeout_s=inf the `isfinite(deadline)` guard used
    to skip parking entirely, so downstream heartbeat/recovery logic saw
    almost no elapsed time for a round the master waited out.  Pinned
    semantics: an unbounded wait ends when the (finite) failure detector
    declares the silent workers dead — park the clock there."""
    from repro.runtime.resilience import HeartbeatMonitor
    lat = DeadWorkerLatency(DeterministicLatency(base=1.0),
                            deaths={0: 0, 1: 0})
    sched = EventScheduler(3, lat)
    mon = HeartbeatMonitor(3, timeout_s=30.0, now=0.0)
    trace = sched.dispatch_round(0, threshold=2, monitor=mon)
    assert math.isinf(trace.t_first_R)
    assert sched.clock == pytest.approx(30.0)            # t0 + detector
    # ...at which instant the silent workers' staleness has reached the
    # detector's threshold (any later instant exceeds it)
    silent = [w for w in (0, 1)
              if sched.clock - mon.workers[w].last_heartbeat
              >= mon.timeout_s]
    assert silent == [0, 1]


def test_starved_round_without_any_bound_leaves_clock_at_last_delivery():
    """No deadline AND no finite failure detector: the wait is
    unsimulatable; the pinned semantics are 'clock stays at the last
    delivery' (callers wanting recovery must bound the wait)."""
    lat = DeadWorkerLatency(DeterministicLatency(base=1.0),
                            deaths={0: 0, 1: 0})
    sched = EventScheduler(3, lat)
    trace = sched.dispatch_round(0, threshold=2)
    assert math.isinf(trace.t_first_R)
    # worker 2's result at base * (1 + 0.05 * 2) was the last delivery
    assert sched.clock == pytest.approx(1.1)


# ---------------------------------------------------------------------------
# Multi-phase MPC rounds (scheduler level; runner-level in test_mpc_cluster)
# ---------------------------------------------------------------------------

def test_mpc_round_reshare_barrier_is_wait_for_all():
    """Phase 0 latencies 1..4s: NO final share moves before t=4 — the
    all-to-all barrier gates everyone on the slowest worker."""
    sched = EventScheduler(4, DeterministicLatency(base=1.0, skew=1.0))
    models = [DeterministicLatency(base=1.0, skew=1.0),
              DeterministicLatency(base=0.5, skew=0.0)]
    trace = sched.run_mpc_round(0, collect_threshold=3, phase_models=models)
    assert trace.barriers == [pytest.approx(4.0)]
    assert trace.t_done == pytest.approx(4.5)
    assert trace.t_all == pytest.approx(4.5)
    assert sched.clock == pytest.approx(4.5)
    assert sorted(map(int, trace.responders[:3])) == [0, 1, 2]


def test_mpc_round_subshares_flow_through_transport():
    """The sim enacts the reshare as real peer messages: every worker's
    inbox sees a SubShare from every worker for each phase."""
    from repro.cluster.messages import SubShare
    tr = InProcessTransport()
    sched = EventScheduler(3, DeterministicLatency(base=1.0), transport=tr)
    seen: dict[int, set] = {v: set() for v in range(3)}
    orig_recv = tr.recv

    def spy(dst, now):
        out = orig_recv(dst, now)
        for _, m in out:
            if isinstance(m, SubShare):
                seen[m.dst].add((m.phase, m.src))
        return out

    tr.recv = spy
    sched.run_mpc_round(0, collect_threshold=3,
                        phase_models=[DeterministicLatency(base=1.0)] * 3)
    for v in range(3):
        assert seen[v] == {(j, s) for j in range(2) for s in range(3)}


def test_mpc_round_dead_worker_starves_despite_live_majority():
    """One dead worker of four: three live workers exceed 2T+1 = 3, but the
    barrier never completes — BGW has no erasures."""
    models = [DeadWorkerLatency(DeterministicLatency(), {3: 0}),
              DeterministicLatency(base=0.5)]
    sched = EventScheduler(4, models[0])
    trace = sched.run_mpc_round(0, collect_threshold=3, phase_models=models,
                                timeout_s=50.0)
    assert math.isinf(trace.t_done)
    assert math.isinf(trace.barriers[0])
    assert len(trace.responders) == 0                    # nobody combined
    assert sched.clock == pytest.approx(50.0)            # waited it out


def test_scheduler_feeds_monitor_on_simulated_clock():
    from repro.runtime.resilience import HeartbeatMonitor
    mon = HeartbeatMonitor(3, timeout_s=100.0, now=0.0)
    sched = EventScheduler(3, DeterministicLatency(base=2.0, skew=0.5))
    sched.dispatch_round(0, threshold=3, monitor=mon)
    # monitor saw heartbeat acks + per-result latencies at simulated times
    assert mon.workers[2].last_heartbeat == pytest.approx(4.0)  # 2*(1+1)
    assert mon.workers[0].latency_ewma == pytest.approx(0.2 * 2.0)
    assert list(mon.survivors(now=sched.clock)) == [0, 1, 2]


# ---------------------------------------------------------------------------
# ClusterRunner: the acceptance criterion
# ---------------------------------------------------------------------------

def test_cluster_bit_identical_to_reference_20_rounds(binary_data):
    """>= 20 rounds with heavy straggler injection: exact weight equality
    between the event-driven runner and train_reference over the trace."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    lat = LognormalTailLatency(seed=3, tail_prob=0.3, tail_scale=25.0)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat)
    w_cluster = runner.run(20)

    # stragglers actually shuffled the decode order at least once
    orders = {tuple(r.survivors) for r in runner.records.values()}
    assert len(orders) > 1, "latency model produced a constant decode order"

    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=20,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w_cluster) == np.asarray(w_ref)).all()


def test_cluster_bit_identical_minibatch_multiclass(mc_data):
    """Mini-batch + multi-class: draw_batch/round_key derivations must match
    make_schedule's exactly."""
    x, y = mc_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3, batch_rows=16)
    lat = BurstyStragglerLatency(seed=5, burst_prob=0.1, slow_factor=30.0)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat)
    w_cluster = runner.run(12)
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=12,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w_cluster) == np.asarray(w_ref)).all()


def test_cluster_first_T_strictly_faster_under_tails(binary_data):
    """The paper's Fig. 5 effect in simulation: decoding at the fastest
    threshold beats waiting for all under heavy-tailed latency."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    lat = LognormalTailLatency(seed=0, tail_prob=0.2, tail_scale=10.0)
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat)
    runner.run(15)
    stats = runner.wait_stats()
    assert stats["coded_T"]["mean"] < stats["wait_all"]["mean"]


def test_cluster_dead_worker_tolerated_within_threshold(binary_data):
    """N - threshold workers can die outright; decode never needs them."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)     # threshold 7: 1 spare
    lat = DeadWorkerLatency(DeterministicLatency(base=1.0, skew=0.1),
                            deaths={5: 0})
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat)
    w = runner.run(20)
    assert all(5 not in set(r.survivors) for r in runner.records.values())
    assert all(math.isinf(r.all_wait_s) for r in runner.records.values())
    # and the result still matches the reference over the observed trace
    w_ref, _ = protocol.train_reference(cfg, jax.random.PRNGKey(7), x, y,
                                        iters=20,
                                        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()


def test_cluster_starved_round_raises(binary_data):
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)     # threshold 7
    lat = DeadWorkerLatency(DeterministicLatency(base=1.0),
                            deaths={0: 3, 1: 3})       # 6 alive < 7
    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, lat,
                           round_timeout_s=30.0)
    with pytest.raises(ClusterDecodeError):
        runner.run(10)


def test_cluster_resilient_recovers_from_worker_death(binary_data):
    """Mid-run death below the decode threshold: checkpoint restore +
    worker reprovision replays and completes the run."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    lat = DeadWorkerLatency(LognormalTailLatency(seed=5),
                            deaths={0: 4, 1: 4})
    runner = ClusterRunner(cfg, jax.random.PRNGKey(9), x, y, lat,
                           round_timeout_s=60.0)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_write=False)
        w = runner.run_resilient(12, mgr, checkpoint_every=2)
    assert runner.restarts == 1
    assert len(runner.records) == 12
    assert w.shape == (x.shape[1],)
    # post-revival rounds decode with the replacement workers available
    assert runner.records[11].n_responders >= cfg.threshold


def test_cluster_straggler_excluded_from_dispatch(binary_data):
    """A persistently slow worker gets speculatively excluded once the
    monitor's EWMA flags it (fast set still covers the threshold).

    Worker 7 takes 6s vs 1s for everyone else: its round-t result arrives
    ~5 rounds late as a STALE message, which still feeds the latency EWMA
    (a late reply is evidence of slowness, not death).  Once
    ewma_7 > straggler_factor * median the dispatch set drops it."""
    x, y = binary_data
    cfg = protocol.CPMLConfig(N=8, K=2, T=0, r=1)     # threshold 4: margin

    class OneSlow(DeterministicLatency):
        def sample(self, round, worker):
            return 6.0 if worker == 7 else 1.0

    runner = ClusterRunner(cfg, jax.random.PRNGKey(7), x, y, OneSlow(),
                           straggler_factor=3.0)
    w = runner.run(14)
    assert 7 in set(runner.records[0].dispatched)      # starts included
    assert 7 not in set(runner.records[13].dispatched)  # learned + excluded
    assert all(7 not in set(r.survivors) for r in runner.records.values())
    w_ref, _ = protocol.train_reference(
        cfg, jax.random.PRNGKey(7), x, y, iters=14,
        survivor_fn=runner.survivor_fn())
    assert (np.asarray(w) == np.asarray(w_ref)).all()
