"""runtime/resilience.py coverage: heartbeat/straggler exclusion, survivor
ordering, FailureInjector determinism, ResilientLoop retry budget."""
import numpy as np
import pytest

from repro.runtime.resilience import (
    FailureInjector,
    HeartbeatMonitor,
    ResilientLoop,
)


# ---------------------------------------------------------------------------
# HeartbeatMonitor
# ---------------------------------------------------------------------------

def test_survivors_fastest_first():
    mon = HeartbeatMonitor(4, timeout_s=10.0, now=0.0)
    for w, lat in ((0, 3.0), (1, 1.0), (2, 2.0), (3, 1.5)):
        mon.heartbeat(w, latency_s=lat, now=1.0)
    surv = mon.survivors(now=1.0)
    assert list(surv) == [1, 3, 2, 0]       # ascending latency EWMA


def test_straggler_excluded():
    mon = HeartbeatMonitor(4, timeout_s=100.0, straggler_factor=3.0, now=0.0)
    for _ in range(20):                      # converge the EWMA
        for w in range(3):
            mon.heartbeat(w, latency_s=1.0, now=1.0)
        mon.heartbeat(3, latency_s=50.0, now=1.0)
    surv = mon.survivors(now=1.0)
    assert 3 not in surv and set(surv) == {0, 1, 2}


def test_dead_worker_excluded_by_timeout_and_mark_failed():
    mon = HeartbeatMonitor(3, timeout_s=5.0, now=0.0)
    mon.heartbeat(0, latency_s=1.0, now=8.0)
    mon.heartbeat(1, latency_s=1.0, now=8.0)
    # worker 2 last heartbeated at t=0: stale at t=8
    assert 2 not in mon.survivors(now=8.0)
    mon.mark_failed(0)
    assert list(mon.survivors(now=8.0)) == [1]


def test_survivors_accepts_explicit_epoch_zero():
    """Regression: ``now=0.0`` must mean simulated epoch 0, not wall clock.

    With the old ``now = now or time.time()`` a simulated-clock caller at
    t=0 got wall time instead, making every worker look timed out."""
    mon = HeartbeatMonitor(3, timeout_s=10.0, now=0.0)
    assert len(mon.survivors(now=0.0)) == 3


def test_liveness_only_heartbeat_keeps_ewma():
    mon = HeartbeatMonitor(1, now=0.0)
    mon.heartbeat(0, latency_s=5.0, now=1.0)
    ewma = mon.workers[0].latency_ewma
    mon.heartbeat(0, now=2.0)                # liveness ack: no latency info
    assert mon.workers[0].latency_ewma == ewma
    assert mon.workers[0].last_heartbeat == 2.0


def test_revive_resets_state():
    mon = HeartbeatMonitor(2, now=0.0)
    mon.heartbeat(0, latency_s=9.0, now=1.0)
    mon.mark_failed(0)
    mon.revive(0, now=5.0)
    w = mon.workers[0]
    assert w.alive and w.latency_ewma == 0.0 and w.last_heartbeat == 5.0


# ---------------------------------------------------------------------------
# FailureInjector
# ---------------------------------------------------------------------------

def _injector_run(seed):
    mon = HeartbeatMonitor(8, now=0.0)
    inj = FailureInjector(seed=seed, fail_prob=0.1, straggle_prob=0.2)
    dead, ewmas = [], []
    for _ in range(30):
        inj.step(mon)
        dead.append(tuple(i for i, w in mon.workers.items() if not w.alive))
        ewmas.append(tuple(round(w.latency_ewma, 9)
                           for w in mon.workers.values()))
    return dead, ewmas


def test_failure_injector_deterministic_under_seed():
    assert _injector_run(123) == _injector_run(123)


def test_failure_injector_seed_changes_schedule():
    assert _injector_run(1) != _injector_run(2)


def test_failure_injector_kills_and_straggles():
    dead, ewmas = _injector_run(0)
    assert len(dead[-1]) > 0                 # somebody died over 30 steps
    # a 10s straggle beat lifts a ~1s EWMA past 2.5 (0.8*1 + 0.2*10 = 2.8)
    assert any(e > 2.5 for step in ewmas for e in step)


# ---------------------------------------------------------------------------
# ResilientLoop
# ---------------------------------------------------------------------------

class _MemCkpt:
    """Minimal in-memory stand-in for CheckpointManager."""

    def __init__(self):
        self.saved = {}

    def save(self, step, state, extra=None):
        self.saved[step] = {k: dict(v) for k, v in state.items()}

    def restore(self, step=None, shardings=None):
        step = max(self.saved) if step is None else step
        out = {"step": step}
        out.update({k: dict(v) for k, v in self.saved[step].items()})
        return out

    def wait(self):
        pass


def test_resilient_loop_recovers_and_counts_restarts():
    ckpt = _MemCkpt()
    ckpt.save(0, {"train": {"x": 0}})
    fail_at = {3}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.discard(step)            # fail once, then succeed
            raise RuntimeError("boom")
        return {"train": {"x": state["train"]["x"] + 1}}

    loop = ResilientLoop(ckpt, checkpoint_every=2, max_retries=2)
    out = loop.run({"train": {"x": 0}}, step_fn, 0, 6)
    assert out["train"]["x"] == 6            # every step replayed to done
    assert loop.restarts == 1


def test_resilient_loop_retry_budget_resets_after_success():
    """Regression: the retry budget must be per-incident, not per-run.

    4 isolated failures, each recovered and followed by successful steps,
    previously tripped ``max_retries=3`` because ``restarts`` accumulated
    over the whole run."""
    ckpt = _MemCkpt()
    ckpt.save(0, {"train": {"x": 0}})
    fail_at = {2, 4, 6, 8}                   # 4 isolated transient failures

    def step_fn(state, step):
        if step in fail_at:
            fail_at.discard(step)
            raise RuntimeError("transient")
        return {"train": {"x": state["train"]["x"] + 1}}

    loop = ResilientLoop(ckpt, checkpoint_every=1, max_retries=3)
    out = loop.run({"train": {"x": 0}}, step_fn, 0, 10)
    assert out["train"]["x"] == 10
    assert loop.restarts == 4                # observability keeps the total


def test_resilient_loop_deterministic_failure_past_checkpoint_terminates():
    """Regression: a deterministic failure at a step PAST the last
    checkpoint must still trip max_retries.  A run-wide budget that resets
    on any successful step would replay checkpoint->fail forever (the
    replayed checkpointed step succeeds each time, wiping the budget)."""
    ckpt = _MemCkpt()
    ckpt.save(0, {"train": {"x": 0}})
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        assert calls["n"] < 100, "livelock: retry budget never trips"
        if step == 3:
            raise RuntimeError("deterministic")
        return {"train": {"x": state["train"]["x"] + 1}}

    loop = ResilientLoop(ckpt, checkpoint_every=2, max_retries=2)
    with pytest.raises(RuntimeError, match="deterministic"):
        loop.run({"train": {"x": 0}}, step_fn, 0, 6)
    assert loop.restarts == 3                # 2 retries + the fatal one


def test_resilient_loop_gives_up_after_consecutive_failures():
    ckpt = _MemCkpt()
    ckpt.save(0, {"train": {"x": 0}})

    def step_fn(state, step):
        raise RuntimeError("permanent")

    loop = ResilientLoop(ckpt, checkpoint_every=1, max_retries=2)
    with pytest.raises(RuntimeError, match="permanent"):
        loop.run({"train": {"x": 0}}, step_fn, 0, 5)
    assert loop.restarts == 3                # 2 retries + the fatal one


def test_resilient_loop_on_restore_hook():
    ckpt = _MemCkpt()
    ckpt.save(0, {"train": {"x": 0}})
    seen = []
    fail_at = {1}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.discard(step)
            raise RuntimeError("boom")
        return {"train": {"x": state["train"]["x"] + 1}}

    loop = ResilientLoop(ckpt, checkpoint_every=1, max_retries=1,
                         on_restore=seen.append)
    loop.run({"train": {"x": 0}}, step_fn, 0, 3)
    assert seen == [1]                       # restored to the step-1 ckpt
