"""ALCC float engine: analog Lagrange coding, decode fallback, engine +
cluster integration, CLI refusal matrix (DESIGN.md §14)."""
import jax
import numpy as np
import pytest

from repro.cluster import ClusterRunner, make_latency
from repro.cluster.alcc_mlp import ALCCMLPRunner
from repro.cluster.alcc_mlp import train_reference as mlp_train_reference
from repro.core import alcc
from repro.core.protocol import alcc_engine
from repro.data import synthetic
from repro.launch import cpml_cluster


def _scheme(N=10, K=3, T=2, **kw):
    return alcc.AnalogScheme(N=N, K=K, T=T, **kw)


# ---------------------------------------------------------------------------
# AnalogScheme units
# ---------------------------------------------------------------------------

def test_thresholds_match_field_formulas():
    assert alcc.recovery_threshold(K=2, T=1, r=1) == 7
    assert alcc.degree_threshold(K=2, T=1, deg_f=2) == 5
    assert alcc.recovery_threshold(K=13, T=1, r=1) == 3 * 13 + 1


def test_point_sets_disjoint_and_bounded():
    s = _scheme()
    assert s.alphas.shape == (10,) and s.betas.shape == (5,)
    assert np.all(np.abs(s.betas) < s.beta_scale + 1e-12)
    both = np.concatenate([s.alphas, s.betas])
    assert np.min(np.diff(np.sort(both))) > 1e-12
    assert s.mask_points().shape == (2,)


def test_colliding_point_sets_rejected():
    """Odd-order Chebyshev sets both contain 0: N=9 alphas and K+T=3 betas
    collide at the origin regardless of beta_scale — the scheme must
    refuse rather than hand decode a singular system."""
    s = alcc.AnalogScheme(N=9, K=2, T=1)
    with pytest.raises(AssertionError, match="collide"):
        s.betas


def test_encode_decode_identity():
    s = _scheme()
    rng = np.random.default_rng(0)
    parts = rng.normal(size=(3, 4, 5))
    masks = alcc.draw_masks(jax.random.PRNGKey(1), 2, (4, 5), sigma=1.0)
    shares = alcc.encode(s, parts, masks)
    assert shares.shape == (10, 4, 5)
    dec, info = s.decode(shares, np.arange(10), deg_f=1)
    assert not info["fallback"]
    np.testing.assert_allclose(dec, parts, atol=1e-9)


@pytest.mark.parametrize("survivor_seed", [0, 1, 2, 3])
def test_decode_from_any_threshold_subset(survivor_seed):
    """ANY degree_threshold survivors suffice — the straggler property
    carries over to the reals (deg-2 elementwise square worker)."""
    s = _scheme(N=10, K=3, T=2)
    rng = np.random.default_rng(survivor_seed)
    parts = rng.normal(size=(3, 6))
    masks = alcc.draw_masks(jax.random.PRNGKey(1), 2, (6,), sigma=1.0)
    shares = alcc.encode(s, parts, masks)
    need = alcc.degree_threshold(3, 2, 2)                  # = 9 of the 10
    surv = rng.permutation(10)[:need]
    dec, info = s.decode(shares[surv] ** 2, surv, deg_f=2)
    np.testing.assert_allclose(dec, parts ** 2, atol=1e-7)
    assert info["need"] == need


def test_masks_cancel_at_any_sigma():
    """Decode error is roundoff, not mask leakage: recovery holds whether
    sigma is 0 or 100, and stays inside the published error budget when
    the worker evaluations are float32 (the real worker dtype)."""
    s_lo = _scheme(sigma=0.0)
    s_hi = _scheme(sigma=100.0)
    rng = np.random.default_rng(2)
    parts = rng.normal(size=(3, 8))
    for s in (s_lo, s_hi):
        masks = alcc.draw_masks(jax.random.PRNGKey(3), 2, (8,), s.sigma)
        results = alcc.encode(s, parts, masks).astype(np.float32)
        dec, info = s.decode(results, np.arange(10), deg_f=1)
        err = np.max(np.abs(dec - parts))
        assert err <= max(info["abs_err_budget"], 1e-12)


def test_decode_sum_matches_decode():
    s = _scheme()
    rng = np.random.default_rng(4)
    parts = rng.normal(size=(3, 7))
    masks = alcc.draw_masks(jax.random.PRNGKey(5), 2, (7,), 1.0)
    shares = alcc.encode(s, parts, masks)
    dec, _ = s.decode(shares, np.arange(10), deg_f=1)
    summed, _ = s.decode_sum(shares, np.arange(10), deg_f=1)
    np.testing.assert_allclose(summed, dec.sum(axis=0), rtol=1e-12)


def test_encode_replicated_broadcasts_value():
    s = _scheme()
    w = np.arange(6, dtype=np.float64).reshape(3, 2)
    masks = alcc.draw_masks(jax.random.PRNGKey(6), 2, (3, 2), 1.0)
    shares = alcc.encode_replicated(s, w, masks)
    dec, _ = s.decode(shares, np.arange(10), deg_f=1)
    for k in range(s.K):
        np.testing.assert_allclose(dec[k], w, atol=1e-9)


def test_decode_fallback_deterministic():
    """cond_max=0 forces the overdetermined pinv path over ALL responders;
    it must still reconstruct, flag itself, and use every row."""
    s = _scheme(cond_max=0.0)
    rng = np.random.default_rng(7)
    parts = rng.normal(size=(3, 5))
    masks = alcc.draw_masks(jax.random.PRNGKey(8), 2, (5,), 1.0)
    shares = alcc.encode(s, parts, masks)
    dec, info = s.decode(shares, np.arange(10), deg_f=1)
    assert info["fallback"] and info["rows"] == 10
    np.testing.assert_allclose(dec, parts, atol=1e-8)
    # square path at the same shapes does NOT fall back
    _, info_sq = _scheme().decode(shares, np.arange(10), deg_f=1)
    assert not info_sq["fallback"] and info_sq["rows"] == info_sq["need"]


def test_error_budget_monotone():
    assert alcc.error_budget(10.0, 2.0) == pytest.approx(
        10.0 * 2.0 * float(np.finfo(np.float32).eps))
    assert alcc.error_budget(100.0, 2.0) > alcc.error_budget(10.0, 2.0)


def test_config_below_threshold_rejected():
    with pytest.raises(AssertionError, match="recovery threshold"):
        alcc_engine.ALCCConfig(N=6, K=2, T=1)


def test_pipeline_hooks_refused():
    cfg = alcc_engine.ALCCConfig(N=8, K=2, T=1)
    with pytest.raises(RuntimeError, match="exact-engine only"):
        alcc_engine.round_fn_split(cfg, None, 0.1)()
    with pytest.raises(RuntimeError, match="exact-engine only"):
        alcc_engine.update_from_parts_fn(cfg, None, 0.1)()


# ---------------------------------------------------------------------------
# Engine + cluster integration (sim)
# ---------------------------------------------------------------------------

def _logreg_data(m=96, d=12):
    return synthetic.mnist_like(jax.random.PRNGKey(1), m=m, d=d)


def test_logistic_tracks_float_oracle():
    cfg = alcc_engine.ALCCConfig(N=8, K=2, T=1, sigma=1.0)
    key = jax.random.PRNGKey(3)
    x, y = _logreg_data()
    w, _ = alcc_engine.train_reference(cfg, key, x, y, iters=15)
    w_o = alcc_engine.float_oracle(cfg, key, x, y, iters=15)
    assert np.max(np.abs(np.asarray(w) - np.asarray(w_o))) < 1e-4


def test_cluster_runner_alcc_replays_bit_identical():
    """Sim contract: ClusterRunner(engine='alcc') is bit-exact to
    train_reference over the observed responder trace, and wait_stats
    surfaces the decode-conditioning block."""
    cfg = alcc_engine.ALCCConfig(N=8, K=2, T=1, sigma=1.0)
    key = jax.random.PRNGKey(7)
    x, y = _logreg_data()
    runner = ClusterRunner(cfg, key, x, y, make_latency("lognormal", seed=5),
                           engine="alcc")
    w = runner.run(5)
    w_ref, _ = alcc_engine.train_reference(cfg, key, x, y, 5,
                                           survivor_fn=runner.survivor_fn())
    assert np.array_equal(np.asarray(w), np.asarray(w_ref))
    stats = runner.wait_stats()
    assert {"cond", "abs_err_budget", "fallbacks"} <= set(stats["alcc"])
    assert stats["alcc"]["cond"]["mean"] > 1.0
    assert stats["alcc"]["fallbacks"]["n"] == 0.0


def test_cluster_runner_alcc_rejects_elastic_and_pipeline():
    cfg = alcc_engine.ALCCConfig(N=8, K=2, T=1)
    key = jax.random.PRNGKey(0)
    x, y = _logreg_data()
    lat = make_latency("deterministic", seed=0)
    with pytest.raises(AssertionError):
        ClusterRunner(cfg, key, x, y, lat, engine="alcc", pipeline="full")
    with pytest.raises(AssertionError):
        ClusterRunner(cfg, key, x, y, lat, engine="alcc", masters=2)


def test_mlp_runner_replays_bit_identical_and_tracks_oracle():
    cfg = alcc_engine.ALCCConfig(N=8, K=2, T=1, c=4, sigma=1.0)
    key = jax.random.PRNGKey(9)
    x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(2), m=96,
                                           d=12, c=4)
    runner = ALCCMLPRunner(cfg, key, x, y, hidden=8,
                           latency=make_latency("lognormal", seed=3),
                           eta=0.1)
    w1, w2 = runner.run(6)
    w1r, w2r, _ = mlp_train_reference(cfg, key, x, y, 8, 6, eta=0.1,
                                      survivor_fn=runner.survivor_fn())
    assert np.array_equal(np.asarray(w1), np.asarray(w1r))
    assert np.array_equal(np.asarray(w2), np.asarray(w2r))
    loss, _ = runner.metrics_now()
    w1o, w2o = alcc_engine.mlp_oracle(cfg, key, x, y, 8, 6, eta=0.1)
    loss_o, _ = alcc_engine.mlp_metrics(runner.state, w1o, w2o)
    assert abs(loss - loss_o) <= cpml_cluster.ALCC_MLP_LOSS_TOL


# ---------------------------------------------------------------------------
# CLI refusal matrix (regression: ISSUE satellite — alcc + mpc must refuse)
# ---------------------------------------------------------------------------

TINY = ["--m", "96", "--d", "12", "--iters", "2"]


@pytest.mark.parametrize("argv,fragment", [
    (["--engine", "alcc", "--protocol", "mpc"], "exact finite-field"),
    (["--model", "mlp", "--protocol", "mpc"], "mlp"),
    (["--model", "mlp"], "--engine alcc"),
    (["--engine", "alcc", "--pipeline", "full"], "pipeline"),
    (["--engine", "alcc", "--masters", "2"], "masters"),
    (["--engine", "alcc", "--spares", "1"], "spare"),
    (["--engine", "alcc", "--transport", "socket", "--wire", "v1"], "wire"),
    (["--model", "mlp", "--engine", "alcc", "--resilient"], "resilient"),
])
def test_cli_refusals(argv, fragment, capsys):
    rc = cpml_cluster.main(argv + TINY)
    assert rc == 2
    err = capsys.readouterr().err.lower()
    assert fragment.lower() in err


def test_cli_alcc_sim_smoke(capsys):
    rc = cpml_cluster.main(["--engine", "alcc", "--workers", "8"] + TINY)
    assert rc == 0
    out = capsys.readouterr().out
    assert "bit-identical" in out


def test_cli_alcc_mlp_sim_smoke(capsys):
    rc = cpml_cluster.main(["--engine", "alcc", "--model", "mlp",
                            "--workers", "8", "--hidden", "8",
                            "--classes", "4"] + TINY)
    assert rc == 0
    out = capsys.readouterr().out
    assert "bit-identical" in out
