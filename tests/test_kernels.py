"""Pallas kernel sweeps vs the pure-jnp ref.py oracles (interpret=True).

Randomized (hypothesis) coverage lives in test_kernels_properties.py behind
``pytest.importorskip`` — hypothesis is an optional dev dependency
(DESIGN.md §8); this module is fully deterministic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field, sigmoid_poly
from repro.kernels import ops, ref
from conftest import exact_modmatmul

PRIMES = [field.P, field.P30]


@pytest.mark.parametrize("p", PRIMES)
@pytest.mark.parametrize("shape", [
    (8, 16, 8), (128, 256, 128), (100, 300, 50), (1, 1, 1), (257, 129, 65),
    (64, 1000, 32),
])
def test_modmatmul_shapes(p, shape, rng):
    M, K, N = shape
    a = jnp.asarray(rng.integers(0, p, (M, K)), jnp.int32)
    b = jnp.asarray(rng.integers(0, p, (K, N)), jnp.int32)
    got = np.asarray(ops.modmatmul(a, b, p, use_pallas=True)).astype(object)
    want = exact_modmatmul(a, b, p)
    assert (got == want).all(), f"mismatch at {shape} p={p}"


@pytest.mark.parametrize("p", PRIMES)
def test_modmatmul_extreme_values(p):
    """All entries p-1 — worst case for limb overflow."""
    a = jnp.full((32, 512), p - 1, jnp.int32)
    b = jnp.full((512, 16), p - 1, jnp.int32)
    got = np.asarray(ops.modmatmul(a, b, p, use_pallas=True)).astype(object)
    assert (got == exact_modmatmul(a, b, p)).all()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_modmatmul_odd_shapes_deterministic(seed):
    """Fixed-seed stand-in for the hypothesis shape sweep."""
    rng = np.random.default_rng(seed)
    m, k, n = rng.integers(1, 80), rng.integers(1, 120), rng.integers(1, 60)
    a = jnp.asarray(rng.integers(0, field.P, (m, k)), jnp.int32)
    b = jnp.asarray(rng.integers(0, field.P, (k, n)), jnp.int32)
    got = np.asarray(ops.modmatmul(a, b, use_pallas=True)).astype(object)
    assert (got == exact_modmatmul(a, b, field.P)).all()


@pytest.mark.parametrize("p", PRIMES)
@pytest.mark.parametrize("mk,d,r", [(64, 32, 1), (300, 64, 2), (257, 96, 3),
                                    (16, 8, 1)])
def test_coded_grad_fused(p, mk, d, r, rng):
    x = jnp.asarray(rng.integers(0, p, (mk, d)), jnp.int32)
    w = jnp.asarray(rng.integers(0, p, (d, r)), jnp.int32)
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(r, 2, 4, 6, p), jnp.int32)
    got = ops.coded_grad(x, w, cbar, p, use_pallas=True)
    want = ref.coded_grad_ref(x, w, cbar, p)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("p", PRIMES)
@pytest.mark.parametrize("mk,d,c,r", [(64, 32, 3, 1), (100, 48, 10, 2),
                                      (17, 8, 2, 3)])
def test_coded_grad_multiclass_fused(p, mk, d, c, r, rng):
    """Multi-head kernel == unfused oracle, and head cls of the (d, c)
    result == the binary kernel run on that head's weight column alone."""
    x = jnp.asarray(rng.integers(0, p, (mk, d)), jnp.int32)
    w = jnp.asarray(rng.integers(0, p, (d, c, r)), jnp.int32)
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(r, 2, 4, 6, p), jnp.int32)
    got = ops.coded_grad_mc(x, w, cbar, p, use_pallas=True)
    want = ref.coded_grad_mc_ref(x, w, cbar, p)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    for cls in (0, c - 1):
        head = ops.coded_grad(x, w[:, cls, :], cbar, p, use_pallas=True)
        assert np.array_equal(np.asarray(got[:, cls]), np.asarray(head))


def test_ref_oracle_against_numpy(rng):
    """ref.py itself is validated against python-int ground truth."""
    p = field.P
    x = jnp.asarray(rng.integers(0, p, (60, 24)), jnp.int32)
    w = jnp.asarray(rng.integers(0, p, (24, 2)), jnp.int32)
    cbar = jnp.asarray(sigmoid_poly.quantized_coeffs(2, 2, 4, 6, p), jnp.int32)
    got = np.asarray(ref.coded_grad_ref(x, w, cbar, p)).astype(object)
    xo = np.asarray(x).astype(object)
    wo = np.asarray(w).astype(object)
    z = (xo @ wo) % p
    s = (int(cbar[0]) + int(cbar[1]) * z[:, 0] + int(cbar[2]) * z[:, 0] * z[:, 1]) % p
    want = (xo.T @ s) % p
    assert (got == want).all()


def test_block_shape_invariance(rng):
    """Kernel output independent of BlockSpec tiling choices."""
    from repro.kernels import modmatmul as mm
    p = field.P
    a = jnp.asarray(rng.integers(0, p, (100, 200)), jnp.int32)
    b = jnp.asarray(rng.integers(0, p, (200, 70)), jnp.int32)
    outs = [np.asarray(mm.modmatmul(a, b, p, bm=bm, bn=bn, bk=bk,
                                    interpret=True))
            for bm, bn, bk in [(32, 32, 64), (128, 128, 256), (16, 64, 32)]]
    assert all(np.array_equal(outs[0], o) for o in outs[1:])
