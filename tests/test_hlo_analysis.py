"""Trip-count-aware HLO analyzer (the roofline engine)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def test_scan_trip_count_multiplication():
    """flops of a scanned matmul must scale with scan length."""
    def make(L):
        def f(ws, x):
            def body(c, w):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, ws)
            return y
        ws = jax.ShapeDtypeStruct((L, 32, 32), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
        comp = jax.jit(f).lower(ws, x).compile()
        return H.analyze(comp.as_text())["flops"]

    f4, f16 = make(4), make(16)
    expected4 = 4 * 2 * 8 * 32 * 32
    assert f4 == pytest.approx(expected4, rel=0.01)
    assert f16 == pytest.approx(4 * f4, rel=0.01)


def test_plain_dot_flops():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((128, 32), jnp.bfloat16)
    comp = jax.jit(f).lower(a, b).compile()
    res = H.analyze(comp.as_text())
    assert res["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ c2), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    comp = jax.jit(f).lower(x).compile()
    res = H.analyze(comp.as_text())
    want = 5 * 3 * 2 * 16 * 16 * 16
    assert res["flops"] == pytest.approx(want, rel=0.05)


def test_shape_bytes_parsing():
    assert H._sig_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert H._sig_bytes("(f32[8,8], s32[])") == 8 * 8 * 4 + 4
    assert H._sig_bytes("pred[]") == 1
    # attr braces must not be parsed as shapes
    assert H._sig_bytes("dimensions={1,0}") == 0


def test_top_ops_drilldown():
    def f(ws, x):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    comp = jax.jit(f).lower(ws, x).compile()
    res = H.analyze(comp.as_text(), top_k=5)
    assert len(res["top_ops"]) == 5
    assert res["top_ops"][0]["effective_bytes"] >= \
        res["top_ops"][-1]["effective_bytes"]
