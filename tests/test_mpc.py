"""BGW/Shamir MPC baseline (paper A.5): primitives + trajectory parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field, mpc_baseline as mpc, protocol
from repro.data import synthetic


def test_requires_honest_majority():
    with pytest.raises(AssertionError):
        mpc.MPCConfig(N=6, T=3)


def test_share_reconstruct(key):
    cfg = mpc.MPCConfig(N=7, T=3)
    v = jax.random.randint(key, (4, 6), 0, field.P, dtype=jnp.int32)
    sh = mpc.share(cfg, key, v)
    assert sh.shape == (7, 4, 6)
    rec = mpc.reconstruct(cfg, sh, cfg.T)
    assert np.array_equal(np.asarray(rec), np.asarray(v))


def test_t_shares_reveal_nothing_statistically(key):
    """A single share of constant data should look uniform over F_p."""
    cfg = mpc.MPCConfig(N=5, T=2)
    v = jnp.ones((512,), jnp.int32)
    sh = mpc.share(cfg, key, v)
    vals = np.asarray(sh[0]).astype(np.float64) / field.P
    assert abs(vals.mean() - 0.5) < 0.05
    assert abs(vals.var() - 1 / 12) < 0.02


def test_multiplication_with_degree_reduction(key):
    cfg = mpc.MPCConfig(N=7, T=3)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.randint(k1, (8,), 0, field.P, dtype=jnp.int32)
    b = jax.random.randint(k2, (8,), 0, field.P, dtype=jnp.int32)
    sa, sb = mpc.share(cfg, k1, a), mpc.share(cfg, k2, b)
    prod = field.mulmod(sa, sb, field.P)          # degree 2T
    red = mpc.degree_reduce(cfg, k3, prod)        # back to degree T
    rec = mpc.reconstruct(cfg, red, cfg.T)
    assert np.array_equal(np.asarray(rec),
                          np.asarray(field.mulmod(a, b, field.P)))


def test_mpc_matches_cpml_trajectory():
    """Same quantization + surrogate => (near-)identical training curves.
    Differences come only from independent stochastic weight draws."""
    x, y = synthetic.mnist_like(jax.random.PRNGKey(42), m=400, d=30)
    mcfg = mpc.MPCConfig(N=7, T=3, r=1)
    ccfg = protocol.CPMLConfig(N=7, K=2, T=1, r=1)
    _, mh = mpc.train(mcfg, jax.random.PRNGKey(7), x, y, iters=6, eval_every=6)
    _, ch = protocol.train(ccfg, jax.random.PRNGKey(7), x, y, iters=6,
                           eval_every=6)
    assert abs(mh[-1]["loss"] - ch[-1]["loss"]) < 2e-3
