"""Wire-format round trips + malformed-input rejection (cluster/wire.py).

Every message type the socket transport carries must survive
serialize->deserialize bit-for-bit: field arrays in [0, p) for BOTH primes,
multi-head (d, c) payloads, empty/None payloads, and exact python-int
matrices (the host Lagrange solves produce arbitrary-precision ints that a
64-bit truncation would silently corrupt).  Malformed or truncated frames
must raise WireError immediately — a corrupt peer may never hang the
master.  Property-based coverage lives in tests/test_wire_properties.py.
"""
import numpy as np
import pytest

from repro.cluster import wire
from repro.cluster.messages import (CombineResult, EncodeShare, Heartbeat,
                                    SubShare, WorkerResult)
from repro.core import field


def roundtrip(msg):
    out = wire.deserialize(wire.serialize(msg))
    assert wire.messages_equal(out, msg), f"{out!r} != {msg!r}"
    return out


@pytest.mark.parametrize("p", [field.P, field.P30])
def test_worker_result_field_array_roundtrip(p):
    rng = np.random.default_rng(0)
    payload = rng.integers(0, p, size=(33, 1), dtype=np.int64).astype(np.int32)
    out = roundtrip(WorkerResult(7, 3, 0.125, payload))
    assert out.payload.dtype == np.int32
    assert (out.payload == payload).all()
    assert (0 <= out.payload).all() and (out.payload < p).all()


def test_multi_head_payload_roundtrip():
    rng = np.random.default_rng(1)
    payload = rng.integers(0, field.P, size=(17, 5)).astype(np.int32)
    out = roundtrip(WorkerResult(0, 0, 1.0, payload))
    assert out.payload.shape == (17, 5)


def test_encode_share_share_plus_batch_roundtrip():
    rng = np.random.default_rng(2)
    msg = EncodeShare(4, 2, {
        "w_share": rng.integers(0, field.P, size=(8, 3, 2)).astype(np.int32),
        "batch": np.arange(16, dtype=np.int32),
    })
    roundtrip(msg)


def test_none_and_empty_payloads_roundtrip():
    roundtrip(EncodeShare(0, 0, None))
    roundtrip(WorkerResult(0, 0, 0.0, None))
    out = roundtrip(WorkerResult(0, 0, 0.0, np.zeros((0, 4), np.int32)))
    assert out.payload.shape == (0, 4)
    roundtrip(EncodeShare(0, 0, {}))
    roundtrip(EncodeShare(0, 0, []))


def test_heartbeat_and_hello_roundtrip():
    roundtrip(Heartbeat(5, 123.456))
    roundtrip(wire.Hello("worker/5"))


@pytest.mark.parametrize("p", [field.P, field.P30])
def test_subshare_field_array_roundtrip(p):
    """The MPC reshare unit for BOTH primes: (m, r) degree-T sub-shares."""
    rng = np.random.default_rng(3)
    payload = rng.integers(0, p, size=(19, 2), dtype=np.int64).astype(np.int32)
    out = roundtrip(SubShare(6, 1, src=2, dst=5, payload=payload))
    assert (out.round, out.phase, out.src, out.dst) == (6, 1, 2, 5)
    assert out.payload.dtype == np.int32
    assert (0 <= out.payload).all() and (out.payload < p).all()


def test_combine_result_roundtrip():
    rng = np.random.default_rng(4)
    payload = rng.integers(0, field.P, size=(13,)).astype(np.int32)
    out = roundtrip(CombineResult(9, 4, 0.75, payload))
    assert (out.round, out.worker, out.compute_s) == (9, 4, 0.75)
    assert (out.payload == payload).all()
    roundtrip(CombineResult(0, 0, 0.0, None))


def test_forward_envelope_roundtrip_and_rejection():
    inner = wire.serialize(SubShare(1, 0, 0, 3, np.arange(6, dtype=np.int32)))
    out = roundtrip(wire.Forward("worker/3", inner))
    assert wire.messages_equal(wire.deserialize(out.frame),
                               wire.deserialize(inner))
    # a Forward whose fields are the wrong types is malformed, not garbage
    bad = wire.serialize(wire.Forward("worker/3", inner))
    # surgically corrupt: re-encode with an int dst via the raw encoder
    out_parts = [bytes([0x15])]
    wire._enc_value(7, out_parts)          # dst must be str
    wire._enc_value(b"xx", out_parts)
    body = b"".join(out_parts)
    with pytest.raises(wire.WireError, match="Forward"):
        wire.deserialize(wire._enc_u32(len(body)) + body)
    assert wire.deserialize(bad) is not None   # the intact one still decodes


def test_exact_python_int_matrix_roundtrip():
    # decode-matrix entries from the exact host solve exceed 64 bits before
    # reduction; the wire must carry them at full precision
    big = field.P ** 5
    mat = np.array([[big, -big - 1], [0, 1]], dtype=object)
    out = roundtrip(WorkerResult(0, 0, 0.0, mat))
    assert out.payload.dtype == object
    assert out.payload[0, 0] == big and out.payload[0, 1] == -big - 1
    assert isinstance(out.payload[0, 0], int)


def test_nested_value_tree_roundtrip():
    roundtrip(EncodeShare(1, 1, {
        "nested": [1, -2, 2.5, float("inf"), True, False, None, "s", b"b",
                   (1.5, 7)],
        "arr": np.linspace(0, 1, 7, dtype=np.float64),
    }))


def test_numpy_scalars_canonicalize_to_python():
    # scalar TYPES are not part of the wire vocabulary, their values are
    assert wire.deserialize(wire.serialize(np.int64(7))) == 7
    assert isinstance(wire.deserialize(wire.serialize(np.int64(7))), int)
    assert wire.deserialize(wire.serialize(np.float32(1.5))) == 1.5
    assert isinstance(wire.deserialize(wire.serialize(np.float32(1.5))), float)


def test_raw_values_roundtrip():
    # the transport contract tests ship plain values, not protocol messages
    for v in ["hello", 42, 3.5, None, [1, "two"], {"k": b"v"}]:
        assert wire.values_equal(wire.deserialize(wire.serialize(v)), v)


# ---------------------------------------------------------------------------
# Malformed input: clear errors, never hangs, never garbage
# ---------------------------------------------------------------------------

def _frame():
    return wire.serialize(WorkerResult(1, 2, 0.5,
                                       np.arange(12, dtype=np.int32)))


def test_truncated_frame_rejected():
    data = _frame()
    for cut in (1, 3, len(data) // 2, len(data) - 1):
        with pytest.raises(wire.WireError):
            wire.deserialize(data[:cut])


def test_trailing_garbage_rejected():
    with pytest.raises(wire.WireError):
        wire.deserialize(_frame() + b"\x00")


def test_corrupt_tag_rejected():
    data = bytearray(_frame())
    data[4] = 0xEE                          # unknown frame tag
    with pytest.raises(wire.WireError, match="frame tag"):
        wire.deserialize(bytes(data))


def test_absurd_length_prefix_rejected():
    with pytest.raises(wire.WireError, match="MAX_FRAME_BYTES"):
        wire.deserialize(b"\xff\xff\xff\xff" + b"x")
    r = wire.FrameReader()
    with pytest.raises(wire.WireError, match="MAX_FRAME_BYTES"):
        r.feed(b"\xff\xff\xff\xff")


def test_corrupt_ndarray_dtype_rejected():
    # corrupt the dtype string inside an ndarray value: still WireError,
    # never a raw numpy TypeError/UnicodeDecodeError
    frame = bytearray(wire.serialize(np.arange(4, dtype=np.int32)))
    i = bytes(frame).index(b"<i4")
    for bad in (b"zz9", b"\xff\xfe\xfd"):
        frame[i: i + 3] = bad
        with pytest.raises(wire.WireError, match="ndarray"):
            wire.deserialize(bytes(frame))


def test_unencodable_values_rejected():
    with pytest.raises(wire.WireError):
        wire.serialize({"bad": object()})
    with pytest.raises(wire.WireError, match="keys must be str"):
        wire.serialize({1: "x"})
    with pytest.raises(wire.WireError, match="only hold ints"):
        wire.serialize(np.array([object()], dtype=object))


def test_frame_reader_reassembles_partial_feeds():
    msgs = [EncodeShare(t, t % 3, {"w_share":
                                   np.full((4, 1, 1), t, np.int32)})
            for t in range(5)]
    stream = b"".join(wire.serialize(m) for m in msgs)
    reader = wire.FrameReader()
    got = []
    for i in range(0, len(stream), 7):      # drip-feed 7 bytes at a time
        got += reader.feed(stream[i: i + 7])
    assert len(got) == 5
    for a, b in zip(got, msgs):
        assert wire.messages_equal(a, b)


# ---------------------------------------------------------------------------
# Wire v2 (DESIGN.md §10): packed arrays, coalesced round frames, HELLO2
# negotiation, iovec emission — every v2 frame decodes messages_equal to its
# v1 twin, and a v1 reader rejects v2 tags like any real v1 build would.
# ---------------------------------------------------------------------------

def roundtrip_v2(msg):
    out = wire.deserialize(wire.serialize(msg, wire.WIRE_V2))
    assert wire.messages_equal(out, msg), f"{out!r} != {msg!r}"
    return out


@pytest.mark.parametrize("p", [field.P, field.P30])
def test_v2_field_array_roundtrip_and_width(p):
    """Shares under the 24-bit P pack to 3 bytes/element; P30 values above
    2^24 are ineligible and ship raw — decoded bits identical either way."""
    from repro.core import quantize
    rng = np.random.default_rng(5)
    payload = rng.integers(0, p, size=(64, 3), dtype=np.int64).astype(np.int32)
    v1 = wire.serialize(WorkerResult(3, 1, 0.25, payload), wire.WIRE_V1)
    v2 = wire.serialize(WorkerResult(3, 1, 0.25, payload), wire.WIRE_V2)
    out = roundtrip_v2(WorkerResult(3, 1, 0.25, payload))
    assert out.payload.dtype == np.int32 and (out.payload == payload).all()
    if int(payload.max()) < 1 << 24:
        assert quantize.wire_itemsize(p) == 3
        assert len(v2) < len(v1)          # 3 bytes/elem beats 4
    elif quantize.wire_itemsize(p) == 4:
        assert len(v2) == len(v1)         # no narrowing available: raw


def test_v2_packing_is_lossless_at_range_edges():
    edges = np.array([0, 1, 255, 256, 65535, 65536, (1 << 24) - 1],
                     dtype=np.int32)
    assert (roundtrip_v2(WorkerResult(0, 0, 0.0, edges)).payload
            == edges).all()
    # one value at 2^24 pushes the whole array out of packing eligibility
    over = np.array([0, 1 << 24], dtype=np.int32)
    assert (roundtrip_v2(WorkerResult(0, 0, 0.0, over)).payload == over).all()
    # negatives are never packed (field values are non-negative by
    # construction, but the encoder must not corrupt arbitrary int32)
    neg = np.array([-1, 5], dtype=np.int32)
    assert (roundtrip_v2(WorkerResult(0, 0, 0.0, neg)).payload == neg).all()


def test_v2_coalesced_round_frame_roundtrip():
    rng = np.random.default_rng(6)
    payload = {"w_share": rng.integers(0, field.P, (20, 1, 1)).astype(np.int32),
               "batch": np.arange(16, dtype=np.int32),
               "next_batch": None}
    msg = EncodeShare(7, 3, payload)
    frame = wire.serialize(msg, wire.WIRE_V2)
    assert frame[4] == 0x19                  # the ROUND frame tag
    out = wire.deserialize(frame)
    assert wire.messages_equal(out, msg)
    assert out.payload["next_batch"] is None
    # smaller than the generic v1 dict encoding of the same message
    assert len(frame) < len(wire.serialize(msg, wire.WIRE_V1))
    # a payload dict with OTHER keys (provisioning) stays a generic frame
    prov = EncodeShare(-1, 0, {"cfg": {"N": 5}, "x_share":
                               np.ones((4, 2), np.int32)})
    assert wire.serialize(prov, wire.WIRE_V2)[4] == 0x10
    roundtrip_v2(prov)


def test_v1_reader_rejects_v2_tags():
    """A true v1 peer sees v2 tags as unknown garbage: WireError, not a
    misparse — for the packed value, the coalesced frame, and HELLO2."""
    packed = wire.serialize(WorkerResult(0, 0, 0.0,
                                         np.arange(9, dtype=np.int32)),
                            wire.WIRE_V2)
    coalesced = wire.serialize(
        EncodeShare(1, 0, {"w_share": np.ones((2, 1, 1), np.int32),
                           "batch": None, "next_batch": None}),
        wire.WIRE_V2)
    hello2 = wire.serialize(wire.Hello("worker/1", wire.WIRE_V2),
                            wire.WIRE_V2)
    for frame in (packed, coalesced, hello2):
        wire.deserialize(frame)              # a v2 reader is fine with it
        with pytest.raises(wire.WireError, match="v1 stream"):
            wire.deserialize(frame, wire.WIRE_V1)
        r1 = wire.FrameReader(version=wire.WIRE_V1)
        with pytest.raises(wire.WireError):
            r1.feed(frame)


def test_hello_negotiation_encoding():
    # v2 x v2 -> HELLO2 carries the version
    out = wire.deserialize(wire.serialize(wire.Hello("worker/2", 2), 2))
    assert out.version == 2 and out.endpoint == "worker/2"
    # a v1 WIRE cannot express a version: encoding a v2 Hello at v1 falls
    # back to plain HELLO and decodes as a v1 peer — the safe default
    out = wire.deserialize(wire.serialize(wire.Hello("worker/2", 2), 1))
    assert out.version == 1
    # plain HELLO from a real v1 build decodes as version 1 on a v2 reader
    out = wire.deserialize(wire.serialize(wire.Hello("worker/2", 1), 2))
    assert out.version == 1


def test_serialize_iovec_matches_serialize():
    rng = np.random.default_rng(7)
    msgs = [
        WorkerResult(1, 2, 0.5, rng.integers(0, field.P,
                                             (100, 2)).astype(np.int32)),
        EncodeShare(2, 0, {"w_share": rng.integers(0, field.P,
                                                   (64, 1, 1)).astype(np.int32),
                           "batch": np.arange(32, dtype=np.int32),
                           "next_batch": np.arange(32, dtype=np.int32)}),
        wire.Hello("worker/0", 2),
        Heartbeat(3, 1.25),
    ]
    for version in (wire.WIRE_V1, wire.WIRE_V2):
        for msg in msgs:
            bufs = wire.serialize_iovec(msg, version)
            assert b"".join(bufs) == wire.serialize(msg, version)
            assert wire.iovec_nbytes(bufs) == len(wire.serialize(msg, version))
    # large array bodies ride as memoryviews (zero-copy), not joined bytes
    bufs = wire.serialize_iovec(msgs[0], wire.WIRE_V2)
    assert any(isinstance(b, memoryview) for b in bufs)


def test_v2_truncation_and_corruption_parity_with_v1():
    """The fail-loud contract holds for v2 frames exactly as for v1."""
    msg = EncodeShare(5, 1, {"w_share": np.arange(24, dtype=np.int32)
                             .reshape(8, 3), "batch": None,
                             "next_batch": None})
    frame = wire.serialize(msg, wire.WIRE_V2)
    for cut in (1, 3, len(frame) // 2, len(frame) - 1):
        with pytest.raises(wire.WireError):
            wire.deserialize(frame[:cut])
    with pytest.raises(wire.WireError):
        wire.deserialize(frame + b"\x00")
    bad = bytearray(frame)
    bad[4] = 0xEE
    with pytest.raises(wire.WireError, match="frame tag"):
        wire.deserialize(bytes(bad))
    # corrupt packed itemsize byte: the value-layer guard fires
    packed = wire.serialize(np.arange(10, dtype=np.int32), wire.WIRE_V2)
    assert packed[5] == 0x0C                # RAW tag, then PACKED value
    bad = bytearray(packed)
    bad[6] = 9                              # itemsize must be 1..3
    with pytest.raises(wire.WireError, match="itemsize"):
        wire.deserialize(bytes(bad))


# ---------------------------------------------------------------------------
# Traced results: the optional TRACE field (DESIGN.md §11) is v2-only
# ---------------------------------------------------------------------------

def _spans():
    return [["recv", 0.001, 0.002], ["compute", 0.002, 0.075],
            ["serialize", 0.075, 0.080]]


def test_traced_worker_result_v2_roundtrip():
    payload = np.arange(12, dtype=np.int32).reshape(4, 3)
    msg = WorkerResult(3, 1, 0.5, payload, trace=_spans())
    frame = wire.serialize(msg, wire.WIRE_V2)
    assert frame[4] == 0x1A                  # the traced-result tag
    out = wire.deserialize(frame)
    assert wire.messages_equal(out, msg)
    assert out.trace == _spans()


def test_traced_combine_result_v2_roundtrip():
    msg = CombineResult(2, 4, 0.25, np.arange(7, dtype=np.int32),
                        trace=_spans() + [["barrier", 0.08, 0.3]])
    frame = wire.serialize(msg, wire.WIRE_V2)
    assert frame[4] == 0x1B
    out = wire.deserialize(frame)
    assert wire.messages_equal(out, msg)
    assert out.trace[-1] == ["barrier", 0.08, 0.3]


def test_v1_serialization_silently_drops_trace():
    """A v1 peer's wire cannot express the trace field: serializing at v1
    produces the CLASSIC result frame with the trace absent — this is what
    makes a mixed v1 fleet round-trip with worker traces silently missing
    instead of failing (same negotiation shape as HELLO2)."""
    traced = WorkerResult(3, 1, 0.5, np.arange(4, dtype=np.int32),
                          trace=_spans())
    bare = WorkerResult(3, 1, 0.5, np.arange(4, dtype=np.int32))
    f1 = wire.serialize(traced, wire.WIRE_V1)
    assert f1 == wire.serialize(bare, wire.WIRE_V1)
    out = wire.deserialize(f1)
    assert out.trace is None
    # same for the MPC result
    tc = CombineResult(0, 0, 0.0, None, trace=_spans())
    assert wire.deserialize(wire.serialize(tc, wire.WIRE_V1)).trace is None
    # and a trace-less message stays a classic frame even at v2
    assert wire.serialize(bare, wire.WIRE_V2)[4] != 0x1A


def test_traced_frame_rejected_on_v1_stream():
    frame = wire.serialize(WorkerResult(0, 0, 0.0, None, trace=_spans()),
                           wire.WIRE_V2)
    with pytest.raises(wire.WireError, match="v1 stream"):
        wire.deserialize(frame, wire.WIRE_V1)


def test_traced_iovec_matches_serialize():
    msg = WorkerResult(1, 2, 0.5, np.arange(30, dtype=np.int32),
                       trace=_spans())
    for version in (wire.WIRE_V1, wire.WIRE_V2):
        bufs = wire.serialize_iovec(msg, version)
        assert b"".join(bufs) == wire.serialize(msg, version)


# ---------------------------------------------------------------------------
# Elastic membership frames (wire v2 only, DESIGN.md §13)
# ---------------------------------------------------------------------------

def test_join_frame_roundtrip_v2():
    from repro.cluster.messages import Join
    out = roundtrip_v2(Join(worker=8, at_round=5, sent_at=12.25))
    assert (out.worker, out.at_round, out.sent_at) == (8, 5, 12.25)


def test_epoch_frame_roundtrip_v2():
    from repro.cluster.messages import Epoch
    out = roundtrip_v2(Epoch(epoch=3, members=(0, 1, 2, 8), round=7))
    assert out.epoch == 3 and out.round == 7
    assert tuple(out.members) == (0, 1, 2, 8)
    # empty / None member lists survive too (informational fan-out)
    assert roundtrip_v2(Epoch(epoch=0, members=None)).members is None


def test_membership_frames_cannot_be_spoken_at_v1():
    """Elastic membership is a v2 protocol: serializing either frame for a
    v1 peer is a caller bug (the master must SKIP v1 peers, whose byte
    stream stays bit-identical to the fixed fleet) — fail loud, and a v1
    reader must reject the v2 tags rather than misparse them."""
    from repro.cluster.messages import Epoch, Join
    with pytest.raises(wire.WireError, match="v1 fleet"):
        wire.serialize(Join(0, 1), wire.WIRE_V1)
    with pytest.raises(wire.WireError, match="v1 peers"):
        wire.serialize(Epoch(1, (0, 1)), wire.WIRE_V1)
    for msg in (Join(0, 1), Epoch(1, (0, 1))):
        frame = wire.serialize(msg, wire.WIRE_V2)
        with pytest.raises(wire.WireError, match="v1 stream"):
            wire.deserialize(frame, wire.WIRE_V1)
        r1 = wire.FrameReader(version=wire.WIRE_V1)
        with pytest.raises(wire.WireError):
            r1.feed(frame)


# ---------------------------------------------------------------------------
# ALCC float frames (wire v2 only, DESIGN.md §14)
# ---------------------------------------------------------------------------

def test_alcc_float_round_frame_roundtrip_v2():
    rng = np.random.default_rng(8)
    payload = {"w_share": rng.normal(size=(6, 2)).astype(np.float32),
               "batch": np.arange(5, dtype=np.int32),
               "next_batch": None}
    msg = EncodeShare(4, 2, payload)
    frame = wire.serialize(msg, wire.WIRE_V2)
    assert frame[4] == 0x20                  # the float ROUND frame tag
    out = wire.deserialize(frame)
    assert wire.messages_equal(out, msg)
    assert out.payload["w_share"].dtype == np.float32
    assert out.payload["batch"].dtype == np.int32
    assert out.payload["next_batch"] is None


def test_alcc_float_result_roundtrip_v2():
    rng = np.random.default_rng(9)
    payload = rng.normal(size=(3, 7)).astype(np.float32)
    bare = WorkerResult(6, 1, 0.25, payload)
    frame = wire.serialize(bare, wire.WIRE_V2)
    assert frame[4] == 0x21                  # the float RESULT frame tag
    out = wire.deserialize(frame)
    assert wire.messages_equal(out, bare)
    assert out.payload.dtype == np.float32 and out.trace is None
    # the traced variant rides the same frame with the marker byte set
    traced = WorkerResult(6, 1, 0.25, payload,
                          trace=[["compute", 0.0, 0.2]])
    tf = wire.serialize(traced, wire.WIRE_V2)
    assert tf[4] == 0x21
    tout = wire.deserialize(tf)
    assert wire.messages_equal(tout, traced)
    assert tout.trace == [["compute", 0.0, 0.2]]


def test_alcc_float_frames_cannot_be_spoken_at_v1():
    """Like Join/Epoch and TRACE: v1 has no float frame to downgrade to.
    Serializing for a v1 peer fails loud at the sender (a mixed fleet must
    not silently run ALCC), and a genuine v1 reader rejects the v2 tags
    rather than misparsing them."""
    rng = np.random.default_rng(10)
    fround = EncodeShare(1, 0, {"w_share":
                                rng.normal(size=(2, 1)).astype(np.float32),
                                "batch": None, "next_batch": None})
    fresult = WorkerResult(1, 0, 0.0, np.zeros((2, 2), np.float32))
    for msg in (fround, fresult):
        with pytest.raises(wire.WireError, match="wire v2"):
            wire.serialize(msg, wire.WIRE_V1)
        frame = wire.serialize(msg, wire.WIRE_V2)
        with pytest.raises(wire.WireError, match="v1 stream"):
            wire.deserialize(frame, wire.WIRE_V1)
        r1 = wire.FrameReader(version=wire.WIRE_V1)
        with pytest.raises(wire.WireError):
            r1.feed(frame)


def test_alcc_float_iovec_matches_serialize():
    rng = np.random.default_rng(11)
    msgs = [EncodeShare(2, 3, {"w_share":
                               rng.normal(size=(4, 2)).astype(np.float32),
                               "batch": np.arange(3, dtype=np.int32),
                               "next_batch": None}),
            WorkerResult(2, 3, 0.5, rng.normal(size=(5,)
                                               ).astype(np.float32),
                         trace=[["compute", 0.1, 0.2]])]
    for msg in msgs:
        bufs = wire.serialize_iovec(msg, wire.WIRE_V2)
        assert b"".join(bufs) == wire.serialize(msg, wire.WIRE_V2)


def test_alcc_float_frame_reader_reassembles_chunks():
    rng = np.random.default_rng(12)
    msg = WorkerResult(9, 4, 0.125, rng.normal(size=(64, 3)
                                               ).astype(np.float32))
    frame = wire.serialize(msg, wire.WIRE_V2)
    reader = wire.FrameReader(version=wire.WIRE_V2)
    got = []
    for i in range(0, len(frame), 7):
        got.extend(reader.feed(frame[i:i + 7]))
    assert len(got) == 1 and wire.messages_equal(got[0], msg)


def test_alcc_float_provision_payload_stays_generic():
    """Float x_share in a PROVISION payload (round -1, other keys) rides
    the generic dict frame at ANY version — only round-eligible frames get
    the dedicated float encoding."""
    prov = EncodeShare(-1, 0, {"cfg": {"N": 8},
                               "x_share": np.ones((4, 2), np.float32)})
    for version in (wire.WIRE_V1, wire.WIRE_V2):
        frame = wire.serialize(prov, version)
        assert frame[4] == 0x10              # generic ENCODE_SHARE tag
        out = wire.deserialize(frame, version)
        assert wire.messages_equal(out, prov)
        assert out.payload["x_share"].dtype == np.float32
