"""Deterministic tests for the F_p arithmetic layer.

Property-based (randomized) coverage of the same laws lives in
test_field_properties.py behind ``pytest.importorskip("hypothesis")`` —
hypothesis is an OPTIONAL dev dependency (see DESIGN.md §8); everything here
runs without it.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field
from conftest import exact_modmatmul

PRIMES = [field.P, field.P30]


@pytest.mark.parametrize("p", PRIMES)
def test_ring_laws_deterministic(p):
    """addmod/submod/mulmod + distributivity on a fixed worst-case triple."""
    cases = [(0, 0, 0), (1, p - 1, 1), (p - 1, p - 1, p - 1),
             (p // 2, p // 2 + 1, 3), (12345, 67890, p - 2)]
    for a, b, c in cases:
        A, B, C = (jnp.int32(x) for x in (a, b, c))
        assert int(field.addmod(A, B, p)) == (a + b) % p
        assert int(field.submod(A, B, p)) == (a - b) % p
        assert int(field.mulmod(A, B, p)) == (a * b) % p
        lhs = field.mulmod(A, field.addmod(B, C, p), p)
        rhs = field.addmod(field.mulmod(A, B, p), field.mulmod(A, C, p), p)
        assert int(lhs) == int(rhs)


@pytest.mark.parametrize("p", PRIMES)
def test_inverse_and_pow_deterministic(p):
    for a in (1, 2, p - 1, p // 3):
        A = jnp.int32(a)
        assert int(field.mulmod(field.invmod(A, p), A, p)) == 1
        for e in (0, 1, 2, 17, 50):
            assert int(field.powmod(A, e, p)) == pow(a, e, p)


@pytest.mark.parametrize("p", PRIMES)
def test_signed_roundtrip(p):
    half = (p - 1) // 2
    vals = jnp.array([-half, -1, 0, 1, half - 1], jnp.int32)
    assert (field.to_signed(field.from_signed(vals, p), p) == vals).all()


@pytest.mark.parametrize("p", PRIMES)
@pytest.mark.parametrize("shape", [(3, 4, 5), (17, 33, 9), (1, 300, 2),
                                   (64, 64, 64)])
def test_matmul_exact(p, shape, rng):
    M, K, N = shape
    a = jnp.asarray(rng.integers(0, p, (M, K)), jnp.int32)
    b = jnp.asarray(rng.integers(0, p, (K, N)), jnp.int32)
    got = np.asarray(field.matmul(a, b, p)).astype(object)
    want = exact_modmatmul(a, b, p)
    assert (got == want).all()


def test_matmul_large_contraction(rng):
    """Contraction > chunk: the chunked path must still be exact."""
    p = field.P30
    a = jnp.asarray(rng.integers(0, p, (4, 40000)), jnp.int32)
    b = jnp.asarray(rng.integers(0, p, (40000, 3)), jnp.int32)
    got = np.asarray(field.matmul(a, b, p)).astype(object)
    assert (got == exact_modmatmul(a, b, p)).all()


def test_host_lagrange_matches_interpolation():
    """U columns must evaluate the interpolant: sum_i f(beta_i) U[i,j] = f(alpha_j)
    for any polynomial of degree < K+T (take f = monomials)."""
    p = field.P
    betas = np.arange(1, 6)       # K+T = 5
    alphas = np.arange(6, 10)
    U = field.host_lagrange_coeffs(alphas, betas, p)
    for deg in range(5):
        fb = np.array([pow(int(b), deg, p) for b in betas], dtype=object)
        fa = (fb @ U.astype(object)) % p
        want = np.array([pow(int(a), deg, p) for a in alphas], dtype=object)
        assert (fa == want).all()


def test_vandermonde_inv():
    p = field.P
    pts = np.array([2, 5, 9, 11])
    Vinv = field.host_vandermonde_inv(pts, p)
    V = np.array([[pow(int(x), j, p) for j in range(4)] for x in pts],
                 dtype=object)
    eye = (V @ Vinv.astype(object)) % p
    assert (eye == np.eye(4, dtype=object)).all()
