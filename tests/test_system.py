"""End-to-end system behaviour: drivers, paper-reproduction invariants."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_train_driver_reduced(tmp_path):
    from repro.launch import train
    rc = train.main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "8",
                     "--batch", "4", "--seq", "32", "--log-every", "100",
                     "--checkpoint-dir", str(tmp_path)])
    assert rc == 0


def test_train_driver_resume(tmp_path):
    from repro.launch import train
    train.main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "6",
                "--batch", "2", "--seq", "32", "--checkpoint-every", "3",
                "--checkpoint-dir", str(tmp_path), "--log-every", "100"])
    rc = train.main(["--arch", "tinyllama-1.1b", "--reduced", "--steps", "3",
                     "--batch", "2", "--seq", "32", "--resume",
                     "--checkpoint-dir", str(tmp_path), "--log-every", "100"])
    assert rc == 0


def test_serve_driver_reduced():
    from repro.launch import serve
    rc = serve.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                     "--prompt-len", "16", "--gen", "4"])
    assert rc == 0


def test_serve_coded_head_with_failure():
    from repro.launch import serve
    rc = serve.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                     "--prompt-len", "16", "--coded-head", "--coded-k", "4",
                     "--coded-t", "1", "--coded-n", "6", "--kill-shard", "3"])
    assert rc == 0


def test_paper_accuracy_reproduction():
    """Fig. 3-style: CPML accuracy ~= conventional logistic regression on a
    separable MNIST-like task after 25 iterations (small scale for CI)."""
    from repro.core import protocol
    from repro.data import synthetic
    x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=800, d=60,
                                margin=12.0)
    cfg = protocol.CPMLConfig(N=8, K=2, T=1, r=1)
    w, hist = protocol.train(cfg, jax.random.PRNGKey(7), x, y, iters=25,
                             eval_every=25)
    # uncoded reference
    state = protocol.setup(cfg, jax.random.PRNGKey(7), x, y)
    eta = protocol.lipschitz_eta(state.xq_real)
    w2 = jnp.zeros(x.shape[1])
    xq = state.xq_real[:800]
    for _ in range(25):
        w2 = w2 - eta * (xq.T @ (protocol.sigmoid(xq @ w2) - y)) / 800
    _, acc_ref = protocol.loss_and_accuracy(w2, xq, y)
    acc_coded = hist[-1]["acc"]
    assert acc_coded > 0.8
    assert abs(acc_coded - float(acc_ref)) < 0.03


def test_cpml_train_driver(tmp_path):
    """The coded-workload CLI end to end: multi-class + mini-batch + a
    straggler every round, json metrics out."""
    from repro.launch import cpml_train
    out = tmp_path / "cpml.json"
    rc = cpml_train.main(["--classes", "3", "--m", "300", "--d", "24",
                          "--iters", "4", "--eval-every", "2",
                          "--batch-rows", "32", "--drop-workers", "1",
                          "--json-out", str(out)])
    assert rc == 0
    import json
    rep = json.loads(out.read_text())
    assert rep["config"]["c"] == 3 and len(rep["history"]) == 2
    assert 0.0 <= rep["acc_coded"] <= 1.0


@pytest.mark.slow
def test_shard_map_backend_multidevice():
    """CPML 'shard' backend on an 8-device forced-CPU mesh == vmap backend."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import protocol
from repro.data import synthetic

x, y = synthetic.mnist_like(jax.random.PRNGKey(42), m=400, d=30)
mesh = jax.make_mesh((8,), ("workers",))
cfgv = protocol.CPMLConfig(N=8, K=2, T=1, r=1, backend="vmap")
sv = protocol.setup(cfgv, jax.random.PRNGKey(0), x, y)
wv = protocol.step(cfgv, jax.random.PRNGKey(1), sv, 0.5).w
cfgs = protocol.CPMLConfig(N=8, K=2, T=1, r=1, backend="shard")
ss = protocol.setup(cfgs, jax.random.PRNGKey(0), x, y)
with mesh:
    ws = protocol.step(cfgs, jax.random.PRNGKey(1), ss, 0.5).w
assert np.allclose(np.asarray(wv), np.asarray(ws), atol=1e-6), \
    float(jnp.abs(wv - ws).max())
# scan engine == per-step reference loop, bit-identical, on the shard
# backend — with and without the fused worker kernel (acceptance matrix).
for kern in (False, True):
    cfgk = protocol.CPMLConfig(N=8, K=2, T=1, r=1, c=3, backend="shard",
                               use_kernel=kern)
    xm, ym = synthetic.multiclass_mnist_like(jax.random.PRNGKey(2), m=240,
                                             d=24, c=3)
    with mesh:
        w1, _ = protocol.train(cfgk, jax.random.PRNGKey(5), xm, ym, iters=10)
        w2, _ = protocol.train_reference(cfgk, jax.random.PRNGKey(5), xm, ym,
                                         iters=10)
    assert (np.asarray(w1) == np.asarray(w2)).all(), kern
print("SHARD_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARD_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The real dry-run path (512 host devices, production mesh) for the
    smallest arch — proves lower+compile+analysis works end to end."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "train_4k", "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=1800)
    assert "ok=1" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
