import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

DOC = """Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this produces (into benchmarks/results/dryrun_<...>.json):
  * memory_analysis()  — per-device argument/output/temp bytes (fits check)
  * cost_analysis()    — per-device HLO flops + bytes accessed
  * collective bytes   — parsed from the post-SPMD compiled HLO text: the sum
    of per-device shard sizes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute ops (all-reduce counted 2x: RS+AG)
  * the three roofline terms (seconds) per EXPERIMENTS.md §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--all] [--out benchmarks/results]
"""
__doc__ = DOC

import argparse
import dataclasses
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, ModelConfig, RunConfig, ShapeConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.parallel import rules

# v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s/link ICI

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of every dtype[shape] in an HLO type signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved by each collective kind (post-SPMD shapes)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = TYPE op-name(...)" — take the output type signature.
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+)\(", s)
        if not m:
            continue
        sig, op = m.groups()
        if op.rstrip("-start") in _COLLECTIVES or op in (
                c + "-start" for c in _COLLECTIVES):
            kind = op.replace("-start", "")
            if kind not in out:
                continue
            nbytes = _shape_bytes(sig)
            if kind == "all-reduce":
                nbytes *= 2          # ring all-reduce = reduce-scatter + all-gather
            out[kind] += nbytes
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, rc: RunConfig, ocfg: opt.OptimizerConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, rc, p, batch))(params)
        params, opt_state, metrics = opt.apply_updates(ocfg, params, grads,
                                                       opt_state)
        return params, opt_state, {"loss": loss, **metrics}
    return train_step


def build_prefill_step(cfg: ModelConfig, rc: RunConfig, cache_len: int):
    def prefill_step(params, batch):
        return M.prefill(cfg, rc, params, batch, cache_len)
    return prefill_step


def build_serve_step(cfg: ModelConfig, rc: RunConfig):
    def serve_step(params, cache, batch):
        return M.decode_step(cfg, rc, params, cache, batch)
    return serve_step


def _named(mesh, spec_tree, shape_tree):
    return jax.tree.map(
        lambda spec, sds: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def abstract_train_inputs(cfg, rc, ocfg, mesh):
    pshapes = M.abstract_params(cfg)
    pspecs = M.param_specs(cfg, mesh, rc.seq_parallel)
    params = _named(mesh, pspecs, pshapes)
    oshapes = jax.eval_shape(functools.partial(opt.init_state, ocfg), pshapes)
    ospecs = {"step": P()}
    for k in oshapes:
        if k != "step":
            ospecs[k] = pspecs
    opt_state = _named(mesh, ospecs, oshapes)
    return params, opt_state, pspecs, ospecs


# ---------------------------------------------------------------------------
# the cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rc: RunConfig | None = None, verbose: bool = True,
             save_hlo: str | None = None) -> dict:
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = registry.applicable(cfg, shape)
    cell = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        cell.update(status="skipped", reason=reason)
        return cell
    rc = rc or default_rc(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    ocfg = opt.OptimizerConfig()
    t0 = time.time()
    with rules.use_rules_mesh(mesh, rc.seq_parallel):
        inputs = registry.input_specs(cfg, shape, mesh, rc)
        if shape.kind == "train":
            params, opt_state, pspecs, ospecs = abstract_train_inputs(
                cfg, rc, ocfg, mesh)
            fn = build_train_step(cfg, rc, ocfg)
            jfn = jax.jit(fn, donate_argnums=(0, 1))
            args = (params, opt_state, inputs)
        elif shape.kind == "prefill":
            pshapes = M.abstract_params(cfg)
            pspecs = M.param_specs(cfg, mesh, rc.seq_parallel)
            params = _named(mesh, pspecs, pshapes)
            fn = build_prefill_step(cfg, rc, shape.seq_len)
            jfn = jax.jit(fn)
            args = (params, inputs)
        else:  # decode
            pshapes = M.abstract_params(cfg)
            pspecs = M.param_specs(cfg, mesh, rc.seq_parallel)
            params = _named(mesh, pspecs, pshapes)
            cache = inputs.pop("cache")
            fn = build_serve_step(cfg, rc)
            jfn = jax.jit(fn, donate_argnums=(1,))
            args = (params, cache, inputs)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    if save_hlo:
        import gzip
        with gzip.open(save_hlo, "wt") as f:
            f.write(compiled.as_text())
    t0 = time.time()
    hlo = hlo_analysis.analyze(compiled.as_text())
    t_analyze = time.time() - t0
    flops = float(hlo["flops"])              # trip-count-aware, per device
    bytes_acc = float(hlo["bytes"])
    coll = {k: float(v) for k, v in hlo["collectives"].items()}
    coll_total = float(hlo["collective_total"])
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll_total / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    model_flops = model_flops_per_step(cfg, shape)
    cell.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        analyze_s=round(t_analyze, 2),
        memory={k: int(getattr(mem, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")},
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=bytes_acc,
        xla_cost_flops_per_device=float(cost.get("flops", 0.0)),
        xla_cost_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=coll,
        collective_total_per_device=coll_total,
        roofline_terms_s=terms,
        dominant=dominant,
        model_flops_global=model_flops,
        useful_ratio=(model_flops / (flops * chips)) if flops else None,
        step_time_bound_s=max(terms.values()),
    )
    if verbose:
        print(json.dumps(cell, indent=2))
    return cell


def model_flops_per_step(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE) per optimizer step;
    for prefill 2*N*D (fwd only); decode: per generated token."""
    n = active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * tokens


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k experts only)."""
    total = cfg.param_count()
    if cfg.num_experts:
        e, k = cfg.num_experts, cfg.experts_per_token
        expert_params = sum(
            count * e * (3 if cfg.act == "silu" else 2)
            * cfg.d_model * cfg.moe_d_ff
            for kind, count in cfg.block_pattern if kind == "moe")
        total = total - expert_params + expert_params * k // e
    return total


def default_rc(cfg: ModelConfig, shape: ShapeConfig) -> RunConfig:
    rc = RunConfig(seq_len=shape.seq_len, global_batch=shape.global_batch)
    if shape.seq_len >= 32768 and shape.kind != "decode":
        rc = dataclasses.replace(rc, q_block=1024, kv_block=1024)
    return rc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) for the chosen mesh")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--save-hlo", action="store_true",
                    help="save gzipped compiled HLO text per cell")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    cells = ([(a, s) for a in registry.ARCHS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    results = []
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{mesh_tag}"
        print(f"=== {tag} ===", flush=True)
        try:
            hlo_path = (os.path.join(args.out, f"hlo_{tag}.txt.gz")
                        if args.save_hlo else None)
            cell = run_cell(arch, shape, args.multi_pod, save_hlo=hlo_path)
        except Exception as e:
            cell = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]}
            print(cell["error"], flush=True)
        results.append(cell)
        with open(os.path.join(args.out, f"dryrun_{tag}.json"), "w") as f:
            json.dump(cell, f, indent=2)
    n_ok = sum(c["status"] == "ok" for c in results)
    n_skip = sum(c["status"] == "skipped" for c in results)
    n_err = len(results) - n_ok - n_skip
    print(f"\nDRYRUN SUMMARY [{mesh_tag}]: ok={n_ok} skipped={n_skip} "
          f"errors={n_err}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
