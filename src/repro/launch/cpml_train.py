"""CodedPrivateML training driver (the coded-workload analogue of launch.train).

    python -m repro.launch.cpml_train --classes 10 --iters 25 --batch-rows 64

Builds a synthetic classification task, runs the scan-jitted coded engine
(multi-class one-vs-all + optional mini-batch SGD + optional straggler
schedule), and reports accuracy against the cleartext quantized baseline.
``--backend shard`` forces an N-device host mesh (one coded share per
device, the paper's deployment shape); ``--kernel`` routes the worker step
through the fused Pallas kernel.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description="CodedPrivateML coded training")
    ap.add_argument("--workers", "-N", type=int, default=8)
    ap.add_argument("--parallel", "-K", type=int, default=2)
    ap.add_argument("--privacy", "-T", type=int, default=1)
    ap.add_argument("--degree", "-r", type=int, default=1)
    ap.add_argument("--classes", "-c", type=int, default=1,
                    help="1 = binary logistic regression (the paper's task)")
    ap.add_argument("--m", type=int, default=2000, help="samples")
    ap.add_argument("--d", type=int, default=128, help="features")
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--eta", type=float, default=None,
                    help="step size (default: 1/L via power iteration)")
    ap.add_argument("--batch-rows", type=int, default=None,
                    help="mini-batch rows per part per round (default: full)")
    ap.add_argument("--backend", choices=("vmap", "shard"), default="vmap")
    ap.add_argument("--kernel", action="store_true",
                    help="use the fused Pallas worker kernel")
    ap.add_argument("--p30", action="store_true",
                    help="use the 30-bit extended prime (more headroom)")
    ap.add_argument("--drop-workers", type=int, default=0,
                    help="simulate this many stragglers every round")
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json-out", type=str, default=None,
                    help="write the final metrics to this path")
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    if args.backend == "shard" and "XLA_FLAGS" not in os.environ:
        # one device per worker BEFORE jax initializes
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.workers}")

    import jax
    import numpy as np

    from repro.core import field, protocol
    from repro.data import synthetic

    cfg = protocol.CPMLConfig(
        N=args.workers, K=args.parallel, T=args.privacy, r=args.degree,
        c=args.classes, p=field.P30 if args.p30 else field.P,
        backend=args.backend, use_kernel=args.kernel,
        batch_rows=args.batch_rows)
    drop = args.drop_workers
    assert cfg.N - drop >= cfg.threshold, (
        f"dropping {drop} of N={cfg.N} leaves fewer than the recovery "
        f"threshold {cfg.threshold}")
    print(f"CPML: N={cfg.N} K={cfg.K} T={cfg.T} r={cfg.r} c={cfg.c} "
          f"threshold={cfg.threshold} backend={cfg.backend} "
          f"kernel={cfg.use_kernel} batch_rows={cfg.batch_rows}")

    key = jax.random.PRNGKey(args.seed)
    if cfg.c == 1:
        x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=args.m, d=args.d,
                                    margin=12.0)
    else:
        x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(1),
                                               m=args.m, d=args.d, c=cfg.c)

    survivor_fn = None
    if drop:
        survivor_fn = lambda t: np.roll(np.arange(cfg.N), t)[: cfg.N - drop]

    def run():
        return protocol.train(cfg, key, x, y, iters=args.iters, eta=args.eta,
                              survivor_fn=survivor_fn,
                              eval_every=args.eval_every)

    t0 = time.time()
    if args.backend == "shard":
        assert jax.device_count() >= cfg.N, (
            f"shard backend wants {cfg.N} devices, have {jax.device_count()}")
        mesh = jax.make_mesh((cfg.N,), (cfg.mesh_axis,))
        with mesh:
            w, hist = run()
    else:
        w, hist = run()
    dt = time.time() - t0
    for h in hist:
        print(f"  iter {h['iter']:4d}  loss {h['loss']:.4f}  "
              f"acc {h['acc']:.2%}")
    print(f"trained {args.iters} private iterations in {dt:.1f}s "
          f"({args.iters / dt:.1f} it/s, one jitted scan)")

    # cleartext quantized baseline: same X̄, true sigmoid, same step count
    wc, xq = protocol.cleartext_baseline(cfg, x, y, args.iters, eta=args.eta)
    if cfg.c == 1:
        _, acc_ref = protocol.loss_and_accuracy(wc, xq, y)
        _, acc = protocol.loss_and_accuracy(w, xq, y)
    else:
        _, acc_ref = protocol.multiclass_loss_and_accuracy(wc, xq, y)
        _, acc = protocol.multiclass_loss_and_accuracy(w, xq, y)
    print(f"accuracy: coded {float(acc):.2%} vs cleartext baseline "
          f"{float(acc_ref):.2%}")

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"config": {"N": cfg.N, "K": cfg.K, "T": cfg.T,
                                  "r": cfg.r, "c": cfg.c,
                                  "backend": cfg.backend,
                                  "use_kernel": cfg.use_kernel,
                                  "batch_rows": cfg.batch_rows},
                       "seconds": dt, "history": hist,
                       "acc_coded": float(acc),
                       "acc_cleartext": float(acc_ref)}, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
