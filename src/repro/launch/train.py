"""Training driver: `python -m repro.launch.train --arch tinyllama-1.1b ...`

Composes the substrate end-to-end: config -> mesh -> sharded params ->
data pipeline -> jit train step (loss/grad/AdamW) -> checkpointed resilient
loop.  `--reduced` runs the same code path on a CPU-sized model (the smoke
path and the examples/train_lm.py driver); full configs are for real TPU
meshes (dry-run proves they lower+compile).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.configs.base import RunConfig
from repro.data.loader import LMBatchLoader
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.parallel import rules
from repro.runtime.resilience import ResilientLoop


def build_sharded_state(cfg, rc, ocfg, mesh, key):
    pspecs = M.param_specs(cfg, mesh, rc.seq_parallel)
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        params = jax.jit(
            lambda k: M.init_params(cfg, k),
            out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                       pspecs, is_leaf=_is_spec))(key)
        ospecs = {"step": P()}
        ostate_shape = jax.eval_shape(lambda p: opt.init_state(ocfg, p), params)
        for k in ostate_shape:
            if k != "step":
                ospecs[k] = pspecs
        opt_state = jax.jit(
            lambda p: opt.init_state(ocfg, p),
            out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                       ospecs, is_leaf=_is_spec))(params)
    return params, opt_state, pspecs, ospecs


def _is_spec(x):
    return isinstance(x, P)


def train_step_fn(cfg, rc, ocfg):
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, rc, p, batch))(params)
        params, opt_state, metrics = opt.apply_updates(
            ocfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics}
    return step


def main(argv=None, config_override=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list(registry.ARCHS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = config_override or registry.get_config(args.arch)
    if args.reduced:
        cfg = registry.reduced_config(cfg)
    rc = RunConfig(seq_len=args.seq, global_batch=args.batch,
                   q_block=min(512, args.seq), kv_block=min(1024, args.seq),
                   loss_chunk=min(512, args.seq),
                   scan_chunk=min(128, args.seq))
    ocfg = opt.OptimizerConfig(learning_rate=args.lr,
                               warmup_steps=max(2, args.steps // 10),
                               total_steps=max(args.steps, 10))
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    key = jax.random.PRNGKey(0)
    params, opt_state, pspecs, ospecs = build_sharded_state(
        cfg, rc, ocfg, mesh, key)
    step_fn = jax.jit(train_step_fn(cfg, rc, ocfg), donate_argnums=(0, 1))

    ckpt = CheckpointManager(args.checkpoint_dir)
    start = 0
    shardings = {
        "params": jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=_is_spec),
        "opt_state": jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                                  is_leaf=_is_spec),
    }
    if args.resume and ckpt.latest_step() is not None:
        restored = ckpt.restore(shardings=shardings)   # elastic: any mesh
        start = restored["step"]
        params, opt_state = restored["params"], restored["opt_state"]
        print(f"resumed from step {start}")

    state = {"params": params, "opt_state": opt_state}
    loop = ResilientLoop(ckpt, checkpoint_every=args.checkpoint_every)
    losses = []

    # context manager: the prefetch thread is joined even when a step fails
    with LMBatchLoader(mesh, args.batch, args.seq, cfg.vocab_size) as loader:
        it = iter(loader)

        def one_step(state, step):
            batch = next(it)
            t0 = time.time()
            with rules.use_rules_mesh(mesh, rc.seq_parallel):
                p, o, metrics = step_fn(state["params"], state["opt_state"],
                                        batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):8.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"dt {time.time()-t0:6.2f}s", flush=True)
            return {"params": p, "opt_state": o}

        state = loop.run(state, one_step, start, args.steps)
    if args.checkpoint_every:
        ckpt.save(start + args.steps, state)
        ckpt.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    import math
    if not math.isfinite(losses[-1]):
        return 1
    # loss should not be diverging; short runs are noisy, so allow 5% slack
    return 0 if (losses[-1] < losses[0] * 1.05 or args.steps < 20) else 1


if __name__ == "__main__":
    raise SystemExit(main())
