"""Coded prediction service driver: simulated OR real multi-process serving.

    python -m repro.launch.cpml_serve --queries 64 --rate 200
    python -m repro.launch.cpml_serve --mode closed --queries 32
    python -m repro.launch.cpml_serve --straggle-worker 7 \\
        --straggle-sleep 0.5 --collect-all
    python -m repro.launch.cpml_serve --transport socket --queries 32
    python -m repro.launch.cpml_serve --transport socket --kill-worker 5 \\
        --kill-at-round 3
    python -m repro.launch.cpml_serve --trace-out serve.trace.json \\
        --metrics-out serve.prom

Runs the privacy-preserving prediction plane (cluster/serve.py): the model
is Lagrange-encoded ONCE and provisioned to N workers, then an open-loop
(Poisson arrivals at ``--rate`` qps) or closed-loop (``--mode closed``,
one saturated batch in flight at a time) client load is admitted into the
bounded request queue, flushed under the max-batch/max-wait policy, and
decoded at the first 2(K+T-1)+1 responders.  Every run reports queries/s
and latency p50/p99 under BOTH wait policies — the first-threshold service
and the wait-for-all counterfactual from the same responder traces — plus
a bit-identity check of the served predictions against the uncoded
plaintext oracle.

``--transport inprocess`` (default) simulates workers under ``--latency``;
``--straggle-worker i`` adds ``--straggle-sleep`` seconds to worker i on
EITHER backend (simulated additive sleep, or a real time.sleep in the
worker process), and ``--kill-worker`` crashes a real worker mid-service
to demo first-threshold decode riding through a death.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="CodedPrivateML prediction-serving driver")
    ap.add_argument("--workers", "-N", type=int, default=8)
    ap.add_argument("--parallel", "-K", type=int, default=2)
    ap.add_argument("--privacy", "-T", type=int, default=1)
    ap.add_argument("--d", type=int, default=32, help="feature dimension")
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=32,
                    help="rows per coded flush (K must divide it)")
    ap.add_argument("--max-wait", type=float, default=0.02,
                    help="seconds the oldest admitted query may wait "
                         "before a partial flush")
    ap.add_argument("--queue-cap", type=int, default=64,
                    help="admitted-but-unflushed query bound (a full "
                         "queue rejects at submission)")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--rows", type=int, default=4,
                    help="feature rows per query (open loop)")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="open-loop arrival rate, queries/s (Poisson)")
    ap.add_argument("--mode", choices=("open", "closed"), default="open",
                    help="open = scheduled arrivals through the batching "
                         "policy; closed = one full-batch query in flight "
                         "at a time (throughput ceiling)")
    ap.add_argument("--transport", choices=("inprocess", "socket"),
                    default="inprocess")
    ap.add_argument("--latency", choices=("deterministic", "lognormal",
                                          "bursty"),
                    default="lognormal",
                    help="per-worker latency profile (inprocess only)")
    ap.add_argument("--latency-seed", type=int, default=0)
    ap.add_argument("--latency-base", type=float, default=0.01,
                    help="latency model base seconds (inprocess only; "
                         "serving rounds are much lighter than training)")
    ap.add_argument("--straggle-worker", type=int, default=None,
                    help="add --straggle-sleep seconds to this worker "
                         "(both backends)")
    ap.add_argument("--straggle-sleep", type=float, default=0.25)
    ap.add_argument("--kill-worker", type=int, default=None,
                    help="crash this worker index mid-service (socket only)")
    ap.add_argument("--kill-at-round", type=int, default=2,
                    help="flush index at which --kill-worker crashes")
    ap.add_argument("--collect-all", action="store_true",
                    help="keep each flush open until every dispatched "
                         "worker responds, so the wait-for-all "
                         "counterfactual is measured (do not combine "
                         "with --kill-worker)")
    ap.add_argument("--round-timeout", type=float, default=math.inf)
    ap.add_argument("--heartbeat-timeout", type=float, default=math.inf)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--wire", choices=("v1", "v2"), default="v2")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the per-flush bit-identity check vs the "
                         "uncoded plaintext oracle")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json-out", type=str, default=None)
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write a Perfetto/Chrome trace with per-query "
                         "queue/batch/dispatch/decode spans here")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the serve_* metrics registry here "
                         "(*.json = snapshot, else Prometheus text)")
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    import jax

    from repro.cluster import make_latency
    from repro.cluster.latency import SleepyStragglerLatency
    from repro.cluster.serve import (
        PredictionServer, ServeConfig, open_loop_queries)
    from repro.launch.cpml_cluster import (
        _json_finite, _recorder_for, local_socket_cluster)

    cfg = ServeConfig(N=args.workers, K=args.parallel, T=args.privacy,
                      max_batch=args.max_batch, max_wait_s=args.max_wait,
                      queue_cap=args.queue_cap)
    mode = (args.latency if args.transport == "inprocess"
            else f"socket x{cfg.N} procs")
    print(f"CPML serve: N={cfg.N} K={cfg.K} T={cfg.T} "
          f"threshold={cfg.threshold} max_batch={cfg.max_batch} "
          f"max_wait={cfg.max_wait_s * 1e3:.0f}ms [{mode}, {args.mode} loop]")

    # stand-in for a trained model head; any (d, classes) weights serve
    w = 0.5 * jax.random.normal(jax.random.PRNGKey(args.seed),
                                (args.d, args.classes))
    key = jax.random.PRNGKey(args.seed + 1)
    rows = cfg.max_batch if args.mode == "closed" else args.rows
    rate = 0.0 if args.mode == "closed" else args.rate
    queries = open_loop_queries(args.queries, rows=rows, d=args.d,
                                rate_qps=rate, seed=args.seed + 2)
    kw = dict(round_timeout_s=args.round_timeout,
              heartbeat_timeout_s=args.heartbeat_timeout,
              collect_all=args.collect_all, verify=not args.no_verify,
              recorder=_recorder_for(args))

    if args.transport == "socket":
        die = ({args.kill_worker: args.kill_at_round}
               if args.kill_worker is not None else None)
        sleep = ({args.straggle_worker: args.straggle_sleep}
                 if args.straggle_worker is not None else None)
        with local_socket_cluster(cfg.N, port=args.port, die_at_round=die,
                                  sleep_s=sleep,
                                  wire_version=int(args.wire[1:])) as tr:
            srv = PredictionServer(cfg, w, key, transport=tr, **kw)
            srv.provision()
            t0 = time.monotonic()
            if args.mode == "closed":
                srv.run_closed_loop(queries)
            else:
                srv.run(queries)
            wall_s = time.monotonic() - t0
            srv.shutdown_workers()
        print(f"socket service: {len(srv.results)} queries over TCP "
              f"in {wall_s:.1f}s")
        if die:
            print(f"killed worker {args.kill_worker} at flush "
                  f"{args.kill_at_round}: first-threshold decode rode "
                  f"through")
    else:
        latency = make_latency(args.latency, seed=args.latency_seed,
                               base=args.latency_base)
        if args.straggle_worker is not None:
            latency = SleepyStragglerLatency(
                latency, {args.straggle_worker: args.straggle_sleep})
        srv = PredictionServer(cfg, w, key, latency=latency, **kw)
        if args.mode == "closed":
            srv.run_closed_loop(queries)
        else:
            srv.run(queries)

    stats = srv.stats()
    first, allw = stats["latency_first"], stats["latency_all"]
    word = "wall" if args.transport == "socket" else "simulated"
    print(f"served {stats['queries']}/{args.queries} queries "
          f"({stats['rejected']} rejected) in {stats['rounds']} flushes: "
          f"{stats['queries_per_s']:.1f} queries/s, "
          f"{stats['rows_per_s']:.0f} rows/s ({word})")
    print(f"latency first-threshold: p50 {first['p50'] * 1e3:.1f}ms  "
          f"p99 {first['p99'] * 1e3:.1f}ms")
    if allw["n"]:
        print(f"latency wait-for-all:    p50 {allw['p50'] * 1e3:.1f}ms  "
              f"p99 {allw['p99'] * 1e3:.1f}ms "
              f"({allw['unobserved']} unobserved)")
    elif allw["unobserved"]:
        print(f"(wait-for-all unobserved on every flush: rerun with "
              f"--collect-all to measure the counterfactual)")

    rc = 0
    if not args.no_verify:
        ok = stats["oracle"]["bit_identical"] and stats["oracle"]["checked"]
        print(f"served predictions bit-identical to the uncoded plaintext "
              f"oracle: {bool(ok)} ({stats['oracle']['checked']} flushes)")
        if not ok:
            rc = 1

    if args.trace_out:
        from repro.obs.export import (straggler_report, waterfall,
                                      write_chrome_trace)
        obj = write_chrome_trace(srv.obs, args.trace_out)
        pids = {e.get("pid") for e in obj["traceEvents"]}
        print(f"trace: {len(obj['traceEvents'])} events / {len(pids)} "
              f"process(es) -> {args.trace_out} (load at ui.perfetto.dev)")
        print(waterfall(srv.obs))
        text, _ = straggler_report(srv.traces, cfg.threshold)
        print(text)
    if args.metrics_out:
        srv.metrics.write(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(_json_finite(
                {"config": {"N": cfg.N, "K": cfg.K, "T": cfg.T,
                            "threshold": cfg.threshold,
                            "max_batch": cfg.max_batch,
                            "max_wait_s": cfg.max_wait_s,
                            "queue_cap": cfg.queue_cap,
                            "transport": args.transport,
                            "mode": args.mode,
                            "latency": (args.latency
                                        if args.transport == "inprocess"
                                        else None)},
                 "stats": stats}), f, indent=2)
    return rc


if __name__ == "__main__":
    sys.exit(main())
