"""Serving driver: batched prefill + decode, optional Lagrange-coded LM head.

`--coded-head` routes the vocab projection through core/coded_linear: the
head is Lagrange-encoded over N logical shards (K data + T privacy masks),
so any K+T shard results reconstruct exact logits — per-token straggler/
failure tolerance for the TP group, demonstrated by `--kill-shard i`.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import RunConfig
from repro.core import coded_linear as CL
from repro.launch.mesh import make_local_mesh
from repro.models import model as M


def greedy_decode(cfg, rc, params, prompt, steps, coded=None, survivors=None):
    """prompt: (B, S) tokens. Returns (B, steps) generated tokens."""
    B, S = prompt.shape
    logits, cache, h = M.prefill(cfg, rc, params, {"tokens": prompt},
                                 cache_len=S + steps, return_hidden=True)
    outs = []
    decode = jax.jit(lambda p, c, b: M.decode_step(cfg, rc, p, c, b,
                                                   return_hidden=True))
    for _ in range(steps):
        if coded is not None:
            # coded path: project the REAL post-final-norm hidden state
            # through the Lagrange-coded head instead of lm_head
            lg = CL.coded_head_apply(coded["cfg"],
                                     h[:, -1].astype(jnp.float32),
                                     coded["shares"], survivors=survivors)
            tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
        logits, cache, h = decode(params, cache, {"tokens": tok})
    return jnp.concatenate(outs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list(registry.ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--coded-head", action="store_true")
    ap.add_argument("--coded-k", type=int, default=4)
    ap.add_argument("--coded-t", type=int, default=1)
    ap.add_argument("--coded-n", type=int, default=6)
    ap.add_argument("--kill-shard", type=int, default=-1,
                    help="simulate loss of one coded head shard")
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = registry.reduced_config(cfg)
    rc = RunConfig(q_block=min(512, args.prompt_len),
                   kv_block=min(1024, args.prompt_len),
                   scan_chunk=min(128, args.prompt_len))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, dtype=jnp.int32)

    coded = None
    survivors = None
    if args.coded_head:
        # vocab must divide K: pad config choice onto the reduced vocab
        ccfg = CL.CodedLinearConfig(N=args.coded_n, K=args.coded_k,
                                    T=args.coded_t)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"]).astype(jnp.float32)
        v = w.shape[1] - (w.shape[1] % args.coded_k)
        w = w[:, :v]
        shares = CL.encode_weights(ccfg, jax.random.PRNGKey(2), w)
        if args.kill_shard >= 0:
            survivors = np.array([i for i in range(ccfg.N)
                                  if i != args.kill_shard])
            print(f"killed shard {args.kill_shard}; decoding from "
                  f"{len(survivors)} survivors (threshold {ccfg.threshold})")
        # one-shot accuracy check on the prompt's hidden states before
        # generating: coded head vs the uncoded projection
        h, _ = M.backbone(cfg, rc, params, {"tokens": prompt})
        lg = CL.coded_head_apply(ccfg, h[:, -1].astype(jnp.float32), shares,
                                 survivors=survivors)
        ref = (h[:, -1].astype(jnp.float32) @ w)
        err = float(jnp.abs(lg - ref).max() / (jnp.abs(ref).max() + 1e-9))
        tok_coded = jnp.argmax(lg, -1)
        tok_ref = jnp.argmax(ref, -1)
        agree = float((tok_coded == tok_ref).mean())
        print(f"coded head: rel err {err:.4f}, argmax agreement {agree:.2%}, "
              f"useful fraction K/N = {args.coded_k}/{args.coded_n}")
        coded = {"cfg": ccfg, "shares": shares}
    t0 = time.time()
    toks = greedy_decode(cfg, rc, params, prompt, args.gen, coded=coded,
                         survivors=survivors)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", np.asarray(toks[0])[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
