"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model) — v5e pod.
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the `pod`
axis crosses the DCN and carries only data parallelism (gradient
all-reduce), never tensor parallelism.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run pins the device count via XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across versions: axis_types only exists on newer jax
    (jax <= 0.4.x meshes are implicitly Auto on every axis)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    return int(mesh.devices.size)
