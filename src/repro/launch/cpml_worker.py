"""CodedPrivateML worker process: serve coded rounds over a socket.

    python -m repro.launch.cpml_worker --host 127.0.0.1 --port 9000 --worker 3

One process = one of the paper's N workers.  It connects to the master's
SocketTransport, registers its endpoint ("worker/3"), and serves the
message protocol (DESIGN.md §7):

  1. PROVISION — an EncodeShare with ``round == PROVISION_ROUND`` carrying
     {cfg kwargs, the worker's coded dataset share X̃_i, sigmoid-surrogate
     coefficients c̄}.  The worker acks with a Heartbeat once loaded.
  2. ROUNDS    — each EncodeShare(t, i, {"w_share", "batch"}) is acked with
     an immediate Heartbeat (liveness), then answered with
     WorkerResult(t, i, compute_s, payload=f(X̃_i, W̃_i)) — the (d, c) field
     evaluation of the paper's Eq. 20 polynomial, exact int32 mod p, so the
     master's decode is bit-identical to computing the round locally.
  3. SHUTDOWN  — ``round == SHUTDOWN_ROUND`` (or the master hanging up)
     ends the serve loop.

Fault-injection flags make the failure paths deterministic for tests and
benchmarks: ``--die-at-round R`` simulates a crash (exit without replying
when round R's share arrives); ``--sleep-s S`` makes this worker a real
straggler (sleeps S seconds before every reply).
"""
from __future__ import annotations

import argparse
import math
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description="CodedPrivateML socket worker")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--worker", type=int, required=True,
                    help="this worker's index i in [0, N)")
    ap.add_argument("--connect-timeout", type=float, default=30.0)
    ap.add_argument("--die-at-round", type=int, default=None,
                    help="crash (exit silently) when this round's share "
                         "arrives — deterministic kill-a-worker injection")
    ap.add_argument("--sleep-s", type=float, default=0.0,
                    help="sleep this long before every reply — a real "
                         "injected straggler")
    return ap


def serve(args) -> int:
    # imports deferred so --help/arg errors don't pay jax startup
    import jax.numpy as jnp
    import numpy as np

    from repro.cluster.messages import (
        MASTER, PROVISION_ROUND, SHUTDOWN_ROUND, EncodeShare, Heartbeat,
        WorkerResult, worker_endpoint)
    from repro.cluster.socket_transport import SocketTransport
    from repro.core.protocol import compute
    from repro.core.protocol.config import CPMLConfig

    me = worker_endpoint(args.worker)
    tr = SocketTransport.connect(args.host, args.port, me,
                                 timeout_s=args.connect_timeout)
    f = None
    x_share = None
    try:
        while not tr.peer_closed:
            if tr.next_delivery(me) is None:
                continue
            for _, msg in tr.recv(me, math.inf):
                if not isinstance(msg, EncodeShare):
                    continue
                if msg.round == SHUTDOWN_ROUND:
                    return 0
                if msg.round == PROVISION_ROUND:
                    p = msg.payload
                    # worker compute never needs the sharded backend or the
                    # Pallas kernel: the jnp reference path is the exact
                    # field-arithmetic spec (DESIGN.md §4), identical mod p.
                    cfg = CPMLConfig(**p["cfg"])
                    f = compute.worker_fn(cfg, jnp.asarray(p["cbar"],
                                                           jnp.int32))
                    x_share = jnp.asarray(p["x_share"], jnp.int32)
                    tr.send(MASTER, Heartbeat(args.worker, time.monotonic()))
                    continue
                if args.die_at_round is not None \
                        and msg.round >= args.die_at_round:
                    return 0            # crash: no heartbeat, no result
                tr.send(MASTER, Heartbeat(args.worker, time.monotonic()))
                if f is None:
                    raise RuntimeError(
                        f"{me}: round {msg.round} share arrived before "
                        f"provisioning")
                t0 = time.monotonic()
                if args.sleep_s > 0:
                    time.sleep(args.sleep_s)
                w_share = jnp.asarray(msg.payload["w_share"], jnp.int32)
                batch = msg.payload.get("batch")
                xb = (x_share if batch is None
                      else jnp.take(x_share, jnp.asarray(batch, jnp.int32),
                                    axis=0))
                result = np.asarray(f(xb, w_share), dtype=np.int32)
                tr.send(MASTER,
                        WorkerResult(msg.round, args.worker,
                                     compute_s=time.monotonic() - t0,
                                     payload=result))
        return 0
    finally:
        tr.close()


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return serve(args)
    except OSError as e:
        print(f"cpml_worker {args.worker}: cannot reach master at "
              f"{args.host}:{args.port}: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
