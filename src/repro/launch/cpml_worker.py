"""CodedPrivateML worker process: serve coded OR MPC rounds over a socket.

    python -m repro.launch.cpml_worker --host 127.0.0.1 --port 9000 --worker 3

One process = one of the paper's N workers.  It connects to the master's
SocketTransport, registers its endpoint ("worker/3"), and serves the
message protocol (DESIGN.md §7):

  1. PROVISION — an EncodeShare with ``round == PROVISION_ROUND`` carrying
     {cfg kwargs, the worker's coded dataset share X̃_i, sigmoid-surrogate
     coefficients c̄}.  A ``"protocol": "mpc"`` key selects the BGW serve
     mode (the share is then a FULL-dataset Shamir share); an
     ``"protocol": "alcc"`` key selects the ALCC float backend (DESIGN.md
     §14) — the share is a real-valued float32 Lagrange share and the
     round function is the float surrogate evaluation X̃ᵀĝ(X̃W̃); an
     ``"protocol": "alcc_mlp"`` key serves the two-phase coded MLP
     (cluster/alcc_mlp.py) — even rounds compute the coded forward
     X̃·W̃1, odd rounds the coded backward X̃[batch]ᵀ·δ̃1; a
     ``"protocol": "serve"`` key selects the prediction-serving plane
     (cluster/serve.py) — the payload carries the model share W̃_i held
     for the deployment's lifetime, and each later round ships a query
     share X̃_i answered with the bilinear evaluation X̃_i·W̃_i.  The
     worker acks with a Heartbeat once loaded.
  2. ROUNDS    — CPML: each EncodeShare(t, i, {"w_share", "batch"}) is
     acked with an immediate Heartbeat (liveness), then answered with
     WorkerResult(t, i, compute_s, payload=f(X̃_i, W̃_i)).  A pipelined
     master (DESIGN.md §9) additionally ships "next_batch" — round t+1's
     W-independent batch indices — and the worker pre-slices that coded
     sub-batch after replying, while its next weight share is in flight.  MPC: the share
     carries {"w_share", "kred"}; the worker runs the BGW phases — local
     multiply, then one all-to-all reshare BARRIER per degree reduction
     (SubShares exchanged with every peer through the master's relay;
     combining needs ALL N, so one slow peer stalls this worker too) —
     and answers with CombineResult(t, i, compute_s, payload=g-share).
     All field math is exact int32 mod p via the same core/mpc_baseline
     hooks the single-host oracle composes, so the master's reconstruction
     is bit-identical to computing the round locally.
  3. SHUTDOWN  — ``round == SHUTDOWN_ROUND`` (or the master hanging up)
     ends the serve loop.

Fault-injection flags make the failure paths deterministic for tests and
benchmarks: ``--die-at-round R`` simulates a crash (exit without replying
when round R's share arrives); ``--sleep-s S`` makes this worker a real
straggler (sleeps S seconds before every reply — in MPC mode before every
phase's sends, which stalls EVERY peer at the barrier).
"""
from __future__ import annotations

import argparse
import collections
import math
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description="CodedPrivateML socket worker")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--worker", type=int, required=True,
                    help="this worker's index i in [0, N)")
    ap.add_argument("--connect-timeout", type=float, default=30.0)
    ap.add_argument("--barrier-timeout", type=float, default=600.0,
                    help="seconds to wait at an MPC reshare barrier before "
                         "giving up (a missing peer means the round can "
                         "never complete)")
    ap.add_argument("--die-at-round", type=int, default=None,
                    help="crash (exit silently) when this round's share "
                         "arrives — deterministic kill-a-worker injection")
    ap.add_argument("--join-at-round", type=int, default=None,
                    help="elastic JOIN (DESIGN.md §13): announce this "
                         "worker as a late joiner for the given round fence "
                         "right after HELLO; the master provisions its "
                         "pre-encoded spare share and admits it at the "
                         "first fence with t >= this round (wire v2 only)")
    ap.add_argument("--sleep-s", type=float, default=0.0,
                    help="sleep this long before every reply — a real "
                         "injected straggler")
    ap.add_argument("--wire", type=int, choices=(1, 2), default=2,
                    help="wire protocol version this worker speaks "
                         "(DESIGN.md §10): 2 = packed/coalesced frames "
                         "negotiated at HELLO, 1 = behave exactly like a "
                         "legacy v1 build")
    return ap


def serve(args) -> int:
    # imports deferred so --help/arg errors don't pay jax startup
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.cluster.messages import (
        MASTER, PROVISION_ROUND, SHUTDOWN_ROUND, CombineResult, EncodeShare,
        Epoch, Heartbeat, Join, SubShare, WorkerResult, worker_endpoint)
    from repro.cluster.socket_transport import SocketTransport
    from repro.core import field, mpc_baseline as mpc
    from repro.core.protocol import compute
    from repro.core.protocol.config import CPMLConfig

    me = worker_endpoint(args.worker)
    tr = SocketTransport.connect(args.host, args.port, me,
                                 timeout_s=args.connect_timeout,
                                 wire_version=args.wire)
    if args.join_at_round is not None:
        if args.wire < 2:
            raise SystemExit(
                f"{me}: --join-at-round needs wire v2 (a v1 fleet has no "
                f"JOIN frame)")
        # the negotiated version toward the master stays v1 until its
        # HELLO2 ack lands — wait for the upgrade, or the JOIN frame (v2
        # only) would be refused at serialization
        deadline = time.monotonic() + args.connect_timeout
        while tr.peer_version(MASTER) < 2:
            if time.monotonic() > deadline:
                raise SystemExit(
                    f"{me}: master never acked HELLO2 — cannot announce "
                    f"an elastic JOIN to a v1 master")
            tr.next_delivery(me)
        # late joiner: announce the slot + target fence; the master stashes
        # the request and answers with this worker's PROVISION at the fence
        tr.send(MASTER, Join(args.worker, args.join_at_round,
                             time.monotonic()))
    pending: collections.deque = collections.deque()
    subshares: dict[tuple[int, int], dict[int, object]] = {}
    state: dict[str, object] = {"protocol": None}

    def drain() -> None:
        """Pull everything off the wire: SubShares into the reshare buffer,
        EncodeShares into the pending work queue (with their local arrival
        stamp, so a traced round's "recv" span covers wire + queue wait)."""
        for at, msg in tr.recv(me, math.inf):
            if isinstance(msg, SubShare):
                subshares.setdefault((msg.round, msg.phase),
                                     {})[msg.src] = msg.payload
            elif isinstance(msg, Epoch):
                # informational membership fan-out: remember the fleet
                # generation (the master's round math never depends on this
                # worker having seen it)
                state["epoch"] = msg.epoch
            elif isinstance(msg, EncodeShare):
                pending.append((at, msg))

    def reshare_barrier(cfg, t: int, phase: int, kphase, value):
        """One BGW degree reduction from this worker's seat: re-share,
        send a sub-share to every peer, then BLOCK until all N sub-shares
        for (t, phase) are in and combine."""
        if args.sleep_s > 0:
            time.sleep(args.sleep_s)
        sub = np.asarray(mpc.make_subshares(
            cfg, mpc.reshare_keys(cfg, kphase)[args.worker], value),
            np.int32)                                   # (N, *value.shape)
        for v in range(cfg.N):
            if v != args.worker:
                tr.send(worker_endpoint(v),
                        SubShare(t, phase, args.worker, v, sub[v]))
        got = {args.worker: sub[args.worker]}
        deadline = time.monotonic() + args.barrier_timeout
        while len(got) < cfg.N:
            for src, payload in subshares.pop((t, phase), {}).items():
                got[src] = np.asarray(payload, np.int32)
            if len(got) == cfg.N:
                break
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"{me}: reshare barrier (round {t}, phase {phase}) "
                    f"starved: peers {sorted(set(range(cfg.N)) - set(got))} "
                    f"never re-shared")
            if tr.next_delivery(me) is not None:
                drain()
        gathered = jnp.asarray(np.stack([got[i] for i in range(cfg.N)]),
                               jnp.int32)
        return mpc.combine_subshares(cfg, gathered)

    def mpc_round(at: float, msg) -> None:
        cfg, x_share, cbar = state["cfg"], state["x_share"], state["cbar"]
        t = msg.round
        t0 = time.monotonic()
        # worker-side flight-recorder spans (DESIGN.md §11): [name, start,
        # end] triples on THIS process's monotonic clock, piggy-backed on
        # the CombineResult over a v2 wire.  Each reshare barrier becomes
        # its own span, so the trace shows which phase a stall happened in.
        spans = state.pop("carry", []) if state.get("trace") else None
        if spans is not None:
            spans.append(["recv", at, t0])
        w_share = jnp.asarray(msg.payload["w_share"], jnp.int32)  # (d, r)
        kred = np.asarray(msg.payload["kred"])                    # (r+1, 2)
        z = mpc.worker_mul(cfg, x_share, w_share)                 # (m, r)
        t1 = time.monotonic()
        if spans is not None:
            spans.append(["compute", t0, t1])
        z = reshare_barrier(cfg, t, 0, jnp.asarray(kred[0]), z)
        if spans is not None:
            spans.append(["barrier", t1, time.monotonic()])
        prod = z[..., 0]
        s = mpc.s_init(cfg, cbar, prod)
        for i in range(2, cfg.r + 1):
            prod = field.mulmod(prod, z[..., i - 1], cfg.p)
            b0 = time.monotonic()
            prod = reshare_barrier(cfg, t, i - 1, jnp.asarray(kred[i - 1]),
                                   prod)
            if spans is not None:
                spans.append(["barrier", b0, time.monotonic()])
            s = mpc.s_accum(cfg, cbar[i], s, prod)
        if args.sleep_s > 0:
            time.sleep(args.sleep_s)
        t2 = time.monotonic()
        g = np.asarray(mpc.worker_final(cfg, x_share, s), np.int32)
        t3 = time.monotonic()
        if spans is not None:
            spans.append(["serialize", t2, t3])
        tr.send(MASTER, CombineResult(t, args.worker,
                                      time.monotonic() - t0, g,
                                      trace=spans))
        if spans is not None:
            # the socket write can only be timed AFTER the message is built;
            # it rides the NEXT traced round (one-round lag, like the
            # provisioning warm-compile span)
            state["carry"] = [["send", t3, time.monotonic()]]
        # reshare traffic for finished rounds can never be consumed again
        for key in [k for k in subshares if k[0] <= t]:
            del subshares[key]

    def cpml_round(at: float, msg) -> None:
        t0 = time.monotonic()
        # spans: [name, start, end] on this process's clock, shipped with
        # the result over a v2 wire (DESIGN.md §11).  "recv" covers wire +
        # queue wait (arrival stamp -> processing start); an injected
        # straggler sleep gets its own honest span.
        spans = state.pop("carry", []) if state.get("trace") else None
        if spans is not None:
            spans.append(["recv", at, t0])
        if args.sleep_s > 0:
            time.sleep(args.sleep_s)
            if spans is not None:
                spans.append(["straggle", t0, time.monotonic()])
        t1 = time.monotonic()
        w_share = jnp.asarray(msg.payload["w_share"], jnp.int32)
        batch = msg.payload.get("batch")
        x_share = state["x_share"]
        cached = state.get("xb_cache")
        if batch is None:
            xb = x_share
        elif cached is not None and cached[0] == msg.round:
            # pre-sliced from last round's "next_batch" (pipelined master,
            # DESIGN.md §9) — same indices, so the result is bit-identical
            xb = cached[1]
        else:
            xb = jnp.take(x_share, jnp.asarray(batch, jnp.int32), axis=0)
        r = state["f"](xb, w_share)
        r.block_until_ready()
        t2 = time.monotonic()
        if spans is not None:
            spans.append(["compute", t1, t2])
        result = np.asarray(r, dtype=np.int32)
        t3 = time.monotonic()
        if spans is not None:
            spans.append(["serialize", t2, t3])   # device->host materialize
        tr.send(MASTER,
                WorkerResult(msg.round, args.worker,
                             compute_s=time.monotonic() - t0,
                             payload=result, trace=spans))
        if spans is not None:
            # socket-write wall is only known after the message is built; it
            # rides the NEXT traced round, like the warm-compile span
            state["carry"] = [["send", t3, time.monotonic()]]
        nxt = msg.payload.get("next_batch")
        if nxt is not None:
            # W-independent worker-side prefetch: slice round t+1's coded
            # sub-batch AFTER replying, while waiting for its weight share
            state["xb_cache"] = (
                msg.round + 1,
                jnp.take(x_share, jnp.asarray(nxt, jnp.int32), axis=0))
        else:
            state["xb_cache"] = None

    def alcc_round(at: float, msg) -> None:
        """One ALCC float round (DESIGN.md §14): same span shape as
        cpml_round, float32 arithmetic throughout.  Logistic mode applies
        the provisioned worker polynomial; MLP mode selects the phase by
        round PARITY — even rounds are the coded forward X̃_i @ W̃1_i,
        odd rounds the coded backward X̃_i[batch]ᵀ @ δ̃1_i (both shares
        arrive under the same "w_share" key)."""
        t0 = time.monotonic()
        spans = state.pop("carry", []) if state.get("trace") else None
        if spans is not None:
            spans.append(["recv", at, t0])
        if args.sleep_s > 0:
            time.sleep(args.sleep_s)
            if spans is not None:
                spans.append(["straggle", t0, time.monotonic()])
        t1 = time.monotonic()
        w_share = jnp.asarray(msg.payload["w_share"], jnp.float32)
        batch = msg.payload.get("batch")
        x_share = state["x_share"]
        xb = (x_share if batch is None
              else jnp.take(x_share, jnp.asarray(batch, jnp.int32), axis=0))
        if state["protocol"] == "alcc_mlp":
            f = state["f_fwd"] if msg.round % 2 == 0 else state["f_bwd"]
        else:
            f = state["f"]
        r = f(xb, w_share)
        r.block_until_ready()
        t2 = time.monotonic()
        if spans is not None:
            spans.append(["compute", t1, t2])
        result = np.asarray(r, dtype=np.float32)
        t3 = time.monotonic()
        if spans is not None:
            spans.append(["serialize", t2, t3])
        tr.send(MASTER,
                WorkerResult(msg.round, args.worker,
                             compute_s=time.monotonic() - t0,
                             payload=result, trace=spans))
        if spans is not None:
            state["carry"] = [["send", t3, time.monotonic()]]

    def serve_round(at: float, msg) -> None:
        """One coded prediction flush (cluster/serve.py): a query share
        X̃_i arrives, reply with the bilinear evaluation X̃_i·W̃_i.  Same
        span shape as cpml_round so the master's per-query waterfall and
        the training waterfall read identically."""
        t0 = time.monotonic()
        spans = state.pop("carry", []) if state.get("trace") else None
        if spans is not None:
            spans.append(["recv", at, t0])
        if args.sleep_s > 0:
            time.sleep(args.sleep_s)
            if spans is not None:
                spans.append(["straggle", t0, time.monotonic()])
        t1 = time.monotonic()
        xb = jnp.asarray(msg.payload["x_share"], jnp.int32)
        r = state["f"](xb, state["w_share"])
        r.block_until_ready()
        t2 = time.monotonic()
        if spans is not None:
            spans.append(["compute", t1, t2])
        result = np.asarray(r, dtype=np.int32)
        t3 = time.monotonic()
        if spans is not None:
            spans.append(["serialize", t2, t3])
        tr.send(MASTER,
                WorkerResult(msg.round, args.worker,
                             compute_s=time.monotonic() - t0,
                             payload=result, trace=spans))
        if spans is not None:
            state["carry"] = [["send", t3, time.monotonic()]]

    try:
        while not tr.peer_closed:
            if not pending:
                if tr.next_delivery(me) is None:
                    continue
                drain()
                continue
            at, msg = pending.popleft()
            if msg.round == SHUTDOWN_ROUND:
                return 0
            if msg.round == PROVISION_ROUND:
                p = msg.payload
                # master opts this worker into span recording (DESIGN.md
                # §11); the spans only reach it over a v2 wire — a v1
                # serialization silently drops the trace field
                state["trace"] = bool(p.get("trace"))
                if p.get("protocol") == "mpc":
                    state["protocol"] = "mpc"
                    state["cfg"] = mpc.MPCConfig(**p["cfg"])
                    state["cbar"] = jnp.asarray(p["cbar"], jnp.int32)
                elif p.get("protocol") == "serve":
                    # serving plane (cluster/serve.py): hold the model share
                    # W̃_i for the deployment's lifetime; every flush ships
                    # a query share X̃_i and the round function is one
                    # bilinear field matmul X̃_i·W̃_i.
                    state["protocol"] = "serve"
                    prime = int(p["p"])
                    state["w_share"] = jnp.asarray(p["w_share"], jnp.int32)
                    state["f"] = jax.jit(
                        lambda xb, ws, _p=prime: field.matmul(xb, ws, _p))
                elif p.get("protocol") == "alcc":
                    # ALCC float logistic (DESIGN.md §14): real shares,
                    # float32 arithmetic, real surrogate coefficients
                    from repro.core.protocol import alcc_engine
                    state["protocol"] = "alcc"
                    cbar = jnp.asarray(p["cbar"], jnp.float32)
                    state["f"] = jax.jit(
                        lambda xb, ws, _c=cbar:
                        alcc_engine.worker_eval(_c, xb, ws))
                elif p.get("protocol") == "alcc_mlp":
                    # ALCC MLP (cluster/alcc_mlp.py): two bilinear phases
                    # selected by round parity, both plain float32 matmuls
                    state["protocol"] = "alcc_mlp"
                    state["f_fwd"] = jax.jit(lambda xb, ws: xb @ ws)
                    state["f_bwd"] = jax.jit(lambda xb, ws: xb.T @ ws)
                else:
                    # worker compute never needs the sharded backend or the
                    # Pallas kernel: the jnp reference path is the exact
                    # field-arithmetic spec (DESIGN.md §4), identical mod p.
                    state["protocol"] = "cpml"
                    cfg = CPMLConfig(**p["cfg"])
                    # jit the round evaluation: eager op-by-op dispatch of
                    # the limb matmul costs ~50x the fused kernel per round
                    # and was the bulk of the measured socket "overhead".
                    # jit changes WHEN ops run, never what they compute —
                    # exact int32 field math either way (DESIGN.md §4).
                    state["f"] = jax.jit(compute.worker_fn(
                        cfg, jnp.asarray(p["cbar"], jnp.int32)))
                if state["protocol"] != "serve":
                    # field protocols ship exact int32 shares; the ALCC
                    # modes ship float32 real shares
                    dt = (jnp.float32
                          if str(state["protocol"]).startswith("alcc")
                          else jnp.int32)
                    state["x_share"] = jnp.asarray(p["x_share"], dt)
                if state["protocol"] == "serve":
                    # serve flushes are padded to a FIXED (rows, d) shape
                    # (cluster/serve.py), so this one compile covers every
                    # future flush — no mid-service recompile p99 spikes.
                    rows = int(p["rows"])
                    xw = jnp.zeros((rows, state["w_share"].shape[0]),
                                   jnp.int32)
                    t_c0 = time.monotonic()
                    state["f"](xw, state["w_share"]).block_until_ready()
                    if state["trace"]:
                        state["carry"] = [
                            ["warm_compile", t_c0, time.monotonic()]]
                if str(state["protocol"]).startswith("alcc"):
                    # same warmup-before-ack contract as cpml below; ALCC
                    # round shapes are static floats: logistic
                    # (rows, d) x (d, c), MLP (rows, d) x (d, h) forward
                    # and (rows, d)ᵀ x (rows, h) backward
                    x_share = state["x_share"]
                    rows = int(p["cfg"].get("batch_rows")
                               or x_share.shape[0])
                    xw = jnp.zeros((rows, x_share.shape[1]), jnp.float32)
                    t_c0 = time.monotonic()
                    if state["protocol"] == "alcc":
                        ww = jnp.zeros((x_share.shape[1],
                                        int(p["cfg"]["c"])), jnp.float32)
                        state["f"](xw, ww).block_until_ready()
                    else:
                        h = int(p["hidden"])
                        w1 = jnp.zeros((x_share.shape[1], h), jnp.float32)
                        dz = jnp.zeros((rows, h), jnp.float32)
                        state["f_fwd"](xw, w1).block_until_ready()
                        state["f_bwd"](xw, dz).block_until_ready()
                    if state["trace"]:
                        state["carry"] = [
                            ["warm_compile", t_c0, time.monotonic()]]
                if state["protocol"] == "cpml":
                    # compile BEFORE acking: provisioning is the documented
                    # warmup window (rounds start only after every ack, so
                    # round-0 timing never absorbs XLA compilation).  Round
                    # shapes are static: (batch_rows|mk, d) x (d, c, r).
                    x_share = state["x_share"]
                    rows = (cfg.batch_rows if cfg.batch_rows is not None
                            else x_share.shape[0])
                    xw = x_share[jnp.zeros(rows, jnp.int32)]
                    ww = jnp.zeros((x_share.shape[1], cfg.c, cfg.r),
                                   jnp.int32)
                    t_c0 = time.monotonic()
                    state["f"](xw, ww).block_until_ready()
                    if state["trace"]:
                        # ships with the first traced result: the warmup
                        # the provisioning barrier absorbed (the master's
                        # cpml_xla_warm_compile_seconds gauge reads it)
                        state["carry"] = [
                            ["warm_compile", t_c0, time.monotonic()]]
                tr.send(MASTER, Heartbeat(args.worker, time.monotonic()))
                continue
            if args.die_at_round is not None \
                    and msg.round >= args.die_at_round:
                return 0                # crash: no heartbeat, no result
            tr.send(MASTER, Heartbeat(args.worker, time.monotonic()))
            if state["protocol"] is None:
                raise RuntimeError(
                    f"{me}: round {msg.round} share arrived before "
                    f"provisioning")
            if state["protocol"] == "mpc":
                mpc_round(at, msg)
            elif state["protocol"] == "serve":
                serve_round(at, msg)
            elif str(state["protocol"]).startswith("alcc"):
                alcc_round(at, msg)
            else:
                cpml_round(at, msg)
        return 0
    finally:
        tr.close()


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return serve(args)
    except OSError as e:
        print(f"cpml_worker {args.worker}: cannot reach master at "
              f"{args.host}:{args.port}: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
