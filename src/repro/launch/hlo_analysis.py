"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's HloCostAnalysis counts every `while` body ONCE, so with scan-over-layers
the reported flops/bytes/collectives are ~L-times too small.  This analyzer
walks the call graph from ENTRY, multiplying each while body by its trip
count (recovered from the loop-condition constant), giving per-device:

  * dot flops          (2 * out_elems * contraction_size, incl. nested whiles)
  * bytes accessed     (operands + outputs of every materializing op)
  * collective bytes   (per kind; all-reduce counted 2x = RS+AG)

This is the honest "from the compiled artifact" roofline source; dryrun.py
cross-checks it against the analytic model-FLOPs count.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
# ops that don't touch memory (aliases / metadata)
_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota", "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _sig_dims(sig: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _sig_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _sig_dims(sig):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _strip_meta(line: str) -> str:
    line = _COMMENT_RE.sub("", line)
    for marker in (", metadata=", ", backend_config=", ", frontend_attributes=",
                   ", sharding="):
        i = line.find(marker)
        if i != -1:
            line = line[:i]
    return line


@dataclasses.dataclass
class Instr:
    name: str
    out_sig: str
    op: str
    rest: str          # argument list + attrs (metadata-stripped)
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    symtab: dict[str, str]          # value name -> type signature


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    """Returns ({name: computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw.rstrip())
        if not line:
            continue
        if not line.startswith(" ") and ("{" in line) and ("=" not in line.split("{")[0]):
            # computation header: "%name (...) -> type {" or "ENTRY %name ..."
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        s = _strip_meta(line)
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, sig, op, rest = m.groups()
        # operand names: ONLY inside the argument parens (attrs like
        # condition=%c / body=%b / calls=%f come after the closing paren).
        args = rest.split(")")[0]
        operands = _OPERAND_RE.findall(args)
        cur.instrs.append(Instr(name, sig, op, rest, operands))
        cur.symtab[name] = sig
    assert entry, "no ENTRY computation found"
    return comps, entry


def _dot_flops(instr: Instr, symtab: dict[str, str]) -> float:
    out_elems = 1
    for _, dims in _sig_dims(instr.out_sig):
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    contract = 1
    if m and instr.operands:
        lhs_sig = symtab.get(instr.operands[0], "")
        dims_list = _sig_dims(lhs_sig)
        if dims_list:
            lhs_dims = dims_list[0][1]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _trip_count(cond: Computation) -> int:
    """Max s32 constant in the loop condition — JAX scans compare iter < N."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and "s32" in ins.out_sig:
            m = re.match(r"(-?\d+)\)?", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult


def _attr_target(instr: Instr, attr: str) -> str | None:
    m = re.search(attr + r"=%([\w.\-]+)", instr.rest)
    return m.group(1) if m else None


_UNARY_PASSTHROUGH = {"convert", "bitcast", "copy", "reshape", "transpose",
                      "broadcast"}


def _trace_to_param(comp: Computation, name: str) -> str | None:
    """Follow unary chains back to a fusion parameter, if any."""
    by_name = {i.name: i for i in comp.instrs}
    seen = 0
    while name in by_name and seen < 20:
        ins = by_name[name]
        if ins.op == "parameter":
            return name
        if ins.op in _UNARY_PASSTHROUGH and ins.operands:
            name = ins.operands[0]
            seen += 1
            continue
        return None
    return None


def _fusion_slice_discount(tgt: Computation, ins: Instr, nb: int) -> int:
    """Fusions that only SLICE (or in-place UPDATE) big parameters touch the
    slice, not the buffer — discount the buffer-sized operand charges.
    This matters enormously inside scans: chunked readers would otherwise be
    charged the full carried array every iteration."""
    sliced: dict[str, list[int]] = {}
    for si in tgt.instrs:
        if si.op in ("dynamic-slice", "slice") and si.operands:
            src = _trace_to_param(tgt, si.operands[0])
            if src is not None:
                sliced.setdefault(src, []).append(_sig_bytes(si.out_sig))
        elif si.op == "dynamic-update-slice" and len(si.operands) > 1:
            src = _trace_to_param(tgt, si.operands[0])
            upd = _sig_bytes(tgt.symtab.get(si.operands[1], ""))
            if src is not None:
                buf = _sig_bytes(tgt.symtab.get(src, ""))
                # in place: read+write slice instead of read buf + write buf
                nb -= max(0, 2 * (buf - upd))
    for src, slices in sliced.items():
        buf = _sig_bytes(tgt.symtab.get(src, ""))
        nb -= max(0, buf - sum(slices))
    # NOTE: no output-size floor — a DUS-root fusion's output is the aliased
    # full buffer, which the in-place update never re-writes.
    return max(nb, 0)


# Ops whose operands/outputs we charge to HBM.  Naked elementwise/convert/
# broadcast chains are NOT charged: on TPU they fuse into their consumers
# (XLA CPU leaves more of them unfused, which would inflate the memory term).
# Fusions are charged at the call site — that IS the fusion boundary.
_BYTE_OPS = {"fusion", "call", "dot", "convolution", "reduce", "sort",
             "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
             "copy", "concatenate", "pad", "reduce-window", "select-and-scatter",
             "transpose", "reverse", "cholesky", "triangular-solve", "rng",
             "rng-bit-generator", "reshape", "slice"}


def analyze(text: str, top_k: int = 0) -> dict:
    """Trip-count-aware per-device cost.  top_k > 0 also returns the largest
    collective / byte-moving ops (effective = per-op bytes x trip product)."""
    comps, entry = parse_module(text)
    memo: dict[str, Cost] = {}
    drill: list[tuple[float, str, str]] = []

    def line_cost(comp: Computation, ins: Instr, mult: float,
                  cost_of) -> Cost:
        """Cost of a single instruction (recursing into calls)."""
        c = Cost()
        if ins.op == "while":
            body = _attr_target(ins, "body")
            cond = _attr_target(ins, "condition")
            trips = _trip_count(comps[cond]) if cond in comps else 1
            if body in comps:
                c.add(cost_of(body, mult * trips), trips)
            if cond in comps:
                c.add(cost_of(cond, mult * trips), trips)
            c.bytes += _sig_bytes(ins.out_sig)   # carry moves once
            return c
        if ins.op in ("fusion", "call", "async-start"):
            tgt = _attr_target(ins, "calls") or _attr_target(ins, "to_apply")
            nb = _sig_bytes(ins.out_sig) + sum(
                _sig_bytes(comp.symtab.get(o, "")) for o in ins.operands)
            if tgt in comps:
                sub = cost_of(tgt, mult)
                c.flops += sub.flops              # dots inside fusions
                for k, v in sub.coll.items():
                    c.coll[k] += v
                nb = _fusion_slice_discount(comps[tgt], ins, nb)
            c.bytes += nb
            if top_k:
                drill.append((nb * mult, "bytes", f"{ins.op} {ins.name}"))
            return c
        if ins.op == "conditional":
            for attr in ("true_computation", "false_computation"):
                tgt = _attr_target(ins, attr)
                if tgt in comps:
                    c.add(cost_of(tgt, mult))
            m = re.findall(r"branch_computations=\{([^}]*)\}", ins.rest)
            if m:
                for t in _OPERAND_RE.findall(m[0]):
                    if t in comps:
                        c.add(cost_of(t, mult))
            c.bytes += _sig_bytes(ins.out_sig)
            return c
        base = ins.op.replace("-start", "")
        if base in _COLLECTIVES:
            nbytes = _sig_bytes(ins.out_sig)
            if base == "all-reduce":
                nbytes *= 2
            c.coll[base] += nbytes
            c.bytes += _sig_bytes(ins.out_sig)
            if top_k:
                drill.append((nbytes * mult, "collective",
                              f"{base} {ins.name} {ins.out_sig[:60]}"))
            return c
        if ins.op in _FREE_OPS or ins.op.endswith("-done"):
            return c
        if ins.op in ("dot", "convolution"):
            c.flops += _dot_flops(ins, comp.symtab)
        if ins.op in _BYTE_OPS:
            if ins.op == "dynamic-slice":
                # reads only the slice it extracts
                nb = 2 * _sig_bytes(ins.out_sig)
            elif ins.op == "dynamic-update-slice" and len(ins.operands) > 1:
                # in-place: read-modify-write of the slice region only
                nb = 2 * _sig_bytes(comp.symtab.get(ins.operands[1], ""))
            else:
                nb = _sig_bytes(ins.out_sig) + sum(
                    _sig_bytes(comp.symtab.get(o, "")) for o in ins.operands)
            c.bytes += nb
            if top_k and nb > 0:
                drill.append((nb * mult, "bytes", f"{ins.op} {ins.name}"))
        return c

    def cost_of(name: str, mult: float = 1.0) -> Cost:
        # memoize on name only for totals (mult affects only drill entries;
        # drill dedup below keeps the max-mult occurrence).
        comp = comps.get(name)
        if comp is None:
            return Cost()
        if name in memo and not top_k:
            return memo[name]
        c = Cost()
        for ins in comp.instrs:
            c.add(line_cost(comp, ins, mult, cost_of))
        memo[name] = c
        return c

    c = cost_of(entry)
    out = {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": dict(c.coll),
        "collective_total": float(sum(c.coll.values())),
    }
    if top_k:
        drill.sort(reverse=True)
        out["top_ops"] = [
            {"effective_bytes": round(b), "kind": k, "op": o}
            for b, k, o in drill[:top_k]]
    return out
