"""Coded cluster driver: simulated OR real multi-process deployment.

    python -m repro.launch.cpml_cluster --latency lognormal --iters 25
    python -m repro.launch.cpml_cluster --latency dead --resilient
    python -m repro.launch.cpml_cluster --pipeline full \\
        --encode-cost-s 0.2 --decode-cost-s 0.1
    python -m repro.launch.cpml_cluster --transport socket --iters 10
    python -m repro.launch.cpml_cluster --transport socket --pipeline full
    python -m repro.launch.cpml_cluster --transport socket --kill-worker 5 \\
        --kill-at-round 4
    python -m repro.launch.cpml_cluster --transport socket --masters 2 \\
        --spares 1 --kill-worker 2 --kill-at-round 3 \\
        --heartbeat-timeout 3 --join-at-round 5
    python -m repro.launch.cpml_cluster --transport socket --resilient \\
        --kill-worker 0 --kill-at-round 4
    python -m repro.launch.cpml_cluster --protocol mpc --latency lognormal
    python -m repro.launch.cpml_cluster --protocol mpc --transport socket \\
        --workers 5 --privacy 2 --straggle-worker 4
    python -m repro.launch.cpml_cluster --transport socket --straggle-worker 3 \\
        --trace-out run.trace.json --metrics-out metrics.prom

Runs CodedPrivateML training through the cluster runtime (repro.cluster):
per-round dispatch to N workers, decode at the fastest-`threshold`
responders, and a report of what the wait-for-fastest-T policy saved over
wait-for-all — the paper's headline systems effect, measured per round.

``--transport inprocess`` (default) is the event-driven simulation under a
chosen ``--latency`` profile; ``--resilient`` adds checkpoint/restore
recovery for mid-run worker death (pair with ``--latency dead``).

``--transport socket`` spawns N REAL worker processes on localhost, ships
coded shares as wire frames over TCP, and decodes from the bytes the
fastest responders actually sent — then verifies the weights are
bit-identical to ``train_reference`` replaying the observed responder trace
(DESIGN.md §7: the runtime layer changes when and where rounds execute,
never what they compute).  ``--kill-worker`` crashes one worker mid-run to
demo first-T decode riding through a real death.

``--spares``, ``--join-at-round`` and ``--masters`` exercise the elastic
membership + sharded-master plane (DESIGN.md §13): spare Lagrange
evaluation points absorb mid-run JOINs and permanent LEAVE replacements
without re-encoding the dataset, and a master group of S shards the
per-round encode + streaming decode over contiguous d-slices.  Every
variant stays bit-identical to ``train_reference`` over the observed
responder trace.

``--protocol mpc`` runs the BGW baseline head-to-head over the SAME
runtime: r+1 all-to-all reshare barriers per iteration (workers exchange
SubShares through the master's relay on the socket backend), reconstruction
at the first 2T+1 final shares, and an end-of-run bit-identity check
against the single-host ``mpc_baseline`` oracle.  A straggler stalls every
round (no erasures in BGW) — compare its per-round waits with a coded run
under the same latency profile to see the paper's Fig. 5 effect measured.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import math
import os
import subprocess
import sys
import tempfile
import time

# Documented ALCC verification tolerances (DESIGN.md §14).  A socket run
# replays through train_reference to within ALCC_SOCKET_TOL in max|Δw|
# (XLA-vs-BLAS float32 summation order; sim replays are bit-exact and do
# not use this).  An MLP training run must land within ALCC_MLP_LOSS_TOL
# of the plaintext jax.grad oracle's final full-data loss.
ALCC_SOCKET_TOL = 1e-3
ALCC_MLP_LOSS_TOL = 0.05


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description="CodedPrivateML cluster driver")
    ap.add_argument("--protocol", choices=("cpml", "mpc"), default="cpml",
                    help="cpml = coded training (first-T decode); mpc = the "
                         "BGW baseline run as a real distributed protocol "
                         "over the same runtime (wait-for-all reshare "
                         "barriers, reconstruct at the first 2T+1)")
    ap.add_argument("--engine", choices=("exact", "alcc"), default="exact",
                    help="coded-arithmetic backend (DESIGN.md §14): exact = "
                         "quantized Lagrange coding over F_p with "
                         "bit-identical decode; alcc = real-valued Lagrange "
                         "coding with Gaussian analog masks and a "
                         "least-squares decode whose condition number / "
                         "error budget are tracked per round")
    ap.add_argument("--model", choices=("logreg", "mlp"), default="logreg",
                    help="logreg = the paper's logistic regression; mlp = "
                         "the two-layer gelu MLP (models/layers.py) trained "
                         "as two bilinear coded phases per step — ALCC "
                         "engine only (gelu/softmax are not field "
                         "polynomials)")
    ap.add_argument("--sigma", type=float, default=1.0,
                    help="ALCC Gaussian mask std — the analog privacy knob; "
                         "its cost is proportional decode roundoff "
                         "(--engine alcc only)")
    ap.add_argument("--hidden", type=int, default=32,
                    help="MLP hidden width (--model mlp)")
    ap.add_argument("--eta", type=float, default=0.1,
                    help="MLP step size for both layers (--model mlp; "
                         "logreg keeps the Lipschitz auto-tuned step)")
    ap.add_argument("--workers", "-N", type=int, default=8)
    ap.add_argument("--parallel", "-K", type=int, default=2)
    ap.add_argument("--privacy", "-T", type=int, default=1)
    ap.add_argument("--degree", "-r", type=int, default=1)
    ap.add_argument("--classes", "-c", type=int, default=1)
    ap.add_argument("--m", type=int, default=2000, help="samples")
    ap.add_argument("--d", type=int, default=128, help="features")
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--batch-rows", type=int, default=None)
    ap.add_argument("--transport", choices=("inprocess", "socket"),
                    default="inprocess",
                    help="inprocess = event-driven simulation; socket = "
                         "spawn N real worker processes on localhost")
    ap.add_argument("--pipeline", choices=("off", "prefetch", "streaming",
                                           "full"),
                    default="off",
                    help="overlap master-side coding with in-flight worker "
                         "compute (DESIGN.md §9): prefetch = next round's "
                         "masks/batch/decode-coefficients built during the "
                         "wait; streaming = fold shares into the decode as "
                         "they arrive; full = both.  Bit-identical to off "
                         "in every mode")
    ap.add_argument("--encode-cost-s", type=float, default=0.0,
                    help="modeled master encode seconds per round charged "
                         "to the simulated clock (inprocess only; shows "
                         "the pipelining win on the sim timeline)")
    ap.add_argument("--decode-cost-s", type=float, default=0.0,
                    help="modeled master decode seconds per round "
                         "(inprocess only)")
    ap.add_argument("--latency", choices=("deterministic", "lognormal",
                                          "bursty", "dead"),
                    default="lognormal",
                    help="per-worker latency profile (inprocess only)")
    ap.add_argument("--latency-seed", type=int, default=0)
    ap.add_argument("--round-timeout", type=float, default=math.inf,
                    help="seconds before a round is declared starved "
                         "(required for --latency dead; defaults to 120 "
                         "wall seconds for --transport socket)")
    ap.add_argument("--resilient", action="store_true",
                    help="checkpoint/restore recovery on starved rounds "
                         "(socket: a respawned replacement process is "
                         "reprovisioned over the wire before the replay)")
    ap.add_argument("--checkpoint-every", type=int, default=5)
    # elastic membership + sharded masters (DESIGN.md §13)
    ap.add_argument("--masters", type=int, default=1,
                    help="shard the master role over this many d-slices "
                         "(DESIGN.md §13): each master of the group encodes "
                         "and stream-decodes a contiguous 1/S slice of the "
                         "model dimension — bit-identical to one master, "
                         "1/S the per-master critical path at large d")
    ap.add_argument("--spares", type=int, default=0,
                    help="pre-encode this many spare Lagrange evaluation "
                         "points beyond N (DESIGN.md §13): the alphas are "
                         "consecutive, so shares 0..N-1 are unchanged and "
                         "spare slots absorb elastic JOINs without ever "
                         "re-encoding the dataset")
    ap.add_argument("--join-at-round", type=int, default=None,
                    help="elastic JOIN demo: admit one extra worker at this "
                         "round's fence (socket: spawns a real late-joiner "
                         "process that announces itself with a JOIN frame; "
                         "inprocess: a scheduled join); implies --spares 1")
    # socket-transport options
    ap.add_argument("--port", type=int, default=0,
                    help="master TCP port (0 = ephemeral)")
    ap.add_argument("--kill-worker", type=int, default=None,
                    help="crash this worker index mid-run (socket only)")
    ap.add_argument("--kill-at-round", type=int, default=4,
                    help="round at which --kill-worker crashes")
    ap.add_argument("--straggle-worker", type=int, default=None,
                    help="make this worker sleep before every reply "
                         "(socket only)")
    ap.add_argument("--straggle-sleep", type=float, default=0.25)
    ap.add_argument("--collect-all", action="store_true",
                    help="keep each round open until every dispatched "
                         "worker responds, so the wait-for-all "
                         "counterfactual is measured on the real clock "
                         "(socket only; do not combine with --kill-worker)")
    ap.add_argument("--heartbeat-timeout", type=float, default=math.inf,
                    help="wall seconds of heartbeat silence before a worker "
                         "drops from the dispatch set (socket only)")
    ap.add_argument("--wire", choices=("v1", "v2"), default="v2",
                    help="wire protocol version for the socket transport "
                         "(DESIGN.md §10): v2 = bit-packed, coalesced, "
                         "scatter-gather frames negotiated at HELLO; v1 = "
                         "force the legacy format end to end (master AND "
                         "spawned workers) for byte-for-byte comparison")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the bit-identity check vs train_reference "
                         "(socket only)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json-out", type=str, default=None)
    # flight recorder (DESIGN.md §11) — off unless asked for: the recorder
    # costs nothing when absent (NullRecorder no-ops on every hot-path site)
    ap.add_argument("--trace-out", type=str, default=None,
                    help="record a flight trace and write Perfetto/Chrome "
                         "trace-event JSON here (load at ui.perfetto.dev or "
                         "chrome://tracing); also prints a terminal "
                         "waterfall + straggler attribution post-run")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the run's metrics registry here: a *.json "
                         "path gets the JSON snapshot, anything else the "
                         "Prometheus textfile format")
    return ap


def _worker_env() -> dict[str, str]:
    """Environment for a spawned cpml_worker: this tree on PYTHONPATH,
    CPU-pinned jax."""
    src_root = os.path.abspath(os.path.join(
        os.path.dirname(__file__), "..", ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def spawn_worker(port: int, w: int, *, env: dict[str, str] | None = None,
                 wire_version: int = 2, die_at_round: int | None = None,
                 sleep_s: float | None = None,
                 join_at_round: int | None = None) -> subprocess.Popen:
    """Start one cpml_worker process for slot ``w`` against the master
    listening on ``port``.  Also the resilient-restore respawn primitive:
    a replacement for a dead slot is spawned exactly like the original."""
    cmd = [sys.executable, "-m", "repro.launch.cpml_worker",
           "--host", "127.0.0.1", "--port", str(port),
           "--worker", str(w), "--wire", str(wire_version)]
    if die_at_round is not None:
        cmd += ["--die-at-round", str(die_at_round)]
    if sleep_s is not None:
        cmd += ["--sleep-s", str(sleep_s)]
    if join_at_round is not None:
        cmd += ["--join-at-round", str(join_at_round)]
    return subprocess.Popen(cmd,
                            env=env if env is not None else _worker_env())


@contextlib.contextmanager
def local_socket_cluster(n_workers: int, *, port: int = 0,
                         die_at_round: dict[int, int] | None = None,
                         sleep_s: dict[int, float] | None = None,
                         join_at_round: dict[int, int] | None = None,
                         connect_timeout_s: float = 60.0,
                         poll_interval_s: float = 0.02,
                         wire_version: int = 2):
    """Spawn N cpml_worker processes against a fresh master transport.

    Yields the master ``SocketTransport`` once every worker has connected
    and HELLOed.  On exit the worker processes are terminated and the
    transport closed.  Reused by benchmarks/bench_socket.py and the slow
    socket tests, so every consumer launches workers the same way.
    ``wire_version=1`` forces the legacy wire format on the master AND every
    spawned worker (the v1 baseline for byte-for-byte comparison).

    ``join_at_round={slot: round}`` additionally spawns elastic late
    joiners (DESIGN.md §13): each runs with ``--join-at-round`` and is NOT
    provisioned with the base fleet — it announces a JOIN and waits for the
    master's fence to admit it.  The yielded transport carries the spawned
    process list as ``tr.procs`` so a resilient respawn hook can append
    replacements and have the exit path reap them too.
    """
    from repro.cluster.socket_transport import SocketTransport
    from repro.cluster.messages import worker_endpoint

    env = _worker_env()
    tr = SocketTransport.master(port=port, poll_interval_s=poll_interval_s,
                                wire_version=wire_version)
    procs: list[subprocess.Popen] = []
    tr.procs = procs
    try:
        for w in range(n_workers):
            procs.append(spawn_worker(
                tr.port, w, env=env, wire_version=wire_version,
                die_at_round=(die_at_round or {}).get(w),
                sleep_s=(sleep_s or {}).get(w)))
        for w, at_round in (join_at_round or {}).items():
            procs.append(spawn_worker(tr.port, w, env=env,
                                      wire_version=wire_version,
                                      join_at_round=at_round))
        # joiners connect (and JOIN) right away too: waiting for their
        # HELLO here makes the admission round deterministic for tests —
        # admission itself still only happens at the master's fence
        expect = [*range(n_workers), *(join_at_round or {})]
        tr.wait_for_endpoints([worker_endpoint(w) for w in expect],
                              timeout_s=connect_timeout_s)
        yield tr
    finally:
        tr.close()
        deadline = time.monotonic() + 10.0
        for p in procs:
            # closing the transport hangs up on every worker, which exits
            # its serve loop; escalate only if one wedges.
            try:
                p.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()


def _recorder_for(args):
    """A live Recorder when --trace-out asked for one, else None (the
    runners fall back to the no-op NullRecorder)."""
    if args.trace_out is None:
        return None
    from repro.obs.trace import Recorder
    return Recorder()


def _emit_obs(args, runner, threshold: int) -> None:
    """Post-run observability outputs: Perfetto trace file, terminal
    waterfall, straggler attribution, metrics registry dump."""
    if args.trace_out:
        from repro.obs.export import (straggler_report, waterfall,
                                      write_chrome_trace)
        obj = write_chrome_trace(runner.obs, args.trace_out)
        pids = {e.get("pid") for e in obj["traceEvents"]}
        print(f"trace: {len(obj['traceEvents'])} events / {len(pids)} "
              f"process(es) -> {args.trace_out} (load at ui.perfetto.dev)")
        print(waterfall(runner.obs))
        text, _ = straggler_report(runner.traces, threshold)
        print(text)
    if args.metrics_out:
        runner.metrics.write(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")


def _validate(args) -> int | None:
    """The cross-flag refusal matrix: every structurally impossible combo
    dies here with one clear sentence on stderr and rc 2, mirroring the
    historical --pipeline-with-MPC refusal.  Returns None when the combo
    is runnable."""
    if args.engine == "alcc" and args.protocol == "mpc":
        print("--engine alcc cannot run --protocol mpc: BGW is an exact "
              "finite-field protocol (Shamir shares, modular reshare "
              "barriers) — there is no analog/float variant of its "
              "degree reduction", file=sys.stderr)
        return 2
    if args.model == "mlp":
        if args.protocol == "mpc":
            print("--model mlp is a coded-protocol feature: the BGW "
                  "baseline reproduces the paper's logistic task only",
                  file=sys.stderr)
            return 2
        if args.engine != "alcc":
            print("--model mlp needs --engine alcc: gelu and softmax are "
                  "not finite-field polynomials, so the exact engine "
                  "structurally cannot train the MLP (DESIGN.md §14)",
                  file=sys.stderr)
            return 2
        if args.resilient or args.collect_all:
            print("--resilient/--collect-all are not wired into the MLP "
                  "plane yet — drop them or use --model logreg",
                  file=sys.stderr)
            return 2
    if args.engine == "alcc":
        if args.pipeline != "off":
            print("--pipeline modes are exact-engine only: they split the "
                  "FIELD encode/decode (prefetchable mask rows, integer "
                  "streaming folds) — the ALCC least-squares decode has "
                  "no such split", file=sys.stderr)
            return 2
        if args.masters > 1 or args.spares or args.join_at_round is not None:
            print("--masters/--spares/--join-at-round are exact-engine "
                  "only: the elastic + sharded-master planes rely on "
                  "bit-identical re-encode, which a float engine cannot "
                  "promise", file=sys.stderr)
            return 2
        if args.transport == "socket" and args.wire == "v1":
            print("--engine alcc needs --wire v2: float round shares and "
                  "results are wire v2 frames (like TRACE/JOIN) — a v1 "
                  "fleet has no frame for them", file=sys.stderr)
            return 2
    return None


def _run_socket(args, cfg, key, x, y) -> tuple:
    """--transport socket: N real worker processes, wire frames, wall clock."""
    import numpy as np

    from repro.cluster import ClusterRunner
    from repro.core import protocol
    from repro.core.protocol import alcc_engine

    die = ({args.kill_worker: args.kill_at_round}
           if args.kill_worker is not None else None)
    sleep = ({args.straggle_worker: args.straggle_sleep}
             if args.straggle_worker is not None else None)
    timeout = args.round_timeout
    if math.isinf(timeout):
        timeout = 120.0         # real silence must be detectable
    wv = int(args.wire[1:])
    spares = args.spares
    join = None
    if args.join_at_round is not None:
        spares = max(spares, 1)
        join = {cfg.N: args.join_at_round}      # first spare slot
    with local_socket_cluster(cfg.N, port=args.port, die_at_round=die,
                              sleep_s=sleep, join_at_round=join,
                              wire_version=wv) as tr:
        runner = ClusterRunner(cfg, key, x, y, latency=None, transport=tr,
                               round_timeout_s=timeout,
                               heartbeat_timeout_s=args.heartbeat_timeout,
                               collect_all=args.collect_all,
                               pipeline=args.pipeline,
                               spares=spares, masters=args.masters,
                               recorder=_recorder_for(args),
                               engine=args.engine)
        runner.provision()
        t0 = time.monotonic()
        if args.resilient:
            from repro.checkpoint.manager import CheckpointManager
            from repro.cluster.messages import worker_endpoint
            env = _worker_env()

            def respawn(worker: int, step: int) -> None:
                # a starved round's restore asks for a fresh process for
                # each dead slot; the runner reprovisions it over the wire
                # and waits for its ack before replaying
                tr.procs.append(spawn_worker(tr.port, worker, env=env,
                                             wire_version=wv))
                tr.wait_for_endpoints([worker_endpoint(worker)],
                                      timeout_s=60.0)

            with tempfile.TemporaryDirectory() as ckdir:
                mgr = CheckpointManager(ckdir, async_write=False)
                w = runner.run_resilient(
                    args.iters, mgr,
                    checkpoint_every=args.checkpoint_every, respawn=respawn)
        else:
            w = runner.run(args.iters)
        wall_s = time.monotonic() - t0
        runner.shutdown_workers()
    print(f"socket run: {args.iters} rounds over TCP in {wall_s:.1f}s "
          f"({wall_s / args.iters * 1e3:.0f} ms/round)")
    if args.resilient:
        print(f"resilient socket run: {runner.restarts} restart(s), each "
              f"respawning + reprovisioning the dead slot over TCP")
    stats = runner.wait_stats()
    memb = stats["membership"]
    if memb["joins"] or memb["leaves"]:
        print(f"membership: epoch {int(memb['epoch'])}, "
              f"{int(memb['members'])} member(s) "
              f"({int(memb['joins'])} join(s), {int(memb['leaves'])} "
              f"leave(s), {int(memb['spares_left'])} spare(s) left)")
    if args.masters > 1:
        g = stats["masters"]
        print(f"sharded masters x{args.masters}: per-master critical path "
              f"{g['critical_path_s']:.3f}s (group totals: encode "
              f"{g['encode_total_s']:.3f}s, decode {g['decode_total_s']:.3f}s)")
    if "wire_totals" in stats:
        tot, per = stats["wire_totals"], stats["wire_tx_bytes"]
        print(f"wire [{args.wire}]: {tot['tx_bytes'] / 1e6:.2f} MB tx / "
              f"{tot['rx_bytes'] / 1e6:.2f} MB rx total "
              f"({per['mean'] / 1e3:.1f} kB/round tx, "
              f"{stats['wire_rx_bytes']['mean'] / 1e3:.1f} kB/round rx, "
              f"{int(tot['tx_frames'])} frames out)")
    if die:
        dead = set(die)
        late = [t for t, rec in runner.records.items()
                if dead & set(map(int, rec.survivors))]
        print(f"killed worker(s) {sorted(dead)} at round "
              f"{args.kill_at_round}: last decoded in round "
              f"{max(late) if late else '-'}; first-T decode rode through")
    if not args.no_verify:
        if args.engine == "alcc":
            # ALCC socket verification is tolerance-exact, not bit-exact:
            # the replay's BLAS einsum and the workers' XLA kernels may sum
            # float32 dot products in different orders (DESIGN.md §14's
            # documented contract — ALCC_SOCKET_TOL)
            w_ref, _ = alcc_engine.train_reference(
                runner.cfg, key, x, y, iters=args.iters,
                survivor_fn=runner.survivor_fn())
            gap = float(np.max(np.abs(np.asarray(w) - np.asarray(w_ref))))
            ok = gap <= ALCC_SOCKET_TOL
            print(f"train_reference replay over the observed responder "
                  f"trace: max|Δw| = {gap:.2e} "
                  f"(tolerance {ALCC_SOCKET_TOL:.0e}): "
                  f"{'OK' if ok else 'FAILED'}")
            if not ok:
                return runner, w, 1
        else:
            # runner.cfg is the spare-extended config when elastic (the
            # reference replays the SAME N+spares scheme over the observed
            # responder trace — bit-identity is the elastic invariant)
            w_ref, _ = protocol.train_reference(
                runner.cfg, key, x, y, iters=args.iters,
                survivor_fn=runner.survivor_fn())
            same = bool((np.asarray(w) == np.asarray(w_ref)).all())
            print(f"bit-identical to train_reference over the observed "
                  f"responder trace: {same}")
            if not same:
                return runner, w, 1
    return runner, w, 0


def _run_mpc(args) -> int:
    """--protocol mpc: the BGW baseline head-to-head on the same runtime."""
    import jax
    import numpy as np

    from repro.cluster.mpc_runner import MPCClusterRunner, mpc_phase_models
    from repro.core import mpc_baseline, protocol
    from repro.data import synthetic

    if args.resilient:
        print("--resilient is meaningless for MPC: BGW has no erasure "
              "tolerance — a starved round is terminal", file=sys.stderr)
        return 2
    if args.pipeline != "off":
        print("--pipeline applies to the coded protocol only: every BGW "
              "reshare barrier consumes the previous phase's output, so "
              "there is no W-independent master work to overlap",
              file=sys.stderr)
        return 2
    if args.classes != 1:
        print("--protocol mpc supports the paper's binary task only",
              file=sys.stderr)
        return 2
    if args.kill_worker is not None:
        print("--kill-worker is meaningless for MPC: a crashed worker "
              "starves the reshare barrier and ends the run (that is the "
              "paper's point) — use --straggle-worker to slow one instead",
              file=sys.stderr)
        return 2
    if args.masters > 1 or args.spares or args.join_at_round is not None:
        print("--masters/--spares/--join-at-round are coded-protocol "
              "features: BGW bakes N into every reshare (no spare "
              "evaluation points to join on) and its master only "
              "reconstructs", file=sys.stderr)
        return 2
    cfg = mpc_baseline.MPCConfig(N=args.workers, T=args.privacy,
                                 r=args.degree)
    mode = (args.latency if args.transport == "inprocess"
            else f"socket x{cfg.N} procs")
    print(f"BGW MPC baseline: N={cfg.N} T={cfg.T} r={cfg.r} "
          f"collect=2T+1={2 * cfg.T + 1} [{mode}] — every degree reduction "
          f"is an all-to-all barrier")
    key = jax.random.PRNGKey(args.seed)
    x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=args.m, d=args.d,
                                margin=12.0)
    rc = 0
    if args.transport == "socket":
        timeout = args.round_timeout
        if math.isinf(timeout):
            timeout = 120.0
        sleep = ({args.straggle_worker: args.straggle_sleep}
                 if args.straggle_worker is not None else None)
        with local_socket_cluster(cfg.N, port=args.port, sleep_s=sleep,
                                  wire_version=int(args.wire[1:])) as tr:
            runner = MPCClusterRunner(
                cfg, key, x, y, None, transport=tr,
                round_timeout_s=timeout,
                heartbeat_timeout_s=args.heartbeat_timeout,
                recorder=_recorder_for(args))
            runner.provision()
            t0 = time.monotonic()
            w = runner.run(args.iters)
            wall_s = time.monotonic() - t0
            runner.shutdown_workers()
        print(f"socket MPC run: {args.iters} rounds over TCP in "
              f"{wall_s:.1f}s ({wall_s / args.iters * 1e3:.0f} ms/round, "
              f"{args.degree} reshare barrier(s) each)")
    else:
        models = mpc_phase_models(args.latency, seed=args.latency_seed,
                                  r=cfg.r)
        timeout = args.round_timeout
        if args.latency == "dead" and math.isinf(timeout):
            timeout = 60.0
        runner = MPCClusterRunner(cfg, key, x, y, models,
                                  round_timeout_s=timeout,
                                  recorder=_recorder_for(args))
        w = runner.run(args.iters)
    _emit_obs(args, runner, runner.collect_threshold)
    stats = runner.wait_stats()
    word = "wall" if args.transport == "socket" else "simulated"
    print(f"per-round MPC wait (dispatch -> 2T+1 reconstruct): "
          f"mean {stats['mpc']['mean']:.2f}s  p50 {stats['mpc']['p50']:.2f}s "
          f"p95 {stats['mpc']['p95']:.2f}s "
          f"({word} total {stats['mpc']['total']:.1f}s)")
    if not args.no_verify:
        w_ref, _ = mpc_baseline.train(cfg, key, x, y, iters=args.iters)
        same = bool((np.asarray(w) == np.asarray(w_ref)).all())
        print(f"bit-identical to the single-host mpc_baseline oracle: {same}")
        if not same:
            rc = 1
    _, acc = protocol.loss_and_accuracy(w, runner.state.xq_real, y)
    print(f"accuracy: mpc {float(acc):.2%}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(_json_finite(
                {"config": {"N": cfg.N, "T": cfg.T, "r": cfg.r,
                            "protocol": "mpc",
                            "transport": args.transport,
                            "latency": (args.latency
                                        if args.transport == "inprocess"
                                        else None),
                            "iters": args.iters},
                 "wait_stats": stats,
                 "acc_mpc": float(acc)}), f, indent=2)
    return rc


def _run_mlp(args) -> int:
    """--model mlp: the two-phase coded gelu MLP under ALCC (DESIGN.md
    §14) — the model the exact engine structurally cannot train."""
    import jax
    import numpy as np

    from repro.cluster import make_latency
    from repro.cluster.alcc_mlp import ALCCMLPRunner, train_reference
    from repro.core.protocol import alcc_engine
    from repro.data import synthetic

    c = max(args.classes, 2)        # softmax head: binary becomes 2-class
    cfg = alcc_engine.ALCCConfig(N=args.workers, K=args.parallel,
                                 T=args.privacy, c=c, sigma=args.sigma,
                                 batch_rows=args.batch_rows)
    mode = (args.latency if args.transport == "inprocess"
            else f"socket x{cfg.N} procs")
    print(f"ALCC MLP cluster: N={cfg.N} K={cfg.K} T={cfg.T} c={c} "
          f"hidden={args.hidden} sigma={cfg.sigma} "
          f"phase-threshold={cfg.mlp_threshold} [{mode}]")
    key = jax.random.PRNGKey(args.seed)
    x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(1),
                                           m=args.m, d=args.d, c=c)
    rc = 0
    if args.transport == "socket":
        timeout = args.round_timeout
        if math.isinf(timeout):
            timeout = 120.0
        sleep = ({args.straggle_worker: args.straggle_sleep}
                 if args.straggle_worker is not None else None)
        die = ({args.kill_worker: args.kill_at_round}
               if args.kill_worker is not None else None)
        with local_socket_cluster(cfg.N, port=args.port, sleep_s=sleep,
                                  die_at_round=die,
                                  wire_version=int(args.wire[1:])) as tr:
            runner = ALCCMLPRunner(cfg, key, x, y, args.hidden,
                                   latency=None, transport=tr,
                                   eta=args.eta, round_timeout_s=timeout,
                                   recorder=_recorder_for(args))
            runner.provision()
            t0 = time.monotonic()
            w1, w2 = runner.run(args.iters)
            wall_s = time.monotonic() - t0
            runner.shutdown_workers()
        print(f"socket MLP run: {args.iters} steps (2 coded phases each) "
              f"over TCP in {wall_s:.1f}s "
              f"({wall_s / args.iters * 1e3:.0f} ms/step)")
    else:
        latency = make_latency(args.latency, seed=args.latency_seed)
        runner = ALCCMLPRunner(cfg, key, x, y, args.hidden, latency,
                               eta=args.eta,
                               round_timeout_s=args.round_timeout,
                               recorder=_recorder_for(args))
        runner.run(args.iters)
        w1, w2 = runner.w1, runner.w2
    if args.trace_out or args.metrics_out:
        _emit_obs(args, runner, cfg.mlp_threshold)
    stats = runner.wait_stats()
    a = stats["alcc"]
    print(f"alcc decode: cond p95 {a['cond']['p95']:.1f}, error budget "
          f"p95 {a['abs_err_budget']['p95']:.2e}, "
          f"{int(a['fallbacks']['n'])} fallback(s)")
    coded = stats["coded_T"]
    print(f"per-phase wait  coded-T: mean {coded['mean']:.3f}s  "
          f"p50 {coded['p50']:.3f}s  p95 {coded['p95']:.3f}s")
    loss, acc = runner.metrics_now()
    ow1, ow2 = alcc_engine.mlp_oracle(cfg, key, x, y, args.hidden,
                                      args.iters, args.eta)
    oloss, oacc = alcc_engine.mlp_metrics(runner.state, ow1, ow2)
    print(f"MLP loss {loss:.4f} / acc {acc:.2%} vs plaintext jax.grad "
          f"oracle {oloss:.4f} / {oacc:.2%} "
          f"(|Δloss| = {abs(loss - oloss):.2e}, "
          f"tolerance {ALCC_MLP_LOSS_TOL})")
    if abs(loss - oloss) > ALCC_MLP_LOSS_TOL:
        rc = 1
    if not args.no_verify:
        w1r, w2r, _ = train_reference(cfg, key, x, y, args.hidden,
                                      args.iters, args.eta,
                                      survivor_fn=runner.survivor_fn())
        gap = max(float(np.max(np.abs(np.asarray(w1) - np.asarray(w1r)))),
                  float(np.max(np.abs(np.asarray(w2) - np.asarray(w2r)))))
        if args.transport == "socket":
            ok = gap <= ALCC_SOCKET_TOL
            print(f"train_reference replay: max|Δw| = {gap:.2e} "
                  f"(tolerance {ALCC_SOCKET_TOL:.0e}): "
                  f"{'OK' if ok else 'FAILED'}")
        else:
            ok = gap == 0.0
            print(f"bit-identical to train_reference over the observed "
                  f"responder trace: {ok}")
        if not ok:
            rc = 1
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(_json_finite(
                {"config": {"N": cfg.N, "K": cfg.K, "T": cfg.T, "c": c,
                            "engine": "alcc", "model": "mlp",
                            "hidden": args.hidden, "sigma": cfg.sigma,
                            "eta": args.eta,
                            "transport": args.transport,
                            "iters": args.iters},
                 "wait_stats": stats,
                 "loss_coded": float(loss), "acc_coded": float(acc),
                 "loss_oracle": float(oloss),
                 "acc_oracle": float(oacc)}), f, indent=2)
    return rc


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    rc = _validate(args)
    if rc is not None:
        return rc

    if args.protocol == "mpc":
        return _run_mpc(args)
    if args.model == "mlp":
        return _run_mlp(args)

    import jax

    from repro.cluster import ClusterRunner, make_latency
    from repro.core import protocol
    from repro.core.protocol import alcc_engine
    from repro.data import synthetic

    if args.engine == "alcc":
        cfg = alcc_engine.ALCCConfig(N=args.workers, K=args.parallel,
                                     T=args.privacy, r=args.degree,
                                     c=args.classes, sigma=args.sigma,
                                     batch_rows=args.batch_rows)
    else:
        cfg = protocol.CPMLConfig(N=args.workers, K=args.parallel,
                                  T=args.privacy, r=args.degree,
                                  c=args.classes,
                                  batch_rows=args.batch_rows)
    mode = (args.latency if args.transport == "inprocess"
            else f"socket x{cfg.N} procs")
    print(f"CPML cluster [{args.engine}]: N={cfg.N} K={cfg.K} T={cfg.T} "
          f"r={cfg.r} c={cfg.c} threshold={cfg.threshold} [{mode}]")

    key = jax.random.PRNGKey(args.seed)
    if cfg.c == 1:
        x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=args.m,
                                    d=args.d, margin=12.0)
    else:
        x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(1),
                                               m=args.m, d=args.d, c=cfg.c)

    rc = 0
    if args.transport == "socket":
        runner, w, rc = _run_socket(args, cfg, key, x, y)
    else:
        kw = {}
        if args.latency == "dead" and args.resilient:
            # kill one worker more than coding tolerates, so the run
            # exercises checkpoint restore + reprovision (a single death at
            # N=8 is absorbed by the first-T decode with no restart at all)
            spare = cfg.N - cfg.threshold
            kw["deaths"] = {w: 3 for w in range(spare + 1)}
        latency = make_latency(args.latency, seed=args.latency_seed, **kw)
        timeout = args.round_timeout
        if args.latency == "dead" and math.isinf(timeout):
            timeout = 60.0          # a dead worker must be detectable
        spares = args.spares
        join_schedule = None
        if args.join_at_round is not None:
            spares = max(spares, 1)
            join_schedule = {cfg.N: args.join_at_round}  # first spare slot
        runner = ClusterRunner(cfg, key, x, y, latency,
                               round_timeout_s=timeout,
                               heartbeat_timeout_s=args.heartbeat_timeout,
                               pipeline=args.pipeline,
                               encode_cost_s=args.encode_cost_s,
                               decode_cost_s=args.decode_cost_s,
                               spares=spares, masters=args.masters,
                               join_schedule=join_schedule,
                               recorder=_recorder_for(args),
                               engine=args.engine)
        if args.resilient:
            from repro.checkpoint.manager import CheckpointManager
            with tempfile.TemporaryDirectory() as ckdir:
                mgr = CheckpointManager(ckdir, async_write=False)
                w = runner.run_resilient(
                    args.iters, mgr, checkpoint_every=args.checkpoint_every)
            print(f"resilient run: {runner.restarts} restart(s)")
        else:
            w = runner.run(args.iters)

    _emit_obs(args, runner, cfg.threshold)
    stats = runner.wait_stats()
    memb = stats["membership"]
    if args.transport != "socket" and (memb["joins"] or memb["leaves"]):
        # (the socket path already printed its membership line)
        print(f"membership: epoch {int(memb['epoch'])}, "
              f"{int(memb['members'])} member(s) "
              f"({int(memb['joins'])} join(s), {int(memb['leaves'])} "
              f"leave(s), {int(memb['spares_left'])} spare(s) left)")
    coded, allw = stats["coded_T"], stats["wait_all"]
    print(f"per-round wait  coded-T: mean {coded['mean']:.2f}s  "
          f"p50 {coded['p50']:.2f}s  p95 {coded['p95']:.2f}s")
    if args.pipeline != "off" or args.encode_cost_s or args.decode_cost_s:
        cp, enc, dec = (stats["critical_path"], stats["encode"],
                        stats["decode"])
        print(f"per-round critical path [{args.pipeline}]: "
              f"mean {cp['mean']:.3f}s = encode {enc['mean']:.3f}s + wait "
              f"+ decode {dec['mean']:.3f}s  "
              f"({int(stats['rounds']['prefetched'])} prefetched, "
              f"{int(stats['rounds']['streamed'])} streamed rounds)")
    unobserved = int(stats["rounds"]["dead_rounds"])
    # an UNOBSERVED wait-for-all series is all-zero (wait_summary zeroes an
    # empty input), so gate on total > 0 rather than finiteness
    if allw["total"] > 0:
        print(f"per-round wait wait-all: mean {allw['mean']:.2f}s  "
              f"p50 {allw['p50']:.2f}s  p95 {allw['p95']:.2f}s")
    if unobserved and args.transport == "socket" and not args.collect_all:
        print(f"(wait-for-all unobserved in first-T mode: the master moved "
              f"on at the threshold-th arrival every round; rerun with "
              f"--collect-all to measure it)")
    elif unobserved:
        print(f"({unobserved} round(s) had a dead worker: wait-for-all "
              f"would NEVER complete; wait-all stats cover the "
              f"{int(stats['rounds']['n']) - unobserved} finite rounds)")
    if unobserved == 0 and allw["total"] > 0 and math.isfinite(allw["total"]):
        word = "wall" if args.transport == "socket" else "simulated"
        print(f"{word} training time: {coded['total']:.1f}s coded-T vs "
              f"{allw['total']:.1f}s wait-all "
              f"({allw['total'] / coded['total']:.2f}x speedup)")

    if args.engine == "alcc":
        import numpy as np
        a = stats["alcc"]
        print(f"alcc decode: cond p95 {a['cond']['p95']:.1f}, error budget "
              f"p95 {a['abs_err_budget']['p95']:.2e}, "
              f"{int(a['fallbacks']['n'])} fallback(s)")
        if args.transport != "socket" and not args.no_verify:
            # sim replay is bit-exact (same numpy ops on the same inputs);
            # the socket path already verified inside _run_socket
            w_ref, _ = alcc_engine.train_reference(
                cfg, key, x, y, iters=args.iters,
                survivor_fn=runner.survivor_fn())
            same = bool((np.asarray(w) == np.asarray(w_ref)).all())
            print(f"bit-identical to train_reference over the observed "
                  f"responder trace: {same}")
            if not same:
                rc = 1
        # accuracy vs the UNCODED float oracle (same surrogate, batches
        # and steps — the gap is pure coding/decoding float error)
        w_oracle = alcc_engine.float_oracle(cfg, key, x, y, args.iters)
        metric = (protocol.loss_and_accuracy if cfg.c == 1
                  else protocol.multiclass_loss_and_accuracy)
        x_eval = runner.state.xq_real[: runner.state.m]
        _, acc = metric(w, x_eval, y)
        _, acc_ref = metric(w_oracle, x_eval, y)
        print(f"accuracy: coded {float(acc):.2%} vs uncoded float oracle "
              f"{float(acc_ref):.2%}")
    else:
        # accuracy vs the cleartext quantized baseline, same step count
        wc, xq = protocol.cleartext_baseline(cfg, x, y, args.iters)
        metric = (protocol.loss_and_accuracy if cfg.c == 1
                  else protocol.multiclass_loss_and_accuracy)
        _, acc = metric(w, xq, y)
        _, acc_ref = metric(wc, xq, y)
        print(f"accuracy: coded {float(acc):.2%} vs cleartext baseline "
              f"{float(acc_ref):.2%}")

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(_json_finite({"config": {"N": cfg.N, "K": cfg.K, "T": cfg.T,
                                  "r": cfg.r, "c": cfg.c,
                                  "engine": args.engine,
                                  "sigma": (args.sigma
                                            if args.engine == "alcc"
                                            else None),
                                  "masters": args.masters,
                                  "spares": args.spares,
                                  "transport": args.transport,
                                  "latency": (args.latency
                                              if args.transport == "inprocess"
                                              else None),
                                  "iters": args.iters},
                       "wait_stats": stats,
                       "restarts": getattr(runner, "restarts", 0),
                       "acc_coded": float(acc),
                       "acc_baseline": float(acc_ref)}), f, indent=2)
    return rc


def _json_finite(obj):
    """inf/nan -> null recursively: json.dump would emit bare ``Infinity``
    tokens (rejected by strict RFC-8259 parsers), and unobserved wait-all
    stats are legitimately inf on a first-T socket run."""
    if isinstance(obj, dict):
        return {k: _json_finite(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_finite(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj


if __name__ == "__main__":
    sys.exit(main())
