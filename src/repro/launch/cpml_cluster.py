"""Coded cluster simulation driver (the runtime analogue of cpml_train).

    python -m repro.launch.cpml_cluster --latency lognormal --iters 25
    python -m repro.launch.cpml_cluster --latency dead --resilient

Runs CodedPrivateML training through the event-driven cluster runtime
(repro.cluster): per-round dispatch to N simulated workers under a chosen
latency profile, decode at the fastest-`threshold` responders, and a report
of what the wait-for-fastest-T policy saved over wait-for-all — the paper's
headline systems effect, measured per round.  ``--resilient`` adds
checkpoint/restore recovery for mid-run worker death (pair with
``--latency dead``).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import tempfile


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description="CodedPrivateML cluster sim")
    ap.add_argument("--workers", "-N", type=int, default=8)
    ap.add_argument("--parallel", "-K", type=int, default=2)
    ap.add_argument("--privacy", "-T", type=int, default=1)
    ap.add_argument("--degree", "-r", type=int, default=1)
    ap.add_argument("--classes", "-c", type=int, default=1)
    ap.add_argument("--m", type=int, default=2000, help="samples")
    ap.add_argument("--d", type=int, default=128, help="features")
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--batch-rows", type=int, default=None)
    ap.add_argument("--latency", choices=("deterministic", "lognormal",
                                          "bursty", "dead"),
                    default="lognormal", help="per-worker latency profile")
    ap.add_argument("--latency-seed", type=int, default=0)
    ap.add_argument("--round-timeout", type=float, default=math.inf,
                    help="simulated seconds before a round is declared "
                         "starved (required for --latency dead)")
    ap.add_argument("--resilient", action="store_true",
                    help="checkpoint/restore recovery on starved rounds")
    ap.add_argument("--checkpoint-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json-out", type=str, default=None)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    import jax

    from repro.cluster import ClusterRunner, make_latency
    from repro.core import protocol
    from repro.data import synthetic

    cfg = protocol.CPMLConfig(N=args.workers, K=args.parallel,
                              T=args.privacy, r=args.degree, c=args.classes,
                              batch_rows=args.batch_rows)
    print(f"CPML cluster: N={cfg.N} K={cfg.K} T={cfg.T} r={cfg.r} c={cfg.c} "
          f"threshold={cfg.threshold} latency={args.latency}")

    key = jax.random.PRNGKey(args.seed)
    if cfg.c == 1:
        x, y = synthetic.mnist_like(jax.random.PRNGKey(1), m=args.m,
                                    d=args.d, margin=12.0)
    else:
        x, y = synthetic.multiclass_mnist_like(jax.random.PRNGKey(1),
                                               m=args.m, d=args.d, c=cfg.c)

    kw = {}
    if args.latency == "dead" and args.resilient:
        # kill one worker more than coding tolerates, so the run exercises
        # checkpoint restore + reprovision (a single death at N=8 is
        # absorbed by the first-T decode with no restart at all)
        spare = cfg.N - cfg.threshold
        kw["deaths"] = {w: 3 for w in range(spare + 1)}
    latency = make_latency(args.latency, seed=args.latency_seed, **kw)
    timeout = args.round_timeout
    if args.latency == "dead" and math.isinf(timeout):
        timeout = 60.0          # a dead worker must be detectable
    runner = ClusterRunner(cfg, key, x, y, latency,
                           round_timeout_s=timeout)
    if args.resilient:
        from repro.checkpoint.manager import CheckpointManager
        with tempfile.TemporaryDirectory() as ckdir:
            mgr = CheckpointManager(ckdir, async_write=False)
            w = runner.run_resilient(args.iters, mgr,
                                     checkpoint_every=args.checkpoint_every)
        print(f"resilient run: {runner.restarts} restart(s)")
    else:
        w = runner.run(args.iters)

    stats = runner.wait_stats()
    coded, allw = stats["coded_T"], stats["wait_all"]
    print(f"per-round wait  coded-T: mean {coded['mean']:.2f}s  "
          f"p50 {coded['p50']:.2f}s  p95 {coded['p95']:.2f}s")
    print(f"per-round wait wait-all: mean {allw['mean']:.2f}s  "
          f"p50 {allw['p50']:.2f}s  p95 {allw['p95']:.2f}s")
    dead_rounds = int(stats["rounds"]["dead_rounds"])
    if dead_rounds:
        print(f"({dead_rounds} round(s) had a dead worker: wait-for-all "
              f"would NEVER complete; wait-all stats cover the "
              f"{int(stats['rounds']['n']) - dead_rounds} finite rounds)")
    if dead_rounds == 0 and allw["total"] > 0 and math.isfinite(allw["total"]):
        print(f"simulated training time: {coded['total']:.1f}s coded-T vs "
              f"{allw['total']:.1f}s wait-all "
              f"({allw['total'] / coded['total']:.2f}x speedup)")

    # accuracy vs the cleartext quantized baseline, same step count
    wc, xq = protocol.cleartext_baseline(cfg, x, y, args.iters)
    metric = (protocol.loss_and_accuracy if cfg.c == 1
              else protocol.multiclass_loss_and_accuracy)
    _, acc = metric(w, xq, y)
    _, acc_ref = metric(wc, xq, y)
    print(f"accuracy: coded {float(acc):.2%} vs cleartext baseline "
          f"{float(acc_ref):.2%}")

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"config": {"N": cfg.N, "K": cfg.K, "T": cfg.T,
                                  "r": cfg.r, "c": cfg.c,
                                  "latency": args.latency,
                                  "iters": args.iters},
                       "wait_stats": stats,
                       "restarts": getattr(runner, "restarts", 0),
                       "acc_coded": float(acc),
                       "acc_cleartext": float(acc_ref)}, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
