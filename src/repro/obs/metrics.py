"""Metrics registry: counters/gauges/histograms with two exporters.

One registry per runner (DESIGN.md §11).  The instruments are deliberately
minimal — monotone counters, last-value gauges, fixed-bucket histograms —
because everything heavier (percentiles over full series, waterfalls) comes
out of the span trace, not the metrics.  Two export formats:

  * ``to_prometheus()`` — the textfile exposition format, ready for a
    node-exporter textfile collector (``cpml_cluster --metrics-out``);
  * ``snapshot()`` — a plain JSON-able dict (bench reports, tests).

Updating a metric is a couple of dict/float operations; the registry is
always on (like the wire byte counters it aggregates) and its cost rides
under the same bench_cluster.py overhead gate as the recorder.
"""
from __future__ import annotations

import json
import math

# Default histogram buckets: wait/latency seconds, log-ish spaced from
# 100 µs to ~2 min.  +Inf is implicit (the _count line).
DEFAULT_BUCKETS = (1e-4, 1e-3, 5e-3, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0, 30.0, 120.0)


class Counter:
    """Monotone float counter."""

    kind = "counter"

    def __init__(self, name: str, help_: str = ""):
        self.name, self.help = name, help_
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        self.value += amount


class Gauge:
    """Last-written value."""

    kind = "gauge"

    def __init__(self, name: str, help_: str = ""):
        self.name, self.help = name, help_
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Histogram:
    """Fixed cumulative buckets + sum + count (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help_: str = "",
                 buckets: tuple = DEFAULT_BUCKETS):
        self.name, self.help = name, help_
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * len(self.buckets)   # per-bucket (non-cumulative)
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        value = float(value)
        if math.isnan(value):
            return                   # an unobserved wait is not a sample
        self.count += 1
        self.sum += value if math.isfinite(value) else 0.0
        for i, le in enumerate(self.buckets):
            if value <= le:
                self.counts[i] += 1
                break


class MetricsRegistry:
    """Named instruments, get-or-create, stable iteration order."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help_: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help_, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._get(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._get(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "",
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help_, buckets=buckets)

    # ------------------------------------------------------------------
    # Export
    # ------------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able view of every instrument."""
        out: dict = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                out[name] = {"kind": m.kind, "count": m.count, "sum": m.sum,
                             "buckets": {_le(le): c for le, c
                                         in zip(m.buckets, m.counts)}}
            else:
                out[name] = {"kind": m.kind, "value": m.value}
        return out

    def to_prometheus(self) -> str:
        """Prometheus textfile exposition format."""
        lines: list[str] = []
        for name, m in self._metrics.items():
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for le, c in zip(m.buckets, m.counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{_le(le)}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{name}_sum {_num(m.sum)}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"{name} {_num(m.value)}")
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> None:
        """``.json`` -> snapshot dump; anything else -> Prometheus text."""
        if path.endswith(".json"):
            with open(path, "w") as f:
                json.dump(self.snapshot(), f, indent=2)
        else:
            with open(path, "w") as f:
                f.write(self.to_prometheus())


def _le(le: float) -> str:
    return f"{le:g}"


def _num(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return f"{v:g}"
