"""Flight recorder for the cluster runtime (DESIGN.md §11).

Zero-dependency observability substrate: ``trace`` (begin/end spans on a
pluggable clock — SimClock and WallClock runs produce the same trace
SHAPE), ``metrics`` (counters/gauges/histograms with Prometheus-textfile
and JSON exporters), ``export`` (Chrome trace-event / Perfetto JSON, the
terminal waterfall, and the straggler-attribution report).

Tracing is off by default: every instrumented call site holds a
``NullRecorder`` whose methods are no-ops, so the recorder costs nothing
unless a run opts in (gated in benchmarks/bench_cluster.py).
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import NULL_RECORDER, NullRecorder, Recorder, Span

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_RECORDER", "NullRecorder", "Recorder", "Span",
]
