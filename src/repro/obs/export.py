"""Trace exporters: Perfetto/Chrome JSON, terminal waterfall, straggler
attribution, and the trace-event schema validator CI runs.

Chrome trace-event mapping (DESIGN.md §11): one pid per PROCESS (pid 0 is
the master; each worker process that shipped spans over the TRACE wire
field gets its own pid), one tid per TRACK within a process (the master's
own timeline, one flight lane per worker, the prefetch thread).  Because
every process stamps spans on its OWN monotonic clock, each pid's
timestamps are normalized to that process's first event — orderings are
meaningful within a pid and never across pids (the master's flight spans,
stamped on the master clock, are the cross-worker comparison surface).
"""
from __future__ import annotations

import json
import math

from repro.obs.trace import MASTER_PROCESS, PH_INSTANT, PH_SPAN, Recorder

_US = 1e6                             # trace-event timestamps are in µs


def to_chrome_trace(rec: Recorder) -> dict:
    """Recorder -> Perfetto-loadable trace-event JSON object."""
    spans = [s for s in rec.spans if not (s.ph == PH_SPAN and s.open)]
    procs: list[str] = []
    tracks: dict[str, list[str]] = {}
    for s in spans:
        if s.process not in procs:
            procs.append(s.process)
        tl = tracks.setdefault(s.process, [])
        if s.track not in tl:
            tl.append(s.track)
    # stable ids: master first, then the rest by name (worker pids line up
    # with worker indices regardless of whose trace landed first)
    procs.sort(key=lambda p: (p != MASTER_PROCESS, p))
    pid_of = {p: i for i, p in enumerate(procs)}
    tid_of = {(p, t): i for p in procs
              for i, t in enumerate(sorted(tracks[p]))}
    t0 = {p: min((s.start for s in spans if s.process == p),
                 default=0.0) for p in procs}

    events: list[dict] = []
    for p in procs:
        events.append({"name": "process_name", "ph": "M", "pid": pid_of[p],
                       "tid": 0, "args": {"name": p}})
        for t in sorted(tracks[p]):
            events.append({"name": "thread_name", "ph": "M",
                           "pid": pid_of[p], "tid": tid_of[(p, t)],
                           "args": {"name": t}})
    body = []
    for s in spans:
        ev = {"name": s.name, "cat": "cpml", "ph": s.ph,
              "ts": (s.start - t0[s.process]) * _US,
              "pid": pid_of[s.process], "tid": tid_of[(s.process, s.track)],
              "args": {k: _jsonable(v) for k, v in s.args.items()}}
        if s.ph == PH_SPAN:
            ev["dur"] = max(0.0, s.duration) * _US
        else:
            ev["s"] = "t"            # instant scoped to its thread
        body.append(ev)
    body.sort(key=lambda e: (e["pid"], e["tid"], e["ts"]))
    return {"traceEvents": events + body, "displayTimeUnit": "ms",
            "otherData": {"clock_note":
                          "per-pid monotonic clocks, normalized per process;"
                          " timestamps are comparable within a pid only"}}


def write_chrome_trace(rec: Recorder, path: str) -> dict:
    obj = to_chrome_trace(rec)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_chrome_trace(obj) -> list[str]:
    """Schema check for the trace-event JSON (the CI gate): names present,
    known phases, numeric non-negative ts/dur, ts monotone per (pid, tid).
    Returns a list of problems — empty means valid."""
    errors: list[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be a dict with a traceEvents list"]
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["traceEvents must be a non-empty list"]
    last_ts: dict[tuple, float] = {}
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not a dict")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if not ev.get("name"):
            errors.append(f"{where}: empty name")
        if "pid" not in ev or "tid" not in ev:
            errors.append(f"{where}: missing pid/tid")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0 or math.isnan(ts):
            errors.append(f"{where}: bad ts {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0 \
                    or not math.isfinite(dur):
                errors.append(f"{where}: bad dur {dur!r}")
        key = (ev["pid"], ev["tid"])
        if ts < last_ts.get(key, -math.inf):
            errors.append(f"{where}: ts {ts} not monotone on pid/tid {key}")
        last_ts[key] = ts
    return errors


# ---------------------------------------------------------------------------
# Terminal views
# ---------------------------------------------------------------------------

def round_summaries(rec: Recorder) -> list[dict]:
    """Per-round master-side components read back from the spans: the
    reconciliation surface wait_stats is checked against (tests + the
    bench trace gate)."""
    rounds: dict[int, dict] = {}
    for s in rec.spans:
        if s.process != MASTER_PROCESS or "round" not in s.args:
            continue
        t = s.args["round"]
        if not isinstance(t, int) or t < 0:
            continue
        r = rounds.setdefault(t, {"round": t})
        if s.name in ("encode", "wait", "decode") and not s.open:
            r[s.name] = s.duration
    out = []
    for t in sorted(rounds):
        r = rounds[t]
        r.setdefault("encode", 0.0)
        r.setdefault("wait", 0.0)
        r.setdefault("decode", 0.0)
        r["critical_path"] = r["encode"] + r["wait"] + r["decode"]
        out.append(r)
    return out


def waterfall(rec: Recorder, width: int = 48, max_rounds: int = 20) -> str:
    """Fixed-width per-round waterfall: encode (#) | wait (.) | decode (%),
    scaled to the slowest round."""
    rows = round_summaries(rec)
    if not rows:
        return "(no round spans recorded)"
    shown = rows[:max_rounds]
    peak = max(r["critical_path"] for r in shown) or 1.0
    lines = [f"round  {'encode':>9} {'wait':>9} {'decode':>9}  "
             f"critical path (scaled to {peak:.3f}s)"]
    for r in shown:
        cells = ""
        for key, ch in (("encode", "#"), ("wait", "."), ("decode", "%")):
            cells += ch * max(1 if r[key] > 0 else 0,
                              round(r[key] / peak * width))
        lines.append(f"{r['round']:>5}  {r['encode']:>8.3f}s {r['wait']:>8.3f}s "
                     f"{r['decode']:>8.3f}s  |{cells}")
    if len(rows) > max_rounds:
        lines.append(f"  ... {len(rows) - max_rounds} more round(s)")
    return "\n".join(lines)


def straggler_report(traces: dict, threshold: int) -> tuple[str, dict]:
    """Post-run straggler attribution from the observed RoundTraces: per
    worker, how often it was dispatched but missed the decode set, how
    often it was excluded from dispatch outright, and the marginal wait
    attributable to it (for rounds where it WAS the threshold-th arrival:
    the gap it added over the (threshold-1)-th).
    """
    stats: dict[int, dict] = {}
    all_workers: set[int] = set()
    finite_rounds = 0
    for tr in traces.values():
        all_workers.update(int(w) for w in tr.dispatched)
    for tr in sorted(traces.values(), key=lambda r: r.round):
        # RoundTrace stamps the threshold-th arrival as t_first_R; the MPC
        # trace calls the analogous (2T+1)-th final share t_done
        t_thresh = getattr(tr, "t_first_R", None)
        if t_thresh is None:
            t_thresh = tr.t_done
        if not math.isfinite(t_thresh):
            continue
        finite_rounds += 1
        dispatched = {int(w) for w in tr.dispatched}
        order = [int(w) for w in tr.responders]
        decoded = set(order[:threshold])
        for w in sorted(all_workers):
            s = stats.setdefault(w, {"dispatched": 0, "missed_decode": 0,
                                     "excluded": 0, "marginal_wait_s": 0.0,
                                     "decisive": 0})
            if w in dispatched:
                s["dispatched"] += 1
                if w not in decoded:
                    s["missed_decode"] += 1
            else:
                s["excluded"] += 1
        if len(order) >= threshold:
            last = order[threshold - 1]
            prev_t = (tr.arrivals[order[threshold - 2]] if threshold >= 2
                      else tr.t_start)
            gap = tr.arrivals[last] - prev_t
            stats[last]["decisive"] += 1
            stats[last]["marginal_wait_s"] += max(0.0, gap)
    if not stats:
        return "(no completed rounds to attribute)", {}
    lines = [f"straggler attribution over {finite_rounds} round(s) "
             f"(threshold {threshold}):",
             f"{'worker':>6} {'dispatched':>10} {'missed-T':>9} "
             f"{'excluded':>9} {'decisive':>9} {'wait attributed':>16}"]
    for w in sorted(stats):
        s = stats[w]
        lines.append(f"{w:>6} {s['dispatched']:>10} {s['missed_decode']:>9} "
                     f"{s['excluded']:>9} {s['decisive']:>9} "
                     f"{s['marginal_wait_s']:>15.3f}s")
    worst = max(stats, key=lambda w: (stats[w]["marginal_wait_s"]
                                      + stats[w]["missed_decode"]))
    s = stats[worst]
    lines.append(f"slowest: worker {worst} — missed the decode set "
                 f"{s['missed_decode']}/{s['dispatched']} dispatched rounds, "
                 f"added {s['marginal_wait_s']:.3f}s of decisive wait")
    return "\n".join(lines), stats


def _jsonable(v):
    if isinstance(v, (str, int, bool)) or v is None:
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else None
    return str(v)
