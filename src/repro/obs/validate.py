"""Trace-event schema validator CLI (the CI gate for --trace-out output).

    python -m repro.obs.validate run.trace.json

Exit 0 when the file parses as trace-event JSON and passes
``export.validate_chrome_trace`` (names present, known phases, numeric
timestamps, ts monotone per (pid, tid)); exit 1 with the problems printed
otherwise.
"""
from __future__ import annotations

import json
import sys

from repro.obs.export import validate_chrome_trace


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python -m repro.obs.validate <trace.json>",
              file=sys.stderr)
        return 2
    try:
        with open(argv[0]) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{argv[0]}: not readable trace JSON: {e}", file=sys.stderr)
        return 1
    errors = validate_chrome_trace(obj)
    if errors:
        for e in errors:
            print(f"{argv[0]}: {e}", file=sys.stderr)
        return 1
    n = len(obj["traceEvents"])
    pids = {e.get("pid") for e in obj["traceEvents"]}
    print(f"{argv[0]}: OK — {n} events across {len(pids)} process(es)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
