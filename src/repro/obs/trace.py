"""Span tracer: explicit begin/end intervals on a pluggable clock.

The recorder is deliberately dumb — a thread-safe, append-only list of
``Span``s plus a per-(process, track) stack of open spans for parent
attribution.  All interpretation (Perfetto export, waterfalls, straggler
attribution) lives in obs/export.py.

Clock discipline (DESIGN.md §11): the recorder reads time through one
``clock_fn``.  The cluster runner binds it to the scheduler's clock
(``EventScheduler.time.now``), so a SimClock run records simulated seconds
and a WallClock run records ``time.monotonic()`` seconds THROUGH THE SAME
CALL SITES — the two backends produce the same span names and nesting, only
the numbers differ (pinned by tests/test_obs.py).  Spans shipped from other
processes (worker-side recv/compute/serialize/send) arrive via
``add_process_spans`` under their own process name: worker monotonic clocks
share no epoch with the master's, so cross-process timestamps are ordered
only WITHIN a process and are never compared across clock domains.

``NullRecorder`` is the off-by-default path: every method is a constant
no-op (shared singleton context manager, no allocation, no clock read), so
instrumented code costs nothing when tracing is off — the overhead gate in
benchmarks/bench_cluster.py holds the recorder to that claim.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time as _time
from typing import Any, Callable

MASTER_PROCESS = "master"
MASTER_TRACK = "master"

# Chrome trace-event phases the recorder emits (export.py writes them out
# verbatim): complete spans and instant events.
PH_SPAN = "X"
PH_INSTANT = "i"


@dataclasses.dataclass(eq=False)          # identity semantics: the parent
class Span:                               # stacks pop by object, not value
    """One interval (or instant) on one track of one process's timeline.

    ``process`` names the clock domain (``"master"`` or ``"worker3"``);
    ``track`` is a timeline within it (the master's own critical path, one
    per-worker flight lane, the prefetch thread).  ``parent`` is the name of
    the span that was open on the same (process, track) when this one began
    — the nesting tests key on it.
    """
    name: str
    start: float
    end: float = math.nan            # NaN while still open
    process: str = MASTER_PROCESS
    track: str = MASTER_TRACK
    parent: str | None = None
    ph: str = PH_SPAN
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def open(self) -> bool:
        return math.isnan(self.end)

    @property
    def duration(self) -> float:
        return self.end - self.start


class Recorder:
    """Thread-safe span store with begin/end + externally-timed intervals.

    ``clock_fn`` defaults to ``time.monotonic``; ``bind_clock`` lets the
    owner of the authoritative clock (the scheduler) repoint it once the
    clock exists.  Thread safety covers concurrent appenders on DISTINCT
    tracks (the prefetch thread records under ``track="prefetch"`` while the
    main thread records under ``"master"``); interleaving begin/end on one
    track from two threads would corrupt that track's parent stack and is
    not supported.
    """

    enabled = True

    def __init__(self, clock_fn: Callable[[], float] | None = None,
                 process: str = MASTER_PROCESS):
        self._clock = clock_fn or _time.monotonic
        self.process = process
        self.spans: list[Span] = []
        self._lock = threading.Lock()
        self._stacks: dict[tuple[str, str], list[Span]] = {}

    def bind_clock(self, clock_fn: Callable[[], float]) -> None:
        """Repoint the recorder at the authoritative clock (the scheduler's
        SimClock/WallClock), so sim and wall runs share call sites."""
        self._clock = clock_fn

    def now(self) -> float:
        return self._clock()

    # ------------------------------------------------------------------
    # Live spans (clocked at the recorder)
    # ------------------------------------------------------------------

    def begin(self, name: str, track: str = MASTER_TRACK, **args) -> Span:
        s = Span(name=name, start=self.now(), process=self.process,
                 track=track, args=args)
        with self._lock:
            stack = self._stacks.setdefault((self.process, track), [])
            if stack:
                s.parent = stack[-1].name
            stack.append(s)
            self.spans.append(s)
        return s

    def end(self, span: Span, **args) -> Span:
        span.end = self.now()
        if args:
            span.args.update(args)
        with self._lock:
            stack = self._stacks.get((span.process, span.track), [])
            if span in stack:
                # close any child left open (exception unwound past it):
                # every span must close — the invariant tests rely on it
                while stack:
                    top = stack.pop()
                    if top is span:
                        break
                    if top.open:
                        top.end = span.end
        return span

    def span(self, name: str, track: str = MASTER_TRACK, **args):
        """Context manager: ``with rec.span("collect", round=t): ...``"""
        return _SpanScope(self, name, track, args)

    def instant(self, name: str, track: str = MASTER_TRACK, **args) -> Span:
        t = self.now()
        s = Span(name=name, start=t, end=t, process=self.process,
                 track=track, ph=PH_INSTANT, args=args)
        with self._lock:
            stack = self._stacks.get((self.process, track), [])
            if stack:
                s.parent = stack[-1].name
            self.spans.append(s)
        return s

    # ------------------------------------------------------------------
    # Externally-timed intervals (clocked by the caller)
    # ------------------------------------------------------------------

    def add_span(self, name: str, start: float, end: float,
                 track: str = MASTER_TRACK, **args) -> Span:
        """Record an interval measured OUTSIDE the recorder but in the
        recorder's own clock domain (e.g. the runner's encode wall, or a
        flight span reconstructed from a RoundTrace arrival time)."""
        s = Span(name=name, start=start, end=end, process=self.process,
                 track=track, args=args)
        with self._lock:
            stack = self._stacks.get((self.process, track), [])
            if stack:
                s.parent = stack[-1].name
            self.spans.append(s)
        return s

    def add_process_spans(self, process: str, spans, **args) -> None:
        """Ingest spans shipped from another process (the worker's TRACE
        wire field): ``spans`` is a list of ``[name, start, end]`` triples
        in THAT process's monotonic clock.  They are stored under the
        foreign process name and never mixed into this recorder's stacks —
        cross-clock nesting would be meaningless (DESIGN.md §11)."""
        batch = []
        for item in spans:
            try:
                name, start, end = item[0], float(item[1]), float(item[2])
            except (TypeError, ValueError, IndexError):
                continue                     # a malformed triple is dropped,
                                             # never poisons the master trace
            batch.append(Span(name=str(name), start=start, end=end,
                              process=process, track="rounds",
                              args=dict(args)))
        with self._lock:
            self.spans.extend(batch)

    # ------------------------------------------------------------------
    # Views
    # ------------------------------------------------------------------

    def open_spans(self) -> list[Span]:
        with self._lock:
            return [s for s in self.spans if s.ph == PH_SPAN and s.open]

    def find(self, name: str, process: str | None = None) -> list[Span]:
        with self._lock:
            return [s for s in self.spans if s.name == name
                    and (process is None or s.process == process)]


class _SpanScope:
    __slots__ = ("_rec", "_name", "_track", "_args", "span")

    def __init__(self, rec: Recorder, name: str, track: str, args: dict):
        self._rec, self._name, self._track, self._args = (rec, name, track,
                                                          args)
        self.span: Span | None = None

    def __enter__(self) -> Span:
        self.span = self._rec.begin(self._name, self._track, **self._args)
        return self.span

    def __exit__(self, *exc) -> None:
        self._rec.end(self.span)


class _NullScope:
    """One shared no-op context manager for every NullRecorder.span call."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        pass


_NULL_SCOPE = _NullScope()


class NullRecorder:
    """The provably-cheap off switch: no clock reads, no allocation, no
    locking — every instrumented call site goes through these constant
    no-ops when tracing is off (the default)."""

    enabled = False
    spans: tuple = ()

    def bind_clock(self, clock_fn) -> None:
        pass

    def now(self) -> float:
        return 0.0

    def begin(self, name, track=MASTER_TRACK, **args):
        return None

    def end(self, span, **args):
        return None

    def span(self, name, track=MASTER_TRACK, **args):
        return _NULL_SCOPE

    def instant(self, name, track=MASTER_TRACK, **args):
        return None

    def add_span(self, name, start, end, track=MASTER_TRACK, **args):
        return None

    def add_process_spans(self, process, spans, **args) -> None:
        pass

    def open_spans(self) -> list:
        return []

    def find(self, name, process=None) -> list:
        return []


NULL_RECORDER = NullRecorder()


def structure(rec, process: str = MASTER_PROCESS
              ) -> set[tuple[str, str, str | None]]:
    """The trace's SHAPE: ``{(track-class, name, parent)}`` for one process,
    with per-worker track indices collapsed (``worker/3`` -> ``worker/*``).

    Two runs of the same config — simulated or socket — must produce the
    same structure even though durations, worker indices hit, and span
    MULTIPLICITY (ties at the decode instant) differ (tests/test_obs.py).
    """
    out = set()
    for s in rec.spans:
        if s.process != process:
            continue
        track = s.track.split("/")[0] + "/*" if "/" in s.track else s.track
        out.add((track, s.name, s.parent))
    return out
