"""Transformer substrate layers (pure-JAX, sharding-annotation friendly).

Attention is blockwise ("flash-style" at the XLA level): a python loop over
query blocks with a lax.scan over only the STATICALLY-valid kv blocks per
query block (causal upper bound, sliding-window lower bound).  This keeps the
S x S logits tensor out of HBM — mandatory for the 32k cells — and also
removes the masked-out FLOPs from the compiled HLO (2x for causal, much more
for SWA), which shows up directly in the roofline compute term.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig


class ParamSpec(NamedTuple):
    """Template leaf: shape + logical axis names (sharding) + init scale."""
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"      # normal | zeros | ones


# ---------------------------------------------------------------------------
# primitive forwards
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def swiglu(x: jax.Array, w1, w3, w2) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_mlp(x: jax.Array, w1, w2) -> jax.Array:
    return jax.nn.gelu(x @ w1) @ w2


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, S, H, D), positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise attention (training / prefill)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int | None):
    """(qb, kb) additive bias: 0 valid, -inf invalid."""
    valid = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        valid &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        valid &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(valid, 0.0, -jnp.inf).astype(jnp.float32)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        q_block: int = 512, kv_block: int = 1024,
                        softcap: float | None = None,
                        compute_dtype: str = "f32",
                        row_offset: jax.Array | None = None) -> jax.Array:
    """Online-softmax attention.  q: (B,S,H,D), k/v: (B,Sk,KH,D) -> (B,S,H,D).

    Per query block the kv range is STATIC: [window-lower-bound, causal-upper-
    bound), rounded to kv_block tiles, so masked tiles are never computed.
    compute_dtype="bf16" feeds the QK and PV matmuls bf16 inputs with fp32
    accumulation (flash-attention numerics) — halves score-tile HBM traffic.
    """
    in_dt = jnp.bfloat16 if compute_dtype == "bf16" else jnp.float32
    B, S, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    q_block = min(q_block, S)
    kv_block = min(kv_block, Sk)
    nq = -(-S // q_block)
    nk_total = -(-Sk // kv_block)
    q = (q * (D ** -0.5)).astype(q.dtype)
    # pad to block multiples
    Sp, Skp = nq * q_block, nk_total * kv_block
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    qg = q.reshape(B, Sp, KH, G, D)
    outs = []
    # query i has absolute position row_offset + i.  A traced row_offset
    # (context-parallel shards) forces full static kv ranges + masking;
    # a python-int offset lets the block ranges skip masked tiles entirely.
    traced_off = row_offset is not None
    offset = row_offset if traced_off else (Sk - S)
    for i in range(nq):
        qi = jax.lax.dynamic_slice_in_dim(qg, i * q_block, q_block, 1)
        q_pos = offset + i * q_block + jnp.arange(q_block)
        if traced_off:
            lo, hi = 0, nk_total
        else:
            # static kv tile range for this query block
            hi = min(nk_total, -(-(offset + (i + 1) * q_block) // kv_block)) \
                if causal else nk_total
            lo = 0
            if window is not None:
                lo = max(0, (offset + i * q_block - window + 1) // kv_block)
            hi = max(hi, lo + 1)

        def kv_step(carry, j, qi=qi, q_pos=q_pos):
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(k, j * kv_block, kv_block, 1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * kv_block, kv_block, 1)
            k_pos = j * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(in_dt),
                           kj.astype(in_dt),
                           preferred_element_type=jnp.float32)
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            bias = _mask_bias(q_pos, k_pos, causal, window)
            # also mask kv padding
            bias = jnp.where((k_pos < Sk)[None, :], bias, -jnp.inf)
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            # fully-masked tiles (SWA rows whose window misses this tile)
            # leave m_new = -inf; exp(-inf - -inf) = nan — zero them instead.
            dead = jnp.isneginf(m_new)
            p = jnp.where(dead[..., None], 0.0, jnp.exp(s - m_new[..., None]))
            corr = jnp.where(dead, 0.0, jnp.exp(m - m_new))
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(in_dt),
                            vj.astype(in_dt),
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        # derive carries from qi so they inherit device-varying types under
        # shard_map (context-parallel path) — fresh zeros would be
        # replicated-typed and fail the scan carry check.
        qt = qi.transpose(0, 2, 3, 1, 4).astype(jnp.float32)  # (B,KH,G,qb,D)
        m0 = jnp.full_like(qt[..., 0], -jnp.inf)
        l0 = jnp.zeros_like(qt[..., 0])
        a0 = jnp.zeros_like(qt)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(lo, hi))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(out.transpose(0, 3, 1, 2, 4))       # (B, qb, KH, G, D)
    out = jnp.concatenate(outs, axis=1)[:, :S]
    return out.reshape(B, S, H, D).astype(q.dtype)


def context_parallel_attention(mesh, q: jax.Array, k: jax.Array,
                               v: jax.Array, *, causal: bool = True,
                               window: int | None = None, q_block: int = 512,
                               kv_block: int = 1024,
                               softcap: float | None = None,
                               compute_dtype: str = "f32") -> jax.Array:
    """Sequence-sharded self-attention for head counts that don't divide the
    TP axis (arctic 56, hymba 25, qwen2-vl 28, whisper 6).

    Each 'model' shard owns S/tp query rows (perfect load balance regardless
    of head count) and all-gathers the small GQA k/v once per layer —
    replacing GSPMD's fallback of 16x-replicated attention or score-tensor
    all-reduces (EXPERIMENTS.md §Perf cell B).  shard_map + explicit
    collectives; causality handled with a traced per-shard row offset.
    """
    from jax.sharding import PartitionSpec as P
    B, S, H, D = q.shape
    tp = mesh.shape["model"]
    assert S % tp == 0, (S, tp)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b_ok = batch_axes and B % int(np.prod([mesh.shape[a]
                                           for a in batch_axes])) == 0
    bspec = ((batch_axes if len(batch_axes) > 1 else batch_axes[0])
             if b_ok else None)
    spec = P(bspec, "model", None, None)
    qb = min(q_block, S // tp)

    def body(q_l, k_l, v_l):
        k_f = jax.lax.all_gather(k_l, "model", axis=1, tiled=True)
        v_f = jax.lax.all_gather(v_l, "model", axis=1, tiled=True)
        off = jax.lax.axis_index("model") * (S // tp)
        return blockwise_attention(
            q_l, k_f, v_f, causal=causal, window=window, q_block=qb,
            kv_block=kv_block, softcap=softcap, compute_dtype=compute_dtype,
            row_offset=off)

    from repro.parallel import compat
    return compat.shard_map(body, mesh, (spec, spec, spec), spec,
                            check=True)(q, k, v)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window: int | None = None
                     ) -> jax.Array:
    """Single-position attention vs a cache.

    q: (B, 1, H, D); k/v_cache: (B, Smax, KH, D); cache_len: () int32 —
    number of valid cache positions INCLUDING the current token.
    """
    B, _, H, D = q.shape
    _, Smax, KH, _ = k_cache.shape
    G = H // KH
    qg = q.reshape(B, KH, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    k_pos = jnp.arange(Smax)
    valid = k_pos[None, :] < cache_len
    if window is not None:
        valid &= k_pos[None, :] > (cache_len - 1 - window)
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (params + forward)
# ---------------------------------------------------------------------------

def attn_template(cfg: ModelConfig) -> dict[str, ParamSpec]:
    """QKV/O projections.  The flattened heads*head_dim dim carries a
    COUNT-qualified logical axis `heads[n]`: the sharding rules only put it
    on the model axis when the head COUNT divides the axis — sharding the
    flat dim of a non-divisible head count makes GSPMD reshard at the
    (B,S,H,D) reshape and all-reduce score tensors (observed: 16x redundant
    attention for arctic's 56 heads; EXPERIMENTS.md §Perf cell B)."""
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    hq, hkv = f"heads[{h}]", f"heads[{kh}]"
    t = {
        "wq": ParamSpec((d, h * hd), ("embed", hq)),
        "wk": ParamSpec((d, kh * hd), ("embed", hkv)),
        "wv": ParamSpec((d, kh * hd), ("embed", hkv)),
        "wo": ParamSpec((h * hd, d), (hq, "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((h * hd,), (hq,), init="zeros")
        t["bk"] = ParamSpec((kh * hd,), (hkv,), init="zeros")
        t["bv"] = ParamSpec((kh * hd,), (hkv,), init="zeros")
    return t


def attn_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"] + (p.get("bq", 0))
    k = x @ p["wk"] + (p.get("bk", 0))
    v = x @ p["wv"] + (p.get("bv", 0))
    q = rope(q.reshape(B, S, h, hd), positions, cfg.rope_theta)
    k = rope(k.reshape(B, S, kh, hd), positions, cfg.rope_theta)
    return q, k, v.reshape(B, S, kh, hd)


def attn_forward(cfg: ModelConfig, rc: RunConfig, p: dict, x: jax.Array,
                 positions: jax.Array, *, causal: bool = True,
                 window: int | None = None) -> jax.Array:
    q, k, v = attn_qkv(cfg, p, x, positions)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              q_block=rc.q_block, kv_block=rc.kv_block,
                              softcap=cfg.attn_logit_softcap,
                              compute_dtype=rc.attn_dtype)
    B, S, _ = x.shape
    return out.reshape(B, S, -1) @ p["wo"]


def attn_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                cache_index: jax.Array, *, window: int | None = None
                ) -> tuple[jax.Array, dict]:
    """x: (B, 1, d); cache: {'k','v'} (B, Smax, KH, hd). Returns (out, cache)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), cache_index, jnp.int32)
    q, k, v = attn_qkv(cfg, p, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, 1)
    out = decode_attention(q, k_cache, v_cache, cache_index + 1, window=window)
    new_cache = {"k": k_cache, "v": v_cache}
    return out.reshape(B, 1, -1) @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLP block
# ---------------------------------------------------------------------------

def mlp_template(cfg: ModelConfig, ff: int | None = None) -> dict[str, ParamSpec]:
    d = cfg.d_model
    ff = ff or cfg.d_ff
    if cfg.act == "silu":
        return {"w1": ParamSpec((d, ff), ("embed", "ffn")),
                "w3": ParamSpec((d, ff), ("embed", "ffn")),
                "w2": ParamSpec((ff, d), ("ffn", "embed"))}
    return {"w1": ParamSpec((d, ff), ("embed", "ffn")),
            "w2": ParamSpec((ff, d), ("ffn", "embed"))}


def mlp_forward(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        return swiglu(x, p["w1"], p["w3"], p["w2"])
    return gelu_mlp(x, p["w1"], p["w2"])
