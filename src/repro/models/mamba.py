"""Mamba-1 selective SSM block (falcon-mamba / hymba's mamba branch).

Training/prefill uses a CHUNKED parallel scan: lax.scan over sequence chunks
carrying the (B, d_inner, n) state, with an associative_scan inside each
chunk.  This bounds the materialized (B, chunk, d_inner, n) tensor — the
full-sequence associative scan would need B*S*d_inner*n elements (~TBs for
falcon-mamba train_4k), the TPU-native equivalent of the paper's fused CUDA
kernel trick (DESIGN.md hardware-adaptation).

Decode is the exact single-step recurrence with (conv window, ssm state)
carried in the cache — O(1) per token, the reason SSM archs run long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.layers import ParamSpec


def mamba_template(cfg: ModelConfig, d_model: int | None = None
                   ) -> dict[str, ParamSpec]:
    d = d_model or cfg.d_model
    di, n, dtr, cw = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.conv_width
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamSpec((cw, di), (None, "inner")),
        "conv_b": ParamSpec((di,), ("inner",), init="zeros"),
        "x_proj": ParamSpec((di, dtr + 2 * n), ("inner", None)),
        "dt_proj": ParamSpec((dtr, di), (None, "inner")),
        "dt_bias": ParamSpec((di,), ("inner",), init="ones"),
        "A_log": ParamSpec((di, n), ("inner", None), dtype=jnp.float32,
                           init="ones"),
        "D": ParamSpec((di,), ("inner",), dtype=jnp.float32, init="ones"),
        "out_proj": ParamSpec((di, d), ("inner", "embed")),
    }


def _ssm_params(p: dict, x: jax.Array):
    """x: (B, L, di) post-conv activations -> (dt, B_mat, C_mat)."""
    dtr = p["dt_proj"].shape[0]
    n = (p["x_proj"].shape[1] - dtr) // 2
    proj = x @ p["x_proj"]                                   # (B, L, dtr+2n)
    dt = jax.nn.softplus(proj[..., :dtr] @ p["dt_proj"]
                         + p["dt_bias"].astype(proj.dtype))  # (B, L, di)
    Bm = proj[..., dtr: dtr + n]                             # (B, L, n)
    Cm = proj[..., dtr + n:]                                 # (B, L, n)
    return dt.astype(jnp.float32), Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _discretize(p, dt, Bm, x, dtype=jnp.float32):
    """a = exp(dt*A) (B,L,di,n); b = dt*B*x (B,L,di,n)."""
    A = -jnp.exp(p["A_log"])                                 # (di, n)
    a = jnp.exp(dt[..., None] * A[None, None]).astype(dtype)
    b = (dt[..., None] * Bm[:, :, None, :]
         * x.astype(jnp.float32)[..., None]).astype(dtype)
    return a, b


def _chunk_scan(a, b, h0):
    """Linear recurrence h_t = a_t h_{t-1} + b_t within one chunk.

    a,b: (B, L, di, n); h0: (B, di, n).  Returns (h_all (B,L,di,n), h_last).
    The associative combine runs in the a/b dtype (bf16 under
    RunConfig.ssm_dtype="bf16"); the carried state stays f32 at chunk
    boundaries, bounding error accumulation to one chunk length.
    """
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, b_c = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_all = (a_c.astype(jnp.float32) * h0[:, None]
             + b_c.astype(jnp.float32))
    return h_all, h_all[:, -1]


def mamba_mix(cfg: ModelConfig, rc: RunConfig, p: dict, x_in: jax.Array,
              h0: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """Selective-scan core. x_in: (B, S, di) pre-conv. Returns (y, h_last)."""
    B, S, di = x_in.shape
    n = cfg.ssm_state
    cw = cfg.conv_width
    # depthwise causal conv
    xp = jnp.pad(x_in, ((0, 0), (cw - 1, 0), (0, 0)))
    x = sum(xp[:, i: i + S] * p["conv_w"][i][None, None] for i in range(cw))
    x = jax.nn.silu(x + p["conv_b"].astype(x.dtype))
    dt, Bm, Cm = _ssm_params(p, x)
    if h0 is None:
        h0 = jnp.zeros((B, di, n), jnp.float32)
    chunk = min(rc.scan_chunk, S)
    nchunks = -(-S // chunk)
    Sp = nchunks * chunk
    if Sp != S:  # pad with a=1, b=0 (identity steps)
        pad = Sp - S
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    ab_dt = jnp.bfloat16 if rc.ssm_dtype == "bf16" else jnp.float32
    a, b = _discretize(p, dt, Bm, x, ab_dt)

    def chunk_step(h, inputs):
        a_c, b_c, C_c, x_c = inputs      # (B, chunk, ...)
        h_all, h_last = _chunk_scan(a_c, b_c, h)
        y = jnp.einsum("blin,bln->bli", h_all, C_c)
        y = y + p["D"][None, None] * x_c.astype(jnp.float32)
        return h_last, y

    def to_chunks(t):
        return t.reshape(B, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    h_last, ys = jax.lax.scan(chunk_step, h0,
                              (to_chunks(a), to_chunks(b), to_chunks(Cm),
                               to_chunks(x)))
    y = ys.swapaxes(0, 1).reshape(B, Sp, di)[:, :S]
    return y.astype(x_in.dtype), h_last


def mamba_forward(cfg: ModelConfig, rc: RunConfig, p: dict, x: jax.Array
                  ) -> jax.Array:
    """Full mamba block. x: (B, S, d_model) -> (B, S, d_model)."""
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    y, _ = mamba_mix(cfg, rc, p, x_in)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_decode_core(cfg: ModelConfig, p: dict, x_in: jax.Array,
                      cache: dict) -> tuple[jax.Array, dict]:
    """Single-token recurrence on the pre-conv branch input.

    x_in: (B, 1, di); cache: conv (B, cw-1, di), ssm (B, di, n).
    Returns (y (B, 1, di), new cache).  O(1) in context length.
    """
    conv_buf = jnp.concatenate([cache["conv"].astype(x_in.dtype), x_in],
                               axis=1)                      # (B, cw, di)
    xc = jnp.einsum("bwi,wi->bi", conv_buf, p["conv_w"])[:, None]
    xc = jax.nn.silu(xc + p["conv_b"].astype(xc.dtype))
    dt, Bm, Cm = _ssm_params(p, xc)              # (B, 1, ...)
    a, b = _discretize(p, dt, Bm, xc)            # (B, 1, di, n)
    h = a[:, 0] * cache["ssm"] + b[:, 0]         # (B, di, n)
    y = jnp.einsum("bin,bn->bi", h, Cm[:, 0])[:, None]
    y = y + p["D"][None, None] * xc.astype(jnp.float32)
    return y.astype(x_in.dtype), {"conv": conv_buf[:, 1:], "ssm": h}


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict
                 ) -> tuple[jax.Array, dict]:
    """Full-block single-token step.  x: (B, 1, d_model)."""
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)          # (B, 1, di)
    y, new_cache = mamba_decode_core(cfg, p, x_in, cache)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, new_cache
