"""Model assembly: segments of homogeneous blocks, scan-over-layers, loss.

A model is a sequence of SEGMENTS from ModelConfig.block_pattern; a segment
with count > 1 is a lax.scan over stacked layer params (compile time is
independent of depth), count == 1 is inlined.  Kinds:

  dense        attn + mlp                      (llama/mistral/qwen family)
  dense_global dense with full attention even when cfg.sliding_window is set
  moe          attn + MoE (+ optional parallel dense residual — arctic)
  mamba        mamba-1 block                    (falcon-mamba)
  hybrid       parallel attn ∥ mamba heads + mlp (hymba); SWA by default
  hybrid_global hybrid with full attention      (hymba's few global layers)
  enc / dec    whisper encoder / decoder (cross-attention) blocks

Forward modes: `loss` (train), `prefill` (returns cache), `decode` (one
token, cache update).  The vocab loss is seq-chunked so the (B,S,V) logits
tensor never materializes (mandatory at 150k vocab).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers, mamba, moe
from repro.models.layers import ParamSpec
from repro.parallel.rules import constrain

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# templates
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig) -> ParamSpec:
    return ParamSpec((cfg.d_model,), (None,), init="ones")


def block_template(cfg: ModelConfig, kind: str) -> dict[str, Any]:
    base = kind.replace("_global", "")
    t: dict[str, Any] = {}
    if base in ("dense", "moe", "hybrid", "enc", "dec"):
        t["norm1"] = _norm(cfg)
        t["attn"] = layers.attn_template(cfg)
    if base in ("dense", "enc", "dec", "hybrid"):
        t["norm2"] = _norm(cfg)
        t["mlp"] = layers.mlp_template(cfg)
    if base == "moe":
        t["norm2"] = _norm(cfg)
        t["moe"] = moe.moe_template(cfg)
    if base == "mamba":
        t["norm1"] = _norm(cfg)
        t["mamba"] = mamba.mamba_template(cfg)
    if base == "hybrid":
        t["norm_m"] = _norm(cfg)
        t["mamba"] = mamba.mamba_template(cfg)
    if base == "dec":
        t["norm_x"] = _norm(cfg)
        t["xattn"] = layers.attn_template(cfg)
    return t


def model_template(cfg: ModelConfig) -> dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    t: dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed")),
        "final_norm": _norm(cfg),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    for si, (kind, count) in enumerate(cfg.block_pattern):
        t[f"seg{si}"] = {"kind": kind, "count": count,
                         "params": block_template(cfg, kind)}
    if cfg.is_encoder_decoder:
        t["enc"] = {"kind": "enc", "count": cfg.num_encoder_layers,
                    "params": block_template(cfg, "enc")}
        t["enc_norm"] = _norm(cfg)
    return t


def _iter_leaves(tree, path=()):
    if isinstance(tree, ParamSpec):
        yield path, tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            if k in ("kind", "count"):
                continue
            yield from _iter_leaves(v, path + (k,))


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=jnp.bfloat16) -> Params:
    """Materialize the template (smoke tests / real training).

    Segment leaves get a stacked leading layer dim when count > 1 (scanned).
    """
    tmpl = model_template(cfg)

    def build(tree, path, stack):
        if isinstance(tree, ParamSpec):
            shape = ((stack, *tree.shape) if stack > 1 else tree.shape)
            k = jax.random.fold_in(key, hash(path) % (2 ** 31))
            if tree.init == "zeros":
                return jnp.zeros(shape, tree.dtype)
            if tree.init == "ones":
                return jnp.ones(shape, tree.dtype)
            fan_in = tree.shape[-2] if len(tree.shape) >= 2 else tree.shape[-1]
            return (jax.random.normal(k, shape, jnp.float32)
                    * (fan_in ** -0.5)).astype(tree.dtype)
        if isinstance(tree, dict):
            if "kind" in tree:
                return {"params": build(tree["params"], path + ("params",),
                                        tree["count"])}
            return {k: build(v, path + (k,), stack) for k, v in tree.items()}
        return tree

    return {k: build(v, (k,), 1) for k, v in tmpl.items()}


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct tree (no allocation) for AOT lowering."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0),
                                              dtype))


def param_specs(cfg: ModelConfig, mesh, seq_parallel: bool = False) -> Params:
    """PartitionSpec tree matching init_params structure."""
    from repro.parallel import rules
    tmpl = model_template(cfg)

    def build(tree, stacked):
        if isinstance(tree, ParamSpec):
            shape = ((1,) + tree.shape) if stacked else tree.shape
            logical = ((None,) + tree.logical) if stacked else tree.logical
            return rules.spec_for(mesh, shape, logical, seq_parallel)
        if isinstance(tree, dict):
            if "kind" in tree:
                return {"params": build(tree["params"], tree["count"] > 1)}
            return {k: build(v, stacked) for k, v in tree.items()}
        return tree

    return {k: build(v, False) for k, v in tmpl.items()}


# ---------------------------------------------------------------------------
# block forward (train/prefill)
# ---------------------------------------------------------------------------

def _window(cfg: ModelConfig, kind: str) -> int | None:
    if kind.endswith("_global"):
        return None
    return cfg.sliding_window


def block_forward(cfg: ModelConfig, rc: RunConfig, kind: str, p: Params,
                  x: jax.Array, positions: jax.Array,
                  enc_out: jax.Array | None = None,
                  collect_cache: bool = False):
    """One block. Returns (x, cache_entry_or_None)."""
    base = kind.replace("_global", "")
    window = _window(cfg, kind)
    cache = {}
    if base in ("dense", "moe", "enc", "dec", "hybrid"):
        h = layers.rmsnorm(x, p["norm1"], cfg.norm_eps)
        causal = base != "enc"
        q, k, v = layers.attn_qkv(cfg, p["attn"], h, positions)
        if collect_cache:
            cache["k"], cache["v"] = k, v
        # context-parallel path when head count doesn't divide the TP axis
        # (GSPMD's fallbacks there are replication or score all-reduces).
        from repro.parallel.rules import _ACTIVE
        mesh = _ACTIVE["mesh"]
        S_here = x.shape[1]
        use_cp = (mesh is not None and "model" in mesh.axis_names
                  and cfg.num_heads % mesh.shape["model"] != 0
                  and S_here % mesh.shape["model"] == 0
                  and S_here == q.shape[1] and S_here > 1)
        if use_cp:
            attn_out = layers.context_parallel_attention(
                mesh, q, k, v, causal=causal, window=window,
                q_block=rc.q_block, kv_block=rc.kv_block,
                softcap=cfg.attn_logit_softcap, compute_dtype=rc.attn_dtype)
        else:
            attn_out = layers.blockwise_attention(
                q, k, v, causal=causal, window=window, q_block=rc.q_block,
                kv_block=rc.kv_block, softcap=cfg.attn_logit_softcap,
                compute_dtype=rc.attn_dtype)
        B, S, _ = x.shape
        attn_out = attn_out.reshape(B, S, -1) @ p["attn"]["wo"]
        if base == "hybrid":
            hm = layers.rmsnorm(x, p["norm_m"], cfg.norm_eps)
            xz = hm @ p["mamba"]["in_proj"]
            x_in, z = jnp.split(xz, 2, axis=-1)
            ym, h_last = mamba.mamba_mix(cfg, rc, p["mamba"], x_in)
            if collect_cache:
                cw = cfg.conv_width
                cache["conv"] = x_in[:, -(cw - 1):]
                cache["ssm"] = h_last
            mamba_out = (ym * jax.nn.silu(z)) @ p["mamba"]["out_proj"]
            x = x + attn_out + mamba_out
        else:
            x = x + attn_out
        x = constrain(x, ("batch", "seq", None))
        if base == "dec":
            hx = layers.rmsnorm(x, p["norm_x"], cfg.norm_eps)
            # cross-attention: kv from encoder output, not cached per step
            B, S, _ = x.shape
            Se = enc_out.shape[1]
            q = (hx @ p["xattn"]["wq"]).reshape(B, S, cfg.num_heads,
                                                cfg.head_dim)
            k = (enc_out @ p["xattn"]["wk"]).reshape(B, Se, cfg.num_kv_heads,
                                                     cfg.head_dim)
            v = (enc_out @ p["xattn"]["wv"]).reshape(B, Se, cfg.num_kv_heads,
                                                     cfg.head_dim)
            xo = layers.blockwise_attention(q, k, v, causal=False,
                                            q_block=rc.q_block,
                                            kv_block=rc.kv_block)
            x = x + xo.reshape(B, S, -1) @ p["xattn"]["wo"]
        h2 = layers.rmsnorm(x, p["norm2"], cfg.norm_eps)
        if base == "moe":
            x = x + moe.moe_forward(cfg, rc, p["moe"], h2)
        else:
            x = x + layers.mlp_forward(cfg, p["mlp"], h2)
    elif base == "mamba":
        h = layers.rmsnorm(x, p["norm1"], cfg.norm_eps)
        xz = h @ p["mamba"]["in_proj"]
        x_in, z = jnp.split(xz, 2, axis=-1)
        ym, h_last = mamba.mamba_mix(cfg, rc, p["mamba"], x_in)
        if collect_cache:
            cw = cfg.conv_width
            cache["conv"] = x_in[:, -(cw - 1):]
            cache["ssm"] = h_last
        x = x + (ym * jax.nn.silu(z)) @ p["mamba"]["out_proj"]
    else:
        raise ValueError(kind)
    x = constrain(x, ("batch", "seq", None))
    return x, (cache if collect_cache else None)


def _segment_forward(cfg, rc, seg_kind, count, seg_params, x, positions,
                     enc_out=None, collect_cache=False):
    """Scan a homogeneous segment (or inline a single block)."""
    fwd = functools.partial(block_forward, cfg, rc, seg_kind,
                            enc_out=enc_out, collect_cache=collect_cache)
    if rc.remat == "block":
        fwd = jax.checkpoint(fwd)
    if count == 1:
        x, cache = fwd(seg_params, x, positions)
        return x, (jax.tree.map(lambda t: t[None], cache)
                   if collect_cache else None)

    def body(carry, layer_params):
        y, c = fwd(layer_params, carry, positions)
        return y, c

    x, caches = jax.lax.scan(body, x, seg_params)
    return x, caches


# ---------------------------------------------------------------------------
# full model forwards
# ---------------------------------------------------------------------------

def embed_input(cfg: ModelConfig, params: Params, batch: dict) -> jax.Array:
    if "embeds" in batch:                 # stubbed modality frontend
        return batch["embeds"].astype(params["embed"].dtype)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    return constrain(x, ("batch", "seq", None))


def backbone(cfg: ModelConfig, rc: RunConfig, params: Params, batch: dict,
             collect_cache: bool = False):
    """Runs embedding + all segments.  Returns (hidden, caches)."""
    x = embed_input(cfg, params, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    enc_out = None
    if cfg.is_encoder_decoder:
        e = batch["enc_embeds"].astype(x.dtype)
        Be, Se = e.shape[:2]
        epos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (Be, Se))
        e, _ = _segment_forward(cfg, rc, "enc", cfg.num_encoder_layers,
                                params["enc"]["params"], e, epos)
        enc_out = layers.rmsnorm(e, params["enc_norm"], cfg.norm_eps)
    caches = {}
    for si, (kind, count) in enumerate(cfg.block_pattern):
        x, cache = _segment_forward(
            cfg, rc, kind, count, params[f"seg{si}"]["params"], x, positions,
            enc_out=enc_out, collect_cache=collect_cache)
        if collect_cache:
            caches[f"seg{si}"] = cache
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, caches


def lm_head(cfg: ModelConfig, params: Params, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w


def chunked_loss(cfg: ModelConfig, rc: RunConfig, params: Params,
                 h: jax.Array, labels: jax.Array) -> jax.Array:
    """Seq-chunked softmax CE: (B,S,V) logits never materialize."""
    B, S, d = h.shape
    chunk = min(rc.loss_chunk, S)
    nch = -(-S // chunk)
    Sp = nch * chunk
    if Sp != S:
        h = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, Sp - S)),
                         constant_values=-1)
    hc = h.reshape(B, nch, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nch, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_ce(hx, lx):
        logits = lm_head(cfg, params, hx).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1)[..., 0]
        valid = (lx >= 0).astype(jnp.float32)
        return ((logz - gold) * valid).sum(), valid.sum()

    def body(carry, xs):
        tot, cnt = carry
        l, c = chunk_ce(*xs)
        return (tot + l, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, rc: RunConfig, params: Params,
            batch: dict) -> jax.Array:
    h, _ = backbone(cfg, rc, params, batch)
    return chunked_loss(cfg, rc, params, h, batch["labels"])


def prefill(cfg: ModelConfig, rc: RunConfig, params: Params, batch: dict,
            cache_len: int, return_hidden: bool = False):
    """Prefill: returns (last-position logits, decode cache).

    ``return_hidden=True`` appends the last-position post-final-norm
    hidden state (B, 1, D) — the input an alternative head (e.g. the
    Lagrange-coded head, core/coded_linear) projects instead of lm_head.
    """
    h, caches = backbone(cfg, rc, params, batch, collect_cache=True)
    S = h.shape[1]
    logits = lm_head(cfg, params, h[:, -1:])
    cache = init_cache(cfg, rc, h.shape[0], cache_len, dtype=h.dtype)
    for si, (kind, count) in enumerate(cfg.block_pattern):
        src = caches[f"seg{si}"]
        dst = cache[f"seg{si}"]
        if "k" in dst:
            size = dst["k"].shape[2]
            if S >= size:
                # ring alignment: token t lives at slot t % size
                last = jax.tree.map(lambda t: t[:, :, -size:], src)
                shift = S % size
                dst["k"] = jnp.roll(last["k"], shift, axis=2)
                dst["v"] = jnp.roll(last["v"], shift, axis=2)
            else:
                dst["k"] = dst["k"].at[:, :, :S].set(src["k"])
                dst["v"] = dst["v"].at[:, :, :S].set(src["v"])
        if "ssm" in dst:
            dst["ssm"] = src["ssm"].astype(jnp.float32)
            dst["conv"] = src["conv"]
    cache["index"] = jnp.int32(S)
    if return_hidden:
        return logits, cache, h[:, -1:]
    return logits, cache


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, rc: RunConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree.  SWA segments get ring buffers of window size;
    global/full segments get max_len; mamba segments get O(1) state."""
    cache: dict[str, Any] = {"index": jnp.int32(0)}
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    for si, (kind, count) in enumerate(cfg.block_pattern):
        base = kind.replace("_global", "")
        seg: dict[str, Any] = {}
        if base in ("dense", "moe", "hybrid", "dec", "enc"):
            window = _window(cfg, kind)
            size = min(max_len, window) if window else max_len
            seg["k"] = jnp.zeros((count, batch, size, kh, hd), dtype)
            seg["v"] = jnp.zeros((count, batch, size, kh, hd), dtype)
        if base in ("mamba", "hybrid"):
            seg["conv"] = jnp.zeros((count, batch, cfg.conv_width - 1,
                                     cfg.d_inner), dtype)
            seg["ssm"] = jnp.zeros((count, batch, cfg.d_inner, cfg.ssm_state),
                                   jnp.float32)
        cache[f"seg{si}"] = seg
    return cache


def _decode_attn(cfg, p, x, seg_cache_layer, index, window, positions):
    """One layer's cached attention at decode time (ring buffer for SWA)."""
    B = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = layers.attn_qkv(cfg, p, x, positions)
    kc, vc = seg_cache_layer["k"], seg_cache_layer["v"]
    size = kc.shape[1]
    slot = index % size if window else index
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, 1)
    filled = jnp.minimum(index + 1, size)
    out = layers.decode_attention(q, kc, vc, filled, window=None)
    return out.reshape(B, 1, -1) @ p["wo"], {"k": kc, "v": vc}


def decode_block(cfg: ModelConfig, rc: RunConfig, kind: str, p: Params,
                 x: jax.Array, cache_layer: dict, index: jax.Array,
                 enc_out: jax.Array | None = None):
    base = kind.replace("_global", "")
    window = _window(cfg, kind)
    B = x.shape[0]
    positions = jnp.broadcast_to(index[None, None], (B, 1)).astype(jnp.int32)
    new_cache = {}
    if base in ("dense", "moe", "dec", "hybrid"):
        hnorm = layers.rmsnorm(x, p["norm1"], cfg.norm_eps)
        attn_out, kv = _decode_attn(cfg, p["attn"], hnorm, cache_layer, index,
                                    window, positions)
        new_cache.update(kv)
        if base == "hybrid":
            hm = layers.rmsnorm(x, p["norm_m"], cfg.norm_eps)
            xz = hm @ p["mamba"]["in_proj"]
            x_in, z = jnp.split(xz, 2, axis=-1)
            ym, mcache = mamba.mamba_decode_core(
                cfg, p["mamba"], x_in,
                {"conv": cache_layer["conv"], "ssm": cache_layer["ssm"]})
            new_cache.update(mcache)
            x = x + attn_out + (ym * jax.nn.silu(z)) @ p["mamba"]["out_proj"]
        else:
            x = x + attn_out
        if base == "dec":
            hx = layers.rmsnorm(x, p["norm_x"], cfg.norm_eps)
            Se = enc_out.shape[1]
            q = (hx @ p["xattn"]["wq"]).reshape(B, 1, cfg.num_heads,
                                                cfg.head_dim)
            k = (enc_out @ p["xattn"]["wk"]).reshape(B, Se, cfg.num_kv_heads,
                                                     cfg.head_dim)
            v = (enc_out @ p["xattn"]["wv"]).reshape(B, Se, cfg.num_kv_heads,
                                                     cfg.head_dim)
            xo = layers.decode_attention(q, k, v, jnp.int32(Se))
            x = x + xo.reshape(B, 1, -1) @ p["xattn"]["wo"]
        h2 = layers.rmsnorm(x, p["norm2"], cfg.norm_eps)
        if base == "moe":
            x = x + moe.moe_forward(cfg, rc, p["moe"], h2)
        else:
            x = x + layers.mlp_forward(cfg, p["mlp"], h2)
    elif base == "mamba":
        hnorm = layers.rmsnorm(x, p["norm1"], cfg.norm_eps)
        xz = hnorm @ p["mamba"]["in_proj"]
        x_in, z = jnp.split(xz, 2, axis=-1)
        ym, mcache = mamba.mamba_decode_core(
            cfg, p["mamba"], x_in,
            {"conv": cache_layer["conv"], "ssm": cache_layer["ssm"]})
        new_cache.update(mcache)
        x = x + (ym * jax.nn.silu(z)) @ p["mamba"]["out_proj"]
    else:
        raise ValueError(kind)
    return x, new_cache


def decode_step(cfg: ModelConfig, rc: RunConfig, params: Params,
                cache: dict, batch: dict, return_hidden: bool = False):
    """One decode step: batch {'tokens': (B,1)} -> (logits (B,1,V), cache).

    ``return_hidden=True`` appends the post-final-norm hidden state
    (B, 1, D), mirroring ``prefill`` — what a coded head consumes.
    """
    x = embed_input(cfg, params, batch)
    index = cache["index"]
    enc_out = batch.get("enc_out")
    new_cache: dict[str, Any] = {"index": index + 1}
    for si, (kind, count) in enumerate(cfg.block_pattern):
        seg_params = params[f"seg{si}"]["params"]
        seg_cache = cache[f"seg{si}"]
        if count == 1:
            layer_p = jax.tree.map(lambda t: t, seg_params)
            layer_c = jax.tree.map(lambda t: t[0], seg_cache)
            x, nc = decode_block(cfg, rc, kind, layer_p, x, layer_c, index,
                                 enc_out)
            new_cache[f"seg{si}"] = jax.tree.map(lambda t: t[None], nc)
        else:
            def body(carry, xs):
                layer_p, layer_c = xs
                y, nc = decode_block(cfg, rc, kind, layer_p, carry, layer_c,
                                     index, enc_out)
                return y, nc

            x, ncs = jax.lax.scan(body, x, (seg_params, seg_cache))
            new_cache[f"seg{si}"] = ncs
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(cfg, params, x)
    if return_hidden:
        return logits, new_cache, x
    return logits, new_cache
