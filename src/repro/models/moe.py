"""Mixture-of-Experts layer: GShard-style top-k dispatch (+ sort-based alt).

Default path is capacity-based einsum dispatch (GSPMD-robust: the dispatch
einsums lower to all-to-alls when experts are sharded over 'model' and tokens
over 'data').  The sort-based path avoids the dispatch-einsum FLOPs bloat and
is the §Perf hillclimb candidate for the MoE cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.layers import ParamSpec


def moe_template(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    t = {
        "router": ParamSpec((d, e), ("embed", None), dtype=jnp.float32),
        "w1": ParamSpec((e, d, ff), ("experts", "embed", None)),
        "w3": ParamSpec((e, d, ff), ("experts", "embed", None)),
        "w2": ParamSpec((e, ff, d), ("experts", None, "embed")),
    }
    if cfg.dense_residual_d_ff:
        dff = cfg.dense_residual_d_ff
        t["res_w1"] = ParamSpec((d, dff), ("embed", "ffn"))
        t["res_w3"] = ParamSpec((d, dff), ("embed", "ffn"))
        t["res_w2"] = ParamSpec((dff, d), ("ffn", "embed"))
    return t


def _top_k_gating(cfg: ModelConfig, router_logits: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """(..., E) logits -> (weights, indices) both (..., k), softmax-normed."""
    k = cfg.experts_per_token
    weights, idx = jax.lax.top_k(router_logits, k)
    weights = jax.nn.softmax(weights.astype(jnp.float32), axis=-1)
    return weights, idx


def moe_forward_einsum(cfg: ModelConfig, rc: RunConfig, p: dict,
                       x: jax.Array) -> jax.Array:
    """GShard dispatch.  x: (B, S, d) -> (B, S, d).

    Tokens are split into groups of rc.moe_group_size (default: one group
    per batch row); capacity per (group, expert) C = ceil(g * k * cf / E).
    Over-capacity tokens are dropped (combine weight zero) — standard
    Switch/GShard semantics.  Smaller groups cut the (tokens, E, C)
    dispatch/combine tensors and their all-to-alls linearly in C (§Perf).
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    g = S if not rc.moe_group_size else min(rc.moe_group_size, B * S)
    if (B * S) % g:
        g = S
    xg = x.reshape(B * S // g, g, d)
    G = xg.shape[0]
    C = max(4, int(-(-g * k * cfg.capacity_factor // E)))
    C = min(C, g)
    cdt = jnp.bfloat16 if rc.moe_combine_dtype == "bf16" else jnp.float32
    logits = xg.astype(jnp.float32) @ p["router"]           # (G, g, E)
    weights, idx = _top_k_gating(cfg, logits)               # (G, g, k)
    # expert-assignment one-hots, then position-in-expert via cumsum
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)      # (G, g, k, E)
    assign = onehot * weights[..., None]
    flat = onehot.reshape(G, g * k, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, k, E)
    keep = pos < C
    assign = (assign * keep).astype(cdt)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                            dtype=cdt)[..., :C]             # (G, g, k, E, C)
    combine = (assign[..., None] * pos_oh).sum(2)           # (G, g, E, C)
    dispatch = (combine > 0).astype(x.dtype)
    xe = jnp.einsum("bsec,bsd->becd", dispatch, xg)         # all-to-all in SPMD
    h = jnp.einsum("becd,edf->becf", xe, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", xe, p["w3"])
    ye = jnp.einsum("becf,efd->becd", h, p["w2"])
    out = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), ye)
    out = out.reshape(B, S, d)
    if cfg.dense_residual_d_ff:
        res = jax.nn.silu(x @ p["res_w1"]) * (x @ p["res_w3"])
        out = out + res @ p["res_w2"]
    return out


def moe_forward_sort(cfg: ModelConfig, rc: RunConfig, p: dict,
                     x: jax.Array) -> jax.Array:
    """Sort-based dispatch: no (E*C)-wide one-hot matmuls.

    Tokens are sorted by assigned expert; each expert processes a contiguous
    padded slab.  FLOPs = gather + expert matmuls only.  (§Perf candidate.)
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    N = B * S
    C = max(4, int(-(-N * k * cfg.capacity_factor // E)))
    xf = x.reshape(N, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    weights, idx = _top_k_gating(cfg, logits)               # (N, k)
    flat_e = idx.reshape(-1)                                 # (N*k,)
    order = jnp.argsort(flat_e)                              # stable
    sorted_e = flat_e[order]
    # position within expert for capacity check
    same = jnp.cumsum(jnp.ones_like(sorted_e), 0) - 1
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))    # (E,)
    pos_in_e = same - seg_start[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)   # drop -> scratch
    token_of = order // k
    # build (E*C+1) slab of token rows
    slab = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xf[token_of])
    xe = slab[: E * C].reshape(E, C, d)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, p["w3"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w2"]).reshape(E * C, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], 0)
    w_flat = weights.reshape(-1)[order]
    contrib = ye[slot] * w_flat[:, None].astype(ye.dtype)
    out = jnp.zeros((N, d), x.dtype).at[token_of].add(contrib)
    if cfg.dense_residual_d_ff:
        res = jax.nn.silu(xf @ p["res_w1"]) * (xf @ p["res_w3"])
        out = out + res @ p["res_w2"]
    return out.reshape(B, S, d)


def moe_forward(cfg: ModelConfig, rc: RunConfig, p: dict, x: jax.Array
                ) -> jax.Array:
    if rc.moe_impl == "sort":
        return moe_forward_sort(cfg, rc, p, x)
    return moe_forward_einsum(cfg, rc, p, x)
