"""Checkpointing: sharded-array save/restore with async writer + elasticity.

Arrays are written as npz groups alongside a manifest.json (step, tree
structure, dtypes, config fingerprint).  Restore is ELASTIC: checkpoints
store logically-shaped (unsharded) arrays, so a run can resume on a
different mesh shape — restore places each leaf with the sharding derived
from the NEW mesh (DESIGN.md §5 fault tolerance).

The async writer moves device->host copies + compression off the training
thread; `wait()` joins before the next save or program exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


_BF16_TAG = "::bf16"   # numpy can't store bfloat16; persist as uint16 views


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        arr = np.asarray(jax.device_get(tree))
        key = prefix.rstrip("/")
        if arr.dtype == jnp.bfloat16:
            out[key + _BF16_TAG] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    tree: dict[str, Any] = {}
    for key, val in flat.items():
        if key.endswith(_BF16_TAG):
            key = key[: -len(_BF16_TAG)]
            val = val.view(jnp.bfloat16)
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------

    def save(self, step: int, state: dict[str, Any],
             extra: dict | None = None) -> None:
        """state: {'params': tree, 'opt_state': tree, ...}."""
        self.wait()
        # snapshot on the caller thread (device_get) so training can mutate
        flat = {name: _flatten(tree, f"{name}/")
                for name, tree in state.items()}

        def write():
            path = os.path.join(self.directory, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            for name, group in flat.items():
                np.savez(os.path.join(tmp, f"{name}.npz"), **group)
            manifest = {"step": step, "time": time.time(),
                        "groups": sorted(flat), **(extra or {})}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=2)
            os.replace(tmp, path)      # atomic publish
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------- restore ----------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None,
                shardings: dict[str, Any] | None = None) -> dict[str, Any]:
        """Returns {'step': int, group_name: tree, ...}.

        `shardings`: optional {group: tree of NamedSharding} — leaves are
        device_put with them (elastic restore onto any mesh); otherwise
        arrays stay on the default device.
        """
        if step is None:
            step = self.latest_step()
        assert step is not None, f"no checkpoints in {self.directory}"
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        out: dict[str, Any] = {"step": manifest["step"]}
        for name in manifest["groups"]:
            with np.load(os.path.join(path, f"{name}.npz")) as z:
                flat = {k: z[k] for k in z.files}
            tree = _unflatten(flat)[name]
            if shardings and name in shardings:
                tree = jax.tree.map(
                    lambda arr, sh: jax.device_put(jnp.asarray(arr), sh),
                    tree, shardings[name])
            out[name] = tree
        return out
