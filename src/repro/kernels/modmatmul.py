"""Pallas TPU kernel: exact matmul over F_p via 8-bit-limb MXU decomposition.

The paper's workers spend their time on finite-field matmuls (Eq. 20).  On
EC2 CPUs that is int64 scalar code; the TPU-native adaptation (DESIGN.md §3):

  * split both operands into nl 8-bit limbs (nl = ceil(bits(p)/8): 3 for the
    paper's 24-bit prime, 4 for our 30-bit extension);
  * limbs are < 256 so they are EXACT in bf16; limb-pair products < 2^16 are
    exact in the MXU's fp32 accumulation tree for up to 2^8 summands
    -> contraction is tiled at bk <= 256;
  * per (i, j, k) grid step the nl^2 limb-pair partial products land in
    2nl-1 int32 VMEM accumulators (indexed by limb weight i+j), reduced
    mod p every step so nothing exceeds int32;
  * on the last k step the accumulators are recombined as
    sum_s acc_s * 2^{8s} mod p with shift-by-doubling (never > 2p).

Grid: (M/bm, N/bn, K/bk), k innermost (sequential accumulation).
VMEM per step: bm*bk + bk*bn int32 inputs + (2nl-1)*bm*bn int32 scratch
= (128*256 + 256*128 + 5*128*128)*4B ~ 0.9 MB with default blocks: well
inside the ~16MB v5e VMEM budget, MXU-aligned (128-multiples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import field

# fp32 accumulation of limb products (< 2^16) is exact for <= 2^8 terms.
MAX_BK = 256


def _combine_limbs(accs, p):
    """sum_s accs[s] * 2^{8s} mod p, values always < 2p (int32-safe)."""
    out = accs[0]
    for s in range(1, len(accs)):
        out = field.addmod(out, field.double_mod(accs[s], field.LIMB_BITS * s, p),
                           p)
    return out


def _modmatmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, p: int, nl: int,
                      k_steps: int):
    """One (i, j, k) grid step.  acc_ref: (2nl-1, bm, bn) int32 scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]  # (bm, bk) int32 field elements
    b = b_ref[...]  # (bk, bn)
    a_l = [((a >> (field.LIMB_BITS * i)) & field.LIMB_MASK).astype(jnp.bfloat16)
           for i in range(nl)]
    b_l = [((b >> (field.LIMB_BITS * j)) & field.LIMB_MASK).astype(jnp.bfloat16)
           for j in range(nl)]
    for i in range(nl):
        for j in range(nl):
            # MXU: bf16 x bf16 -> fp32, exact (limbs < 2^8, bk <= 2^8).
            prod = jax.lax.dot_general(
                a_l[i], b_l[j], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(jnp.int32)
            s = i + j
            acc_ref[s] = field.addmod(acc_ref[s], field.fmod(prod, p), p)

    @pl.when(k == k_steps - 1)
    def _emit():
        accs = [acc_ref[s] for s in range(2 * nl - 1)]
        o_ref[...] = _combine_limbs(accs, p)


def modmatmul(a: jax.Array, b: jax.Array, p: int = field.P,
              bm: int = 128, bn: int = 128, bk: int = MAX_BK,
              interpret: bool | None = None) -> jax.Array:
    """(a @ b) mod p.  a: (M, K) int32 in [0,p), b: (K, N) int32 in [0,p)."""
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    assert bk <= MAX_BK, "bk > 256 breaks fp32 exactness of limb products"
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    # pad to block multiples; zero padding is exact under mod-p matmul.
    Mp, Np, Kp = (-(-M // bm) * bm), (-(-N // bn) * bn), (-(-K // bk) * bk)
    a_p = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    b_p = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    nl = field.n_limbs(p)
    k_steps = Kp // bk
    kernel = functools.partial(_modmatmul_kernel, p=p, nl=nl, k_steps=k_steps)
    out = pl.pallas_call(
        kernel,
        grid=(Mp // bm, Np // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        scratch_shapes=[pltpu.VMEM((2 * nl - 1, bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]
