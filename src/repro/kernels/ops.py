"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute under interpret=True; on TPU the
same BlockSpecs compile to Mosaic.  `use_pallas=False` falls back to the
pure-jnp oracle — handy for dry-run lowering where the interpreter's
per-element python would be pointlessly slow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import field
from repro.kernels import coded_grad as _cg
from repro.kernels import modmatmul as _mm
from repro.kernels import ref as _ref


@functools.partial(jax.jit, static_argnames=("p", "use_pallas"))
def modmatmul(a: jax.Array, b: jax.Array, p: int = field.P,
              use_pallas: bool = True) -> jax.Array:
    if use_pallas:
        return _mm.modmatmul(a, b, p)
    return _ref.modmatmul_ref(a, b, p)


@functools.partial(jax.jit, static_argnames=("p", "use_pallas"))
def coded_grad(x: jax.Array, w: jax.Array, cbar: jax.Array,
               p: int = field.P, use_pallas: bool = True) -> jax.Array:
    if use_pallas:
        return _cg.coded_grad(x, w, cbar, p)
    return _ref.coded_grad_ref(x, w, cbar, p)


@functools.partial(jax.jit, static_argnames=("p", "use_pallas"))
def coded_grad_mc(x: jax.Array, w: jax.Array, cbar: jax.Array,
                  p: int = field.P, use_pallas: bool = True) -> jax.Array:
    """Multi-head worker step: x (mk, d), w (d, c, r) -> (d, c) mod p."""
    if use_pallas:
        return _cg.coded_grad_mc(x, w, cbar, p)
    return _ref.coded_grad_mc_ref(x, w, cbar, p)
