"""Pallas TPU kernel: fused mamba-1 selective scan (§Perf cell A).

The pure-JAX chunked scan materializes h_all = (B, S, d_inner, n) in HBM —
549 TB/layer for falcon-mamba prefill_32k, 34x the useful I/O, making the
cell the worst roofline fraction of the 40-cell table.  The CUDA original
keeps h in SRAM; this is the TPU-native equivalent: h lives in a VMEM
scratch tile, the sequence is streamed through VMEM in blk_s tiles, and HBM
traffic collapses to the kernel's operands + outputs:

    inputs : x, dt (B,S,di), Bm, Cm (B,S,n), A (di,n), D (di)
    outputs: y (B,S,di), h_last (B,di,n)

Grid (B, di/blk_di, S/blk_s); the S axis is innermost/sequential, carrying
h (blk_di, n) in scratch across S-tiles (same revisiting pattern as the
modmatmul accumulator).  Within a tile a fori_loop steps time — sequential,
but each step is a (blk_di x n) VPU op with zero HBM traffic.

Validated against the pure-jnp oracle (ref_selective_scan / models.mamba) in
interpret mode: tests/test_kernels_mamba.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, bm_ref, cm_ref, a_log_ref, d_ref, h0_ref,
                 y_ref, hlast_ref, h_scratch, *, blk_s: int, s_steps: int):
    """One (b, di-block, s-block) step."""
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scratch[...] = h0_ref[0]          # (blk_di, n)

    A = -jnp.exp(a_log_ref[...])            # (blk_di, n)
    Dv = d_ref[...]                         # (blk_di,)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)        # (blk_di,)
        dt_t = dt_ref[0, t].astype(jnp.float32)      # (blk_di,)
        b_t = bm_ref[0, t].astype(jnp.float32)       # (n,)
        c_t = cm_ref[0, t].astype(jnp.float32)       # (n,)
        a_t = jnp.exp(dt_t[:, None] * A)             # (blk_di, n)
        h = a_t * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = (h * c_t[None, :]).sum(-1) + Dv * x_t  # (blk_di,)
        y_ref[0, t] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, blk_s, step, h_scratch[...])
    h_scratch[...] = h

    @pl.when(si == s_steps - 1)
    def _emit():
        hlast_ref[0] = h


def selective_scan(x: jax.Array, dt: jax.Array, bm: jax.Array, cm: jax.Array,
                   a_log: jax.Array, d: jax.Array, h0: jax.Array,
                   blk_di: int = 512, blk_s: int = 256,
                   interpret: bool | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Fused scan.  x/dt: (B,S,di); bm/cm: (B,S,n); a_log: (di,n); d: (di,);
    h0: (B,di,n).  Returns (y (B,S,di) f32, h_last (B,di,n) f32)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, S, di = x.shape
    n = bm.shape[-1]
    blk_di = min(blk_di, di)
    blk_s = min(blk_s, S)
    assert di % blk_di == 0, (di, blk_di)
    Sp = -(-S // blk_s) * blk_s
    if Sp != S:  # pad time with dt=0 -> a=1, b=0 (identity steps)
        pad = ((0, 0), (0, Sp - S), (0, 0))
        x, dt = jnp.pad(x, pad), jnp.pad(dt, pad)
        bm, cm = jnp.pad(bm, pad), jnp.pad(cm, pad)
    s_steps = Sp // blk_s
    kernel = functools.partial(_scan_kernel, blk_s=blk_s, s_steps=s_steps)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, di // blk_di, s_steps),
        in_specs=[
            pl.BlockSpec((1, blk_s, blk_di), lambda b, i, s: (b, s, i)),
            pl.BlockSpec((1, blk_s, blk_di), lambda b, i, s: (b, s, i)),
            pl.BlockSpec((1, blk_s, n), lambda b, i, s: (b, s, 0)),
            pl.BlockSpec((1, blk_s, n), lambda b, i, s: (b, s, 0)),
            pl.BlockSpec((blk_di, n), lambda b, i, s: (i, 0)),
            pl.BlockSpec((blk_di,), lambda b, i, s: (i,)),
            pl.BlockSpec((1, blk_di, n), lambda b, i, s: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_s, blk_di), lambda b, i, s: (b, s, i)),
            pl.BlockSpec((1, blk_di, n), lambda b, i, s: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((blk_di, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, bm, cm, a_log, d, h0)
    return y[:, :S], h_last


def ref_selective_scan(x, dt, bm, cm, a_log, d, h0):
    """Pure-jnp oracle: naive sequential recurrence (f32)."""
    B, S, di = x.shape
    A = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, inputs):
        x_t, dt_t, b_t, c_t = inputs
        x_t, dt_t = x_t.astype(jnp.float32), dt_t.astype(jnp.float32)
        a_t = jnp.exp(dt_t[:, :, None] * A[None])
        h = a_t * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :].astype(jnp.float32)
        y_t = (h * c_t[:, None, :].astype(jnp.float32)).sum(-1) + d * x_t
        return h, y_t

    h_last, ys = jax.lax.scan(
        step, h0, (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                   bm.swapaxes(0, 1), cm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h_last


def io_bytes(B: int, S: int, di: int, n: int, in_bytes: int = 2,
             out_bytes: int = 4) -> int:
    """HBM traffic of the fused kernel: operands + outputs only.

    Used by EXPERIMENTS.md §Perf to compute the kernel-adjusted memory term
    for the mamba cells (the kernel cannot be Mosaic-compiled on this CPU
    container; correctness is validated in interpret mode)."""
    inputs = (2 * B * S * di + 2 * B * S * n) * in_bytes \
        + (di * n + di) * 4 + B * di * n * 4
    outputs = B * S * di * out_bytes + B * di * n * 4
    return inputs + outputs
