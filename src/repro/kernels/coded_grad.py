"""Pallas TPU kernel: fused CodedPrivateML worker step (paper Eq. 20).

f(X̃, W̃) = X̃ᵀ ḡ(X̃, W̃) with ḡ = sum_i c̄_i prod_{j<=i}(X̃ w̃ʲ) mod p.

Unfused, the worker reads X̃ twice from HBM (once for Z = X̃W̃, once for the
X̃ᵀ· reduction).  Since X̃ is by far the largest operand (m/K x d vs d x r),
this kernel streams each X̃ row-block through VMEM exactly once:

    per row-block b:  Z_b = X̃_b @ W̃        (d-chunked, limb-exact MXU)
                      s_b = poly(Z_b, c̄)    (VPU mod arithmetic)
                      out += X̃_bᵀ @ s_b     (reuses the X̃_b block in VMEM)

=> HBM traffic ~ halves; arithmetic intensity of the worker step ~ doubles.
This is the paper's compute hot spot, so it is the kernel we optimize.

Multi-class (one-vs-all, DESIGN.md §4): the kernel is c-head generic.  W̃ is
laid out (d, c*r) so the SAME streamed X̃ pass feeds all c polynomial heads
(amortizing the dominant HBM read across classes); output block is (c, d).

Constraints: full W̃ (d x c*r) and the (c, d) accumulator live in VMEM —
fine for the paper's scales (d ~ 1.5k-8k: d*c*r*4B < 2MB at c=10,r=2).  The
general tiled path is kernels/modmatmul.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import field

MAX_CHUNK = 256  # fp32-exact contraction depth for 8-bit limb products


def _limbs_bf16(x, nl):
    return [((x >> (field.LIMB_BITS * i)) & field.LIMB_MASK).astype(jnp.bfloat16)
            for i in range(nl)]


def _exact_modmatmul_block(a, b, p, nl):
    """(a @ b) mod p for in-VMEM blocks, chunked at MAX_CHUNK contraction."""
    K = a.shape[-1]
    accs = [jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
            for _ in range(2 * nl - 1)]
    for start in range(0, K, MAX_CHUNK):
        a_c = a[:, start: start + MAX_CHUNK]
        b_c = b[start: start + MAX_CHUNK, :]
        a_l = _limbs_bf16(a_c, nl)
        b_l = _limbs_bf16(b_c, nl)
        for i in range(nl):
            for j in range(nl):
                prod = jax.lax.dot_general(
                    a_l[i], b_l[j], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32).astype(jnp.int32)
                accs[i + j] = field.addmod(accs[i + j], field.fmod(prod, p), p)
    out = accs[0]
    for s in range(1, 2 * nl - 1):
        out = field.addmod(out, field.double_mod(accs[s], field.LIMB_BITS * s, p),
                           p)
    return out


def _coded_grad_kernel(x_ref, w_ref, c_ref, o_ref, *, p: int, nl: int,
                       r: int, c: int):
    """Grid step over one X̃ row-block; accumulates into the (c, d) output.

    W̃ arrives as (d, c*r): column block cls*r..cls*r+r holds the r
    realizations of head cls, so ONE limb-matmul feeds all c polynomial
    heads — the X̃ block is read from VMEM once regardless of c (this is
    the amortization that makes multi-class one-vs-all nearly free).
    """
    b = pl.program_id(0)
    x = x_ref[...]                     # (bm, d) int32
    w = w_ref[...]                     # (d, c*r) int32
    # Z = X̃ @ W̃ mod p  (bm, c*r)
    z = _exact_modmatmul_block(x, w, p, nl)
    # s[:, cls] = ḡ(Z_cls) = c̄_0 + sum_i c̄_i * prod_{j<=i} z_{cls,j}
    cols = []
    for cls in range(c):
        s = jnp.full((z.shape[0],), c_ref[0], jnp.int32)
        prod = None
        for i in range(1, r + 1):
            zi = z[:, cls * r + i - 1]
            prod = zi if prod is None else field.mulmod(prod, zi, p)
            s = field.addmod(s, field.mulmod(
                jnp.broadcast_to(c_ref[i], prod.shape), prod, p), p)
        cols.append(s)
    S = jnp.stack(cols, axis=0)        # (c, bm)
    # out += Sᵀᵀ @ X̃ -> (c, d); contraction depth bm <= 256 keeps exactness.
    contrib = _exact_modmatmul_block(S, x, p, nl)

    @pl.when(b == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] = field.addmod(o_ref[...], contrib, p)


def _coded_grad_impl(x: jax.Array, w3: jax.Array, cbar: jax.Array,
                     p: int, bm: int, interpret: bool | None) -> jax.Array:
    """x (mk, d), w3 (d, c, r), cbar (r+1,) -> (d, c) mod p."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    mk, d = x.shape
    _, c, r = w3.shape
    w2 = w3.reshape(d, c * r)
    bm = min(bm, MAX_CHUNK, mk)  # row-block is also the 2nd contraction depth
    mp = -(-mk // bm) * bm
    x_p = jnp.pad(x, ((0, mp - mk), (0, 0)))  # zero rows: ḡ(0)=c0 but s*0ᵀ...
    # NOTE: padded rows produce s=c̄_0 != 0, but contribute s * x_row = 0
    # because the padded x rows are zero — the X̃ᵀ reduction kills them.
    nl = field.n_limbs(p)
    kernel = functools.partial(_coded_grad_kernel, p=p, nl=nl, r=r, c=c)
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda b: (b, 0)),
            pl.BlockSpec((d, c * r), lambda b: (0, 0)),
            pl.BlockSpec((r + 1,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((c, d), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, d), jnp.int32),
        interpret=interpret,
    )(x_p, w2, cbar.astype(jnp.int32))
    return out.T


def coded_grad(x: jax.Array, w: jax.Array, cbar: jax.Array,
               p: int = field.P, bm: int = MAX_CHUNK,
               interpret: bool | None = None) -> jax.Array:
    """Fused worker step: x (mk, d), w (d, r), cbar (r+1,) -> (d,) mod p."""
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0]
    return _coded_grad_impl(x, w[:, None, :], cbar, p, bm, interpret)[:, 0]


def coded_grad_mc(x: jax.Array, w: jax.Array, cbar: jax.Array,
                  p: int = field.P, bm: int = MAX_CHUNK,
                  interpret: bool | None = None) -> jax.Array:
    """Multi-head fused worker step (one-vs-all logistic regression).

    x (mk, d), w (d, c, r), cbar (r+1,) -> (d, c) mod p.  The c heads share
    the single streamed pass over X̃ (see _coded_grad_kernel).
    """
    assert x.ndim == 2 and w.ndim == 3 and x.shape[1] == w.shape[0]
    return _coded_grad_impl(x, w, cbar, p, bm, interpret)
