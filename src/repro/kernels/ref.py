"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These are deliberately written via the canonical repro.core.field spec (which
tests separately against numpy int64), NOT by sharing code with the kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import field, sigmoid_poly


def modmatmul_ref(a: jax.Array, b: jax.Array, p: int = field.P) -> jax.Array:
    """(a @ b) mod p — chunked int32 limb matmul (field.matmul spec)."""
    return field.matmul(a, b, p)


def coded_grad_ref(x: jax.Array, w: jax.Array, cbar: jax.Array,
                   p: int = field.P) -> jax.Array:
    """X̃ᵀ ḡ(X̃, W̃) mod p via the unfused field ops (paper Eq. 20)."""
    xw = field.matmul(x, w, p)                       # (mk, r)
    s = sigmoid_poly.gbar_field(xw, cbar.astype(jnp.int32), p)  # (mk,)
    return field.matmul(x.T, s[:, None], p)[:, 0]    # (d,)


def coded_grad_mc_ref(x: jax.Array, w: jax.Array, cbar: jax.Array,
                      p: int = field.P) -> jax.Array:
    """Multi-head Eq. 20: x (mk, d), w (d, c, r) -> (d, c) mod p.

    Reshaping W̃ to (d, c*r) before the matmul is exact: Lagrange encoding is
    elementwise-linear across parts, so column cls*r+j of X̃ @ W̃ is precisely
    the head-cls degree-j product the polynomial needs.
    """
    d, c, r = w.shape
    xw = field.matmul(x, w.reshape(d, c * r), p)     # (mk, c*r)
    xw = xw.reshape(x.shape[0], c, r)
    s = sigmoid_poly.gbar_field(xw, cbar.astype(jnp.int32), p)  # (mk, c)
    return field.matmul(x.T, s, p)                   # (d, c)
