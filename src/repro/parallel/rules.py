"""Logical-axis -> PartitionSpec rules (divisible-or-replicate policy).

Every parameter template leaf carries logical axis names (ParamSpec.logical);
activations are constrained via `act_spec`.  The policy:

  * `embed`   -> 'data'   (FSDP: weights sharded over the DP axis, all-
                           gathered per layer by GSPMD — ZeRO-3 style)
  * `heads` / `kv_heads` / `ffn` / `vocab` / `inner` / `experts` -> 'model'
  * `batch`   -> ('pod','data') on the multi-pod mesh, 'data' otherwise
  * `seq`     -> 'model' when RunConfig.seq_parallel (activations only)
  * a dim is sharded ONLY if its size divides the mesh-axes product —
    otherwise it silently replicates (awkward head counts: 25, 56, 6).

This single policy covers all 10 assigned architectures (DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamSpec


def logical_rules(mesh: Mesh, seq_parallel: bool = False
                  ) -> dict[str, tuple[str, ...]]:
    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    model_axes = ("model",) if "model" in axes else ()
    return {
        "batch": batch_axes,
        "embed": tuple(a for a in ("data",) if a in axes),
        "heads": model_axes,
        "kv_heads": model_axes,
        "ffn": model_axes,
        "vocab": model_axes,
        "experts": model_axes,
        "inner": model_axes,
        "seq": model_axes if seq_parallel else (),
        "kv_seq": model_axes,   # long-context decode: shard the cache on seq
    }


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


def spec_for(mesh: Mesh, shape: tuple[int, ...],
             logical: tuple[str | None, ...],
             seq_parallel: bool = False) -> P:
    """PartitionSpec for one array, applying divisible-or-replicate.

    Count-qualified names `heads[n]` shard only when BOTH the dim size and
    the head count n divide the axis (see layers.attn_template)."""
    rules = logical_rules(mesh, seq_parallel)
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, logical):
        count = None
        if name and name.endswith("]") and "[" in name:
            base, cnt = name[:-1].split("[")
            name, count = base, int(cnt)
        axes = rules.get(name, ()) if name else ()
        axes = tuple(a for a in axes if a not in used)
        size = _axes_size(mesh, axes)
        ok = bool(axes) and dim % size == 0 and (
            count is None or count % size == 0)
        if ok:
            parts.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(mesh: Mesh, spec_leaf: ParamSpec, stacked: bool = False,
                 seq_parallel: bool = False) -> NamedSharding:
    shape = ((1,) + spec_leaf.shape) if stacked else spec_leaf.shape
    logical = ((None,) + spec_leaf.logical) if stacked else spec_leaf.logical
    return NamedSharding(mesh, spec_for(mesh, shape, logical, seq_parallel))


def act_spec(mesh: Mesh, x_shape: tuple[int, ...],
             logical: tuple[str | None, ...],
             seq_parallel: bool = False) -> P:
    return spec_for(mesh, x_shape, logical, seq_parallel)


_ACTIVE: dict[str, Any] = {"mesh": None, "seq_parallel": False}


class use_rules_mesh:
    """Context manager: activates activation-sharding constraints.

    The launcher wraps lowering/execution in this; without it `constrain`
    is a no-op so models run unannotated on a single device (smoke tests).
    """

    def __init__(self, mesh: Mesh, seq_parallel: bool = False):
        self.state = (mesh, seq_parallel)

    def __enter__(self):
        self.prev = (_ACTIVE["mesh"], _ACTIVE["seq_parallel"])
        _ACTIVE["mesh"], _ACTIVE["seq_parallel"] = self.state
        return self

    def __exit__(self, *exc):
        _ACTIVE["mesh"], _ACTIVE["seq_parallel"] = self.prev
        return False


def constrain(x: jax.Array, logical: tuple[str | None, ...]) -> jax.Array:
    """with_sharding_constraint under use_rules_mesh, else no-op."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    spec = spec_for(mesh, x.shape, logical, _ACTIVE["seq_parallel"])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
