"""jax version compatibility for SPMD primitives.

The repo targets the modern top-level APIs (jax.shard_map, jax.set_mesh,
mesh axis_types); this shim keeps every call site working on jax 0.4.x
(the pinned container toolchain), where those live under jax.experimental
or do not exist.  Only jax is imported here — any layer may depend on it.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """jax.shard_map on new jax; jax.experimental.shard_map on 0.4.x.

    ``check=False`` disables the replication/varying-manual-axes check
    (check_vma on new jax) — needed when an all_gather makes the output
    replicated in a way the static check cannot infer.  The 0.4.x
    ``check_rep`` checker mis-types scan carries (its own error message
    recommends disabling it), so the fallback always passes check_rep=False.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def ambient_mesh():
    """The active mesh: jax.set_mesh (new) or ``with mesh:`` (0.4.x)."""
    get_abs = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abs is not None:
        mesh = get_abs()
        if mesh is not None and not mesh.empty:
            return mesh
    from jax._src import mesh as mesh_lib
    mesh = mesh_lib.thread_resources.env.physical_mesh
    assert not mesh.empty, (
        "no active mesh: wrap the call in `with mesh:` "
        "(or jax.set_mesh on newer jax)")
    return mesh
