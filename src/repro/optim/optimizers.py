"""Optimizers from scratch (no optax): AdamW + SGD-momentum, schedules,
global-norm clipping, and the paper-derived quantized gradient compressor.

State layout mirrors the param tree (scan-stacked leaves keep their leading
layer dim), so the same sharding specs apply to optimizer state — the FSDP
memory math in DESIGN.md §5 depends on this.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | sgd
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    grad_clip: float = 1.0
    compress: str = "none"           # none | stochastic_quant (optim/compress)
    compress_bits: int = 8


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * cos


def init_state(cfg: OptimizerConfig, params: Any) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {"step": jnp.zeros((), jnp.int32)}
    if cfg.name == "adamw":
        state["mu"] = jax.tree.map(zeros32, params)
        state["nu"] = jax.tree.map(zeros32, params)
    else:
        state["mom"] = jax.tree.map(zeros32, params)
    return state


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def apply_updates(cfg: OptimizerConfig, params: Any, grads: Any,
                  state: dict) -> tuple[Any, dict, dict]:
    """One optimizer step.  Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    if cfg.name == "adamw":
        mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                          state["nu"], grads)
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        new_state = {"step": step, "mu": mu, "nu": nu}
    else:
        mom = jax.tree.map(lambda m, g: cfg.momentum * m + g,
                           state["mom"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mom)
        new_state = {"step": step, "mom": mom}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
