"""Beyond-paper: gradient compression from the paper's own quantizer.

The paper's stochastic quantizer (Eq. 8) is unbiased — exactly the property a
compressed data-parallel all-reduce needs.  We quantize per-leaf gradients to
`bits`-bit integers with a per-leaf scale before the (simulated) cross-pod
reduction, cutting DCN bytes by 32/bits at zero bias (variance shows up as
the sigma^2 term of Theorem 1's rate, same trade as the paper's lw knob).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_grad(key: jax.Array, g: jax.Array, bits: int = 8
                  ) -> tuple[jax.Array, jax.Array]:
    """Unbiased stochastic fixed-point quantization. Returns (q int, scale)."""
    g = g.astype(jnp.float32)
    maxval = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    levels = (1 << (bits - 1)) - 1
    scaled = g / maxval * levels
    floor = jnp.floor(scaled)
    frac = scaled - floor
    u = jax.random.uniform(key, g.shape)
    q = floor + (u < frac)
    return q.astype(jnp.int32), maxval / levels


def dequantize_grad(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(key: jax.Array, grads, bits: int = 8):
    """Quantize every leaf (fresh key per leaf); returns (q_tree, scales)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    qs, scales = [], []
    for k, g in zip(keys, leaves):
        q, s = quantize_grad(k, g, bits)
        qs.append(q)
        scales.append(s)
    return jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, scales)


def decompress_tree(q_tree, scales):
    return jax.tree.map(dequantize_grad, q_tree, scales)
