"""Fault-tolerance runtime: heartbeats, straggler/failure injection, restart.

Three layers of defense for 1000+-node runs (DESIGN.md §5):

1. CODED tolerance (zero-cost recovery): the paper's own mechanism.  Layers
   built on Lagrange codes (core/protocol, core/coded_linear) decode from any
   `threshold` of N shards — the HeartbeatMonitor simply feeds the survivor
   set into the decode-matrix selection.  No recomputation, no restart.

2. CHECKPOINT/RESTART: `ResilientLoop` wraps the train step; any step failure
   restores the latest checkpoint and replays.  Checkpoints are elastic
   (restorable onto a different mesh), giving scale-down-and-continue.

3. STRAGGLER MITIGATION: monitor marks slow workers (simulated via injected
   latency here; wall-clock thresholds on real clusters); coded layers drop
   them from the survivor set, uncoded paths trigger an elastic re-shard.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    latency_ewma: float = 0.0
    alive: bool = True


class HeartbeatMonitor:
    """Tracks N workers; exposes survivor sets for coded-decode selection.

    Clock-agnostic: pass ``now`` everywhere to run on a simulated clock
    (cluster/scheduler.py drives one from simulated epoch 0); omit it for
    wall-clock operation on a real deployment.
    """

    def __init__(self, n_workers: int, timeout_s: float = 10.0,
                 straggler_factor: float = 3.0, now: float | None = None):
        now = time.time() if now is None else now
        self.workers = {i: WorkerState(now) for i in range(n_workers)}
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor

    def add_worker(self, worker: int, now: float | None = None):
        """Elastic JOIN (cluster/membership.py): start tracking a fresh
        worker slot on a clean heartbeat/latency slate.  Idempotent — an
        existing slot is re-initialized, which is exactly revive()."""
        self.workers[worker] = WorkerState(time.time() if now is None else now)

    def remove_worker(self, worker: int):
        """Elastic LEAVE: stop tracking a permanently retired slot (the
        membership layer never dispatches it again, so keeping its state
        would only skew the straggler median)."""
        self.workers.pop(worker, None)

    def heartbeat(self, worker: int, latency_s: float | None = None,
                  now: float | None = None):
        """latency_s=None is a liveness-only ack (leaves the EWMA alone);
        pass a measured latency to update the straggler statistic.  A
        heartbeat from an unknown slot (a joiner's first ack racing its
        admission, or a retired slot's last in-flight reply) is liveness
        evidence for nobody and is dropped."""
        if worker not in self.workers:
            return
        w = self.workers[worker]
        w.last_heartbeat = time.time() if now is None else now
        if latency_s is not None:
            w.latency_ewma = 0.8 * w.latency_ewma + 0.2 * latency_s
        w.alive = True

    def mark_failed(self, worker: int):
        if worker in self.workers:       # a retired slot is already gone
            self.workers[worker].alive = False

    def is_dead(self, worker: int, now: float | None = None) -> bool:
        """The ONE liveness predicate: explicitly failed, or heartbeat-
        silent beyond the (finite) timeout.  Shared by survivors() and the
        scheduler's collect-all dead-exit (cluster/scheduler.py), so the
        failure detector can never drift between call sites."""
        now = time.time() if now is None else now
        w = self.workers[worker]
        return not w.alive or (now - w.last_heartbeat) > self.timeout_s

    def revive(self, worker: int, now: float | None = None):
        """Node replacement: fresh worker on a clean latency slate."""
        self.workers[worker] = WorkerState(time.time() if now is None else now)

    def credit_stall(self, stall_s: float, now: float | None = None):
        """Master-side blocking work (a joiner's provisioning barrier, a
        checkpoint-restore respawn) stops round dispatch — and with it the
        per-round acks that are this detector's heartbeat source.  Without
        credit, a barrier longer than ``timeout_s`` makes the whole healthy
        fleet look silent-dead.  Shift every worker that was live BEFORE
        the stall forward by its duration; a worker already past the
        timeout when the stall began stays dead."""
        now = time.time() if now is None else now
        before = now - stall_s
        for w in self.workers.values():
            if w.alive and (before - w.last_heartbeat) <= self.timeout_s:
                w.last_heartbeat = min(now, w.last_heartbeat + stall_s)

    def survivors(self, now: float | None = None) -> np.ndarray:
        """Alive + non-straggling workers, fastest first."""
        # compare against None: simulated-clock callers legitimately pass 0.0
        now = time.time() if now is None else now
        lat = [w.latency_ewma for w in self.workers.values() if w.alive]
        median = float(np.median(lat)) if lat else 0.0
        good = []
        for i, w in self.workers.items():
            if self.is_dead(i, now=now):
                continue
            if median > 0 and w.latency_ewma > self.straggler_factor * median:
                continue           # straggler: exclude from the fast set
            good.append((w.latency_ewma, i))
        return np.array([i for _, i in sorted(good)], dtype=np.int64)


class FailureInjector:
    """Deterministic chaos for tests: kill/slow workers on a schedule."""

    def __init__(self, seed: int = 0, fail_prob: float = 0.0,
                 straggle_prob: float = 0.0):
        self.rng = random.Random(seed)
        self.fail_prob = fail_prob
        self.straggle_prob = straggle_prob

    def step(self, monitor: HeartbeatMonitor):
        for i, w in monitor.workers.items():
            if not w.alive:
                continue
            if self.rng.random() < self.fail_prob:
                monitor.mark_failed(i)
            elif self.rng.random() < self.straggle_prob:
                monitor.heartbeat(i, latency_s=10.0)
            else:
                monitor.heartbeat(i, latency_s=1.0 + 0.1 * self.rng.random())


class ResilientLoop:
    """Checkpoint-every-k + restore-and-replay on step failure.

    ``max_retries`` bounds failures PER STEP, not over the whole run: a
    long healthy run must not accumulate isolated transient failures until
    restart 4 kills it, while a deterministic failure at one step (which a
    run-wide-but-resetting budget would replay forever whenever a
    checkpointed step succeeds in between) still trips after max_retries.
    ``restarts`` counts every restart over the loop's lifetime for
    observability.  ``on_restore(step)`` (optional) runs after each
    checkpoint restore, before replay — the hook where a cluster driver
    reprovisions dead workers (cluster/runner.py).
    """

    def __init__(self, ckpt_manager, checkpoint_every: int = 100,
                 max_retries: int = 3,
                 on_restore: Callable[[int], None] | None = None):
        self.ckpt = ckpt_manager
        self.every = checkpoint_every
        self.max_retries = max_retries
        self.restarts = 0
        self.on_restore = on_restore

    def run(self, state: dict[str, Any], step_fn: Callable[[dict, int], dict],
            start_step: int, num_steps: int,
            shardings: dict | None = None) -> dict[str, Any]:
        """step_fn(state, step) -> state; must raise on failure."""
        step = start_step
        failures: dict[int, int] = {}
        while step < start_step + num_steps:
            try:
                state = step_fn(state, step)
                step += 1
                if self.every and step % self.every == 0:
                    self.ckpt.save(step, state)
            except Exception:
                self.restarts += 1
                failures[step] = failures.get(step, 0) + 1
                if failures[step] > self.max_retries:
                    raise
                restored = self.ckpt.restore(shardings=shardings)
                step = restored.pop("step")
                state = restored
                if self.on_restore is not None:
                    self.on_restore(step)
        self.ckpt.wait()
        return state
