"""Fault-tolerance runtime: heartbeats, straggler/failure injection, restart.

Three layers of defense for 1000+-node runs (DESIGN.md §5):

1. CODED tolerance (zero-cost recovery): the paper's own mechanism.  Layers
   built on Lagrange codes (core/protocol, core/coded_linear) decode from any
   `threshold` of N shards — the HeartbeatMonitor simply feeds the survivor
   set into the decode-matrix selection.  No recomputation, no restart.

2. CHECKPOINT/RESTART: `ResilientLoop` wraps the train step; any step failure
   restores the latest checkpoint and replays.  Checkpoints are elastic
   (restorable onto a different mesh), giving scale-down-and-continue.

3. STRAGGLER MITIGATION: monitor marks slow workers (simulated via injected
   latency here; wall-clock thresholds on real clusters); coded layers drop
   them from the survivor set, uncoded paths trigger an elastic re-shard.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    latency_ewma: float = 0.0
    alive: bool = True


class HeartbeatMonitor:
    """Tracks N workers; exposes survivor sets for coded-decode selection."""

    def __init__(self, n_workers: int, timeout_s: float = 10.0,
                 straggler_factor: float = 3.0):
        now = time.time()
        self.workers = {i: WorkerState(now) for i in range(n_workers)}
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor

    def heartbeat(self, worker: int, latency_s: float = 0.0):
        w = self.workers[worker]
        w.last_heartbeat = time.time()
        w.latency_ewma = 0.8 * w.latency_ewma + 0.2 * latency_s
        w.alive = True

    def mark_failed(self, worker: int):
        self.workers[worker].alive = False

    def survivors(self, now: float | None = None) -> np.ndarray:
        """Alive + non-straggling workers, fastest first."""
        now = now or time.time()
        lat = [w.latency_ewma for w in self.workers.values() if w.alive]
        median = float(np.median(lat)) if lat else 0.0
        good = []
        for i, w in self.workers.items():
            if not w.alive or (now - w.last_heartbeat) > self.timeout_s:
                continue
            if median > 0 and w.latency_ewma > self.straggler_factor * median:
                continue           # straggler: exclude from the fast set
            good.append((w.latency_ewma, i))
        return np.array([i for _, i in sorted(good)], dtype=np.int64)


class FailureInjector:
    """Deterministic chaos for tests: kill/slow workers on a schedule."""

    def __init__(self, seed: int = 0, fail_prob: float = 0.0,
                 straggle_prob: float = 0.0):
        self.rng = random.Random(seed)
        self.fail_prob = fail_prob
        self.straggle_prob = straggle_prob

    def step(self, monitor: HeartbeatMonitor):
        for i, w in monitor.workers.items():
            if not w.alive:
                continue
            if self.rng.random() < self.fail_prob:
                monitor.mark_failed(i)
            elif self.rng.random() < self.straggle_prob:
                monitor.heartbeat(i, latency_s=10.0)
            else:
                monitor.heartbeat(i, latency_s=1.0 + 0.1 * self.rng.random())


class ResilientLoop:
    """Checkpoint-every-k + restore-and-replay on step failure."""

    def __init__(self, ckpt_manager, checkpoint_every: int = 100,
                 max_retries: int = 3):
        self.ckpt = ckpt_manager
        self.every = checkpoint_every
        self.max_retries = max_retries
        self.restarts = 0

    def run(self, state: dict[str, Any], step_fn: Callable[[dict, int], dict],
            start_step: int, num_steps: int,
            shardings: dict | None = None) -> dict[str, Any]:
        """step_fn(state, step) -> state; must raise on failure."""
        step = start_step
        while step < start_step + num_steps:
            try:
                state = step_fn(state, step)
                step += 1
                if self.every and step % self.every == 0:
                    self.ckpt.save(step, state)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_retries:
                    raise
                restored = self.ckpt.restore(shardings=shardings)
                step = restored.pop("step")
                state = restored
        self.ckpt.wait()
        return state
