"""Analog Lagrange coded computing over the reals (DESIGN.md §14).

The exact engine (core/lagrange.py) runs Lagrange coding over F_p: data is
quantized, masks are uniform field elements, and any `threshold` worker
evaluations decode the polynomial EXACTLY.  This module is the same code
over ordinary float arithmetic — "Approximated Coded Computing" (arXiv
2406.04747), with the analog-noise privacy framing of arXiv 2005.09532:

  * the K + T interpolation points (betas) and N evaluation points (alphas)
    are real numbers, chosen as CHEBYSHEV nodes so the Lagrange/Vandermonde
    systems stay well-conditioned instead of blowing up like equispaced
    points do;
  * the T privacy masks are i.i.d. Gaussian (sigma) instead of uniform
    field elements — any T shares look like the data convolved with
    Gaussian noise of variance growing in sigma ((T, sigma)-analog privacy
    rather than the exact scheme's information-theoretic T-privacy);
  * decoding is a real least-squares solve against a Chebyshev-basis
    Vandermonde system.  In EXACT arithmetic the masks still cancel
    perfectly at the data points — the interpolant u satisfies
    u(beta_k) = X_k by construction regardless of what the masks are — so
    the only decode error is float roundoff amplified by the conditioning
    of the solve and by the magnitude the masks inject (sigma).  That is
    the precision/privacy trade-off: larger sigma = stronger privacy =
    proportionally larger decode error, quantified per round by
    ``error_budget``.

Because there is no prime field, the worker function f needs no polynomial
degree gymnastics to stay under an overflow bound, and nonlinearities only
need to be polynomial *per coded phase* — the master can apply arbitrary
float nonlinearities (gelu, softmax) between phases.  That is what unlocks
the MLP (cluster/alcc_mlp.py).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

_EPS32 = float(np.finfo(np.float32).eps)


def recovery_threshold(K: int, T: int, r: int) -> int:
    """Minimum responders for the degree-(2r+1) logistic round: same
    (2r+1)(K+T-1)+1 count as the exact scheme — the polynomial degree
    argument is field-agnostic."""
    return (2 * r + 1) * (K + T - 1) + 1


def degree_threshold(K: int, T: int, deg_f: int) -> int:
    """Responders needed for an arbitrary degree-``deg_f`` worker poly."""
    return deg_f * (K + T - 1) + 1


def chebyshev_nodes(n: int) -> np.ndarray:
    """n Chebyshev first-kind nodes cos(pi(2i+1)/2n) on (-1, 1), float64.

    Returned in ascending order.  Near-optimal interpolation points: the
    Lebesgue constant grows like log n instead of 2^n for equispaced
    points, which is the whole reason the float decode is usable at all.
    """
    i = np.arange(n, dtype=np.float64)
    return np.sort(np.cos(np.pi * (2.0 * i + 1.0) / (2.0 * n)))


@dataclasses.dataclass(frozen=True)
class AnalogScheme:
    """Static data of one real-valued Lagrange code.

    Mirrors lagrange.CodingScheme's surface (betas / alphas /
    encode_matrix / decode) with real points and a least-squares decode.

    ``beta_scale`` shrinks the beta nodes toward 0 so they interleave
    strictly inside the alpha spread without colliding; ``cond_max`` is
    the square-solve conditioning ceiling beyond which ``decode`` falls
    back to an overdetermined least-squares over ALL received responses.
    """
    N: int                   # workers / shares
    K: int                   # parallelization (data split)
    T: int                   # analog privacy masks
    sigma: float = 1.0       # mask std dev (privacy knob)
    beta_scale: float = 0.45
    cond_max: float = 1e8

    def __post_init__(self):
        assert self.K >= 1 and self.T >= 0 and self.N >= self.K + self.T, (
            f"need N >= K+T, got N={self.N} K={self.K} T={self.T}")
        assert self.sigma >= 0.0 and 0.0 < self.beta_scale < 1.0

    @functools.cached_property
    def alphas(self) -> np.ndarray:
        """N evaluation points: Chebyshev nodes on (-1, 1)."""
        return chebyshev_nodes(self.N)

    @functools.cached_property
    def betas(self) -> np.ndarray:
        """K+T interpolation points: scaled Chebyshev nodes, disjoint from
        the alphas (checked — Chebyshev sets at different orders can
        coincide at 0 when both orders are odd)."""
        b = self.beta_scale * chebyshev_nodes(self.K + self.T)
        both = np.concatenate([b, self.alphas])
        assert np.min(np.diff(np.sort(both))) > 1e-12, (
            "alpha/beta evaluation points collide; pick another beta_scale")
        return b

    @functools.cached_property
    def encode_matrix(self) -> np.ndarray:
        """U (K+T, N) float64: U[j, i] = L_j(alpha_i), the Lagrange basis
        of the betas evaluated at the alphas — shares = U.T @ stacked."""
        return lagrange_basis(self.alphas, self.betas)

    def mask_points(self) -> np.ndarray:
        """The T beta nodes carrying masks (the last T, like the field
        scheme's Z_i rows)."""
        return self.betas[self.K:]

    # -- decode -----------------------------------------------------------

    def decode_matrix(self, survivors, deg_f: int
                      ) -> tuple[np.ndarray, dict]:
        """C (S_used, K) float64 + info so that decoded[k] = C[:, k] @ results.

        Square path: the first ``degree_threshold`` survivors give a square
        Chebyshev-Vandermonde system A c = h(alpha) for the coefficients of
        the degree-deg_f*(K+T-1) product polynomial h; the decode matrix is
        B A^{-1} with B the Chebyshev-Vandermonde at the K data betas.

        Fallback path: when cond(A_square) exceeds ``cond_max`` (clustered
        survivor nodes — the ill-conditioned large-N regime), ALL S received
        responses form an overdetermined system solved via pseudo-inverse,
        which averages the roundoff over the extra rows.

        info: {"cond": float, "fallback": bool, "rows": int, "need": int}.
        ``cond`` is always the condition number of the system actually
        solved.
        """
        surv = tuple(int(w) for w in np.asarray(survivors).ravel())
        return _decode_matrix_cached(self, surv, int(deg_f))

    def decode(self, results: np.ndarray, survivors, deg_f: int
               ) -> tuple[np.ndarray, dict]:
        """Recover {h(beta_k)}_{k<K} from survivor evaluations.

        results: (S, *res_shape) float evaluations h(alpha_i) in survivor
        order; len(survivors) >= degree_threshold(K, T, deg_f).
        Returns ((K, *res_shape) float64, info) — info additionally carries
        ``abs_err_budget``, the a-priori decode error bound
        cond * eps32 * max|results| (float32 worker arithmetic dominates).
        """
        results = np.asarray(results, dtype=np.float64)
        C, info = self.decode_matrix(survivors, deg_f)
        rows = info["rows"]
        flat = results[:rows].reshape(rows, -1)
        out = (C.T @ flat).reshape(self.K, *results.shape[1:])
        info = dict(info)
        mx = float(np.max(np.abs(results[:rows]))) if flat.size else 0.0
        info["abs_err_budget"] = error_budget(info["cond"], mx)
        return out, info

    def decode_sum(self, results: np.ndarray, survivors, deg_f: int
                   ) -> tuple[np.ndarray, dict]:
        """sum_k h(beta_k) — the aggregated-gradient read — in one pass."""
        decoded, info = self.decode(results, survivors, deg_f)
        return decoded.sum(axis=0), info


def lagrange_basis(at: np.ndarray, nodes: np.ndarray) -> np.ndarray:
    """L (len(nodes), len(at)) float64: L[j, i] = prod_{l!=j}
    (at_i - nodes_l) / (nodes_j - nodes_l)."""
    at = np.asarray(at, np.float64)
    nodes = np.asarray(nodes, np.float64)
    n = nodes.shape[0]
    out = np.empty((n, at.shape[0]), np.float64)
    for j in range(n):
        others = np.delete(nodes, j)
        num = np.prod(at[:, None] - others[None, :], axis=1)
        den = np.prod(nodes[j] - others)
        out[j] = num / den
    return out


def encode(scheme: AnalogScheme, parts: np.ndarray, masks: np.ndarray
           ) -> np.ndarray:
    """Encode K stacked parts + T Gaussian masks into N float shares.

    parts: (K, *shape); masks: (T, *shape).  Returns (N, *shape) float64 —
    the degree-(K+T-1) interpolant through (betas, [parts; masks])
    evaluated at the alphas.  Callers ship float32 to workers; the float64
    encode keeps the master-side roundoff below the float32 quantum.
    """
    parts = np.asarray(parts, np.float64)
    if scheme.T:
        stacked = np.concatenate(
            [parts, np.asarray(masks, np.float64)], axis=0)
    else:
        stacked = parts
    flat = stacked.reshape(scheme.K + scheme.T, -1)
    shares = scheme.encode_matrix.T @ flat                # (N, prod(shape))
    return shares.reshape(scheme.N, *parts.shape[1:])


def encode_replicated(scheme: AnalogScheme, value: np.ndarray,
                      masks: np.ndarray) -> np.ndarray:
    """Encode ONE value replicated at every data point (the weight encode:
    v(beta_k) = W for all k <= K, Gaussian at the mask points)."""
    parts = np.broadcast_to(np.asarray(value, np.float64)[None],
                            (scheme.K, *np.shape(value)))
    return encode(scheme, parts, masks)


def draw_masks(key, T: int, part_shape: tuple[int, ...],
               sigma: float) -> np.ndarray:
    """T i.i.d. Gaussian mask matrices, std ``sigma``, float64.

    Drawn through jax.random so rounds are replayable from (kloop, t) keys
    exactly like the field engine's uniform masks; any value works for
    correctness (masks cancel at the betas in exact arithmetic), sigma
    only sets the privacy level and the roundoff it costs.
    """
    if T == 0:
        return np.zeros((0, *part_shape), np.float64)
    import jax
    z = jax.random.normal(key, (T, *part_shape), dtype=np.float32)
    return np.asarray(z, np.float64) * float(sigma)


def error_budget(cond: float, max_abs: float, eps: float = _EPS32) -> float:
    """A-priori absolute decode-error bound: cond * eps * max|evaluation|.

    Worker arithmetic is float32, so each returned evaluation carries
    relative error ~eps32 scaled by its magnitude (which the Gaussian
    masks inflate by O(sigma)); the least-squares solve amplifies it by at
    most the system's condition number.  wait_stats surfaces the per-round
    max of this bound as ``alcc.abs_err_budget``.
    """
    return float(cond) * float(eps) * float(max_abs)


@functools.lru_cache(maxsize=256)
def _decode_matrix_cached(scheme: AnalogScheme, surv: tuple[int, ...],
                          deg_f: int) -> tuple[np.ndarray, dict]:
    from numpy.polynomial import chebyshev

    deg = deg_f * (scheme.K + scheme.T - 1)
    need = deg + 1
    assert len(surv) >= need, (
        f"need {need} survivors for deg(f)={deg_f}, got {len(surv)}")
    B = chebyshev.chebvander(scheme.betas[: scheme.K], deg)   # (K, deg+1)
    A_sq = chebyshev.chebvander(scheme.alphas[list(surv[:need])], deg)
    cond = float(np.linalg.cond(A_sq))
    if cond <= scheme.cond_max or len(surv) == need:
        # h(betas) = B A^{-1} h(alphas): solve A^T C = B^T once per
        # survivor pattern (cached), then every round is one matmul
        C = np.linalg.solve(A_sq.T, B.T).T if cond < 1e15 else B @ np.linalg.pinv(A_sq)
        return C.T, {"cond": cond, "fallback": False,
                     "rows": need, "need": need}
    # ill-conditioned square system: overdetermined least-squares over all
    # received responses (deterministic given the survivor tuple)
    A_all = chebyshev.chebvander(scheme.alphas[list(surv)], deg)
    cond_all = float(np.linalg.cond(A_all))
    C = B @ np.linalg.pinv(A_all)
    return C.T, {"cond": cond_all, "fallback": True,
                 "rows": len(surv), "need": need}
