"""Polynomial sigmoid surrogate + the unbiased product estimator (paper §3.3).

ĝ(z) = sum_i c_i z^i           — least-squares fit of 1/(1+e^{-z})   (Eq. 15)
ḡ(X̄, W̄) = sum_i c_i prod_{j<=i} (X̄ w̄^j)                            (Eq. 17)

E[ḡ] = ĝ(X̄ w) because the r weight quantizations are independent and each is
unbiased — the property Lemma 1 and the convergence proof rest on.

Coefficient quantization (a gap the paper leaves implicit): the real c_i must
live in F_p.  We quantize them at an explicit scale 2^lc and align every term
of ḡ to the SAME total scale lc + r(lx+lw) by pre-multiplying lower-degree
terms with the missing (2^{lx+lw})^{r-i} factor.  The decoded gradient then
dequantizes with l = lc + lx + r(lx+lw) (generalizes the paper's Eq. 24 which
corresponds to lc = 0 — under which a typical fitted slope c_1 ~ 0.2 would
round to ZERO; see tests/test_sigmoid_poly.py).  lc trades coefficient
precision against wrap-around headroom exactly like lx/lw (§3.1 discussion).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field

# Default fit interval.  Chosen so that the degree-1 LSQ slope (~0.24) is
# representable with small lc; MNIST-scale logits stay inside it.
FIT_LO, FIT_HI = -4.0, 4.0


@functools.lru_cache(maxsize=None)
def fit_sigmoid(r: int, z_min: float = FIT_LO, z_max: float = FIT_HI,
                num: int = 2001) -> tuple[float, ...]:
    """Degree-r least-squares fit of the sigmoid on [z_min, z_max] (Eq. 15)."""
    z = np.linspace(z_min, z_max, num)
    y = 1.0 / (1.0 + np.exp(-z))
    V = np.stack([z ** i for i in range(r + 1)], axis=1)
    coeffs, *_ = np.linalg.lstsq(V, y, rcond=None)
    return tuple(float(c) for c in coeffs)


def poly_eval_real(coeffs, z):
    out = jnp.zeros_like(z)
    for i, c in enumerate(coeffs):
        out = out + c * z ** i
    return out


def quantized_coeffs(r: int, lx: int, lw: int, lc: int = 6,
                     p: int = field.P,
                     z_range: tuple[float, float] = (FIT_LO, FIT_HI)
                     ) -> np.ndarray:
    """Field representation c̄_i of c_i, every ḡ term scale-aligned to
    lc + r(lx+lw):  c̄_i = round(c_i · 2^{lc + (r-i)(lx+lw)}) mod p."""
    coeffs = fit_sigmoid(r, *z_range)
    out = []
    for i, c in enumerate(coeffs):
        scale = 2 ** (lc + (r - i) * (lx + lw))
        out.append(int(round(c * scale)) % p)
    return np.array(out, dtype=np.int64)


def gradient_scale_poly(lx: int, lw: int, r: int, lc: int = 6) -> int:
    """Total scale of X̄ᵀḡ when ḡ uses quantized_coeffs: lc + lx + r(lx+lw)."""
    return lc + lx + r * (lx + lw)


def gbar_field(xw: jax.Array, cbar: jax.Array, p: int = field.P) -> jax.Array:
    """ḡ over F_p given the per-degree products XW̄ (Eq. 17), field coeffs c̄.

    xw: (..., r) field elements — column j is X̄ @ w̄^j (scale 2^{lx+lw}).
    cbar: (r+1,) field elements from quantized_coeffs.
    Returns (...,) field elements at uniform scale lc + r(lx+lw).
    """
    r = xw.shape[-1]
    out = jnp.broadcast_to(cbar[0].astype(jnp.int32), xw.shape[:-1])
    prod = None
    for i in range(1, r + 1):
        prod = xw[..., i - 1] if prod is None else field.mulmod(
            prod, xw[..., i - 1], p)
        out = field.addmod(out, field.mulmod(
            jnp.broadcast_to(cbar[i].astype(jnp.int32), prod.shape), prod, p), p)
    return out


def gbar_real(x: jax.Array, w_quants: jax.Array, coeffs,
              lx: int, lw: int, p: int = field.P) -> jax.Array:
    """Real-domain reference of Eq. (17) for tests: unbiased ĝ estimate.

    x: real (quantized-then-dequantized) data; w_quants: (d, r) field (F_p).
    """
    from repro.core import quantize
    r = w_quants.shape[-1]
    out = jnp.full(x.shape[:-1], coeffs[0], jnp.float32)
    prod = None
    for i in range(1, r + 1):
        wj = quantize.dequantize(w_quants[:, i - 1], lw, p)
        term = x @ wj
        prod = term if prod is None else prod * term
        out = out + coeffs[i] * prod
    return out
