"""BGW-style MPC baseline (paper §5 + Appendix A.5).

The comparison system the paper benchmarks against: Shamir secret sharing of
the ENTIRE quantized dataset at every worker + a multi-round BGW protocol for
the gradient polynomial.  Same quantization + sigmoid surrogate as CPML so
the two systems compute the identical update — only the privacy machinery
differs:

  * share:      [S]_i = S + sum_t R_t alpha_i^t            (degree-T Shamir)
  * multiply:   local product -> degree-2T sharing
  * reduce:     every worker re-shares its product share with a fresh
                degree-T polynomial; workers combine received sub-shares with
                Lagrange-at-0 coefficients  ==> one all-to-all round per
                multiplication (the "communication step" of A.5, vectorized)
  * reconstruct: interpolate at 0 from 2T+1 shares.

Costs this exposes (and the benchmarks measure): encode O(N·T·m·d) on the
full dataset per worker (vs CPML's 1/K-sized shares), a collective round per
multiplication (vs CPML's zero worker<->worker rounds), and no 1/K
parallelization of the compute.  Privacy: any T <= (N-1)/2 (higher than
CPML's trade-off — faithfully noted, paper §5).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field, quantize, sigmoid_poly


@dataclasses.dataclass(frozen=True)
class MPCConfig:
    N: int
    T: int
    r: int = 1
    lx: int = 2
    lw: int = 4
    lc: int = 6
    p: int = field.P

    def __post_init__(self):
        assert self.N >= 2 * self.T + 1, (
            f"BGW needs N >= 2T+1, got N={self.N}, T={self.T}")

    @functools.cached_property
    def alphas(self) -> np.ndarray:
        return np.arange(1, self.N + 1, dtype=np.int64)

    @functools.cached_property
    def lambda0(self) -> np.ndarray:
        """Lagrange-at-0 coefficients for all N points (degree < N interp)."""
        return _lagrange_at_zero(self.alphas, self.p)

    def lambda0_first(self, count: int) -> np.ndarray:
        return _lagrange_at_zero(self.alphas[:count], self.p)

    @property
    def grad_scale(self) -> int:
        return sigmoid_poly.gradient_scale_poly(self.lx, self.lw, self.r,
                                                self.lc)


def _lagrange_at_zero(points: np.ndarray, p: int) -> np.ndarray:
    pts = [int(x) % p for x in points]
    lam = []
    for i, ai in enumerate(pts):
        num, den = 1, 1
        for l, al in enumerate(pts):
            if l != i:
                num = num * al % p
                den = den * ((al - ai) % p) % p
        lam.append(num * field.host_inv(den, p) % p)
    return np.array(lam, dtype=np.int64)


# ---------------------------------------------------------------------------
# Shamir primitives (vectorized over all N workers: leading axis = workers)
# ---------------------------------------------------------------------------

def share(cfg: MPCConfig, key: jax.Array, value: jax.Array) -> jax.Array:
    """Degree-T Shamir shares of `value` -> (N, *value.shape)."""
    if cfg.T == 0:
        return jnp.broadcast_to(value[None], (cfg.N, *value.shape))
    masks = jax.random.randint(key, (cfg.T, *value.shape), 0, cfg.p,
                               dtype=jnp.int32)
    alphas = jnp.asarray(cfg.alphas, jnp.int32)          # (N,)
    shares = jnp.broadcast_to(value[None], (cfg.N, *value.shape))
    apow = jnp.ones((cfg.N,), jnp.int32)
    for t in range(cfg.T):
        apow = field.mulmod(apow, alphas, cfg.p)          # alpha^(t+1)
        term = field.mulmod(apow.reshape(-1, *([1] * value.ndim)),
                            masks[t][None], cfg.p)
        shares = field.addmod(shares, term, cfg.p)
    return shares


def reshare_keys(cfg: MPCConfig, key: jax.Array) -> jax.Array:
    """Per-source-worker re-share keys for ONE degree reduction.

    The one derivation both the vectorized oracle (`degree_reduce`) and the
    distributed runtime (cluster/mpc_runner.py, launch/cpml_worker.py) use:
    worker i re-shares under row i, so a worker process holding only the
    phase key produces the exact sub-shares the oracle's vmap lane i does.
    """
    return jax.random.split(key, cfg.N)


def make_subshares(cfg: MPCConfig, key: jax.Array, value: jax.Array
                   ) -> jax.Array:
    """Worker-side re-share: fresh degree-T shares of this worker's product
    share, one per recipient -> (N, *value.shape).  Row j goes to peer j."""
    return share(cfg, key, value)


def combine_subshares(cfg: MPCConfig, gathered: jax.Array) -> jax.Array:
    """Recipient-side combine: (N_from, *s) sub-shares (ordered by source
    worker) -> this worker's new degree-T share, via Lagrange-at-0 weights.

    Needs sub-shares from ALL N sources — the wait-for-all barrier of every
    BGW multiplication (DESIGN.md §7)."""
    lam = jnp.asarray(cfg.lambda0, jnp.int32)             # (N_from,)
    out = jnp.zeros(gathered.shape[1:], jnp.int32)
    for i in range(cfg.N):
        out = field.addmod(out, field.mulmod(
            jnp.broadcast_to(lam[i], gathered.shape[1:]),
            gathered[i], cfg.p), cfg.p)
    return out


def degree_reduce(cfg: MPCConfig, key: jax.Array, shares: jax.Array
                  ) -> jax.Array:
    """BGW degree reduction: (N, *s) degree-2T shares -> degree-T shares.

    The vectorized oracle for one all-to-all communication round, composed
    from the SAME per-worker hooks the distributed runtime runs: every
    source re-shares (`make_subshares` under its `reshare_keys` row), the
    all-to-all exchange is a transpose, and every recipient combines
    (`combine_subshares`).
    """
    resh = jax.vmap(lambda k, v: make_subshares(cfg, k, v))(
        reshare_keys(cfg, key), shares)                   # (N_from, N_to, *s)
    gathered = jnp.swapaxes(resh, 0, 1)                   # (N_to, N_from, *s)
    return jax.vmap(lambda g: combine_subshares(cfg, g))(gathered)


def reconstruct(cfg: MPCConfig, shares: jax.Array, degree: int) -> jax.Array:
    """Interpolate the secret (value at 0) from the first degree+1 shares."""
    need = degree + 1
    assert cfg.N >= need, f"cannot reconstruct degree {degree} from {cfg.N}"
    lam = jnp.asarray(cfg.lambda0_first(need), jnp.int32)
    out = jnp.zeros(shares.shape[1:], jnp.int32)
    for i in range(need):
        out = field.addmod(out, field.mulmod(
            jnp.broadcast_to(lam[i], shares.shape[1:]), shares[i], cfg.p),
            cfg.p)
    return out


def reconstruct_at(cfg: MPCConfig, shares: jax.Array,
                   workers: np.ndarray) -> jax.Array:
    """Interpolate the secret from the shares of an ARBITRARY worker subset.

    ``shares[i]`` is worker ``workers[i]``'s share.  Any 2T+1 correct shares
    of a degree-2T sharing determine the same polynomial, so the value at 0
    is the SAME field element ``reconstruct`` computes from the first 2T+1 —
    exactly, mod p.  This is what lets the cluster master reconstruct from
    the first 2T+1 ARRIVALS (whatever subset that is) while staying
    bit-identical to the single-host oracle (cluster/mpc_runner.py).
    """
    idx = np.asarray(workers, dtype=np.int64)
    lam = jnp.asarray(_lagrange_at_zero(cfg.alphas[idx], cfg.p), jnp.int32)
    out = jnp.zeros(shares.shape[1:], jnp.int32)
    for i in range(len(idx)):
        out = field.addmod(out, field.mulmod(
            jnp.broadcast_to(lam[i], shares.shape[1:]), shares[i], cfg.p),
            cfg.p)
    return out


# ---------------------------------------------------------------------------
# The private gradient protocol (same math as CPML's Eq. 19)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MPCState:
    w: jax.Array
    x_shares: jax.Array     # (N, m, d) — the FULL dataset at every worker
    xty: jax.Array
    m: int
    xq_real: jax.Array
    y: jax.Array


def setup(cfg: MPCConfig, key: jax.Array, x: jax.Array, y: jax.Array,
          w0: jax.Array | None = None) -> MPCState:
    xq = quantize.quantize_data(x, cfg.lx, cfg.p)
    x_shares = share(cfg, key, xq)
    xq_real = quantize.dequantize(xq, cfg.lx, cfg.p)
    xty = xq_real.T @ y.astype(jnp.float32)
    w = w0 if w0 is not None else jnp.zeros((x.shape[1],), jnp.float32)
    return MPCState(w=w, x_shares=x_shares, xty=xty, m=x.shape[0],
                    xq_real=xq_real, y=y)


# --- per-phase hooks: the pieces one worker (or the master) runs.  The
# distributed runtime (cluster/mpc_runner.py + launch/cpml_worker.py MPC
# serve mode) composes EXACTLY these, so a cluster MPC run is bit-identical
# to the single-host oracle below.

def poly_coeffs(cfg: MPCConfig) -> np.ndarray:
    """The quantized sigmoid-surrogate coefficients c̄ (one host-side
    derivation shared by `_step_jit` and worker provisioning)."""
    return np.asarray(
        sigmoid_poly.quantized_coeffs(cfg.r, cfg.lx, cfg.lw, cfg.lc, cfg.p),
        dtype=np.int32)


def step_keys(cfg: MPCConfig, key: jax.Array
              ) -> tuple[jax.Array, jax.Array, list[jax.Array]]:
    """(kw weight-share, kq stochastic-quantization, kred one per degree
    reduction) — the exact split the oracle has always used."""
    kw, kq, *kred = jax.random.split(key, 3 + cfg.r)
    return kw, kq, kred


def encode_step(cfg: MPCConfig, key: jax.Array, w: jax.Array
                ) -> tuple[jax.Array, list[jax.Array]]:
    """Master-side start of one iteration: quantize + Shamir-share the
    weights (same W̄ structure as CPML) and derive the per-reduction reshare
    keys shipped to the workers.  Returns (w_shares (N, d, r), kred)."""
    kw, kq, kred = step_keys(cfg, key)
    wbar = quantize.quantize_weights(kq, w, cfg.lw, cfg.r, cfg.p)   # (d, r)
    return share(cfg, kw, wbar), kred


def worker_mul(cfg: MPCConfig, x_share: jax.Array, w_share: jax.Array
               ) -> jax.Array:
    """Local multiply Z = [X̄] @ [w̄]: secret x secret -> degree-2T (m, r)."""
    return field.matmul(x_share, w_share, cfg.p)


def s_init(cfg: MPCConfig, cbar: jax.Array, prod: jax.Array) -> jax.Array:
    """s = c̄_0 + c̄_1 z after the first degree reduction."""
    return field.addmod(
        jnp.broadcast_to(cbar[0], prod.shape),
        field.mulmod(jnp.broadcast_to(cbar[1], prod.shape), prod, cfg.p),
        cfg.p)


def s_accum(cfg: MPCConfig, cbar_i: jax.Array, s: jax.Array,
            prod: jax.Array) -> jax.Array:
    """s += c̄_i z^i for the higher-degree surrogate terms."""
    return field.addmod(s, field.mulmod(
        jnp.broadcast_to(cbar_i, prod.shape), prod, cfg.p), cfg.p)


def worker_final(cfg: MPCConfig, x_share: jax.Array, s: jax.Array
                 ) -> jax.Array:
    """Final local multiply G-share = [X̄]ᵀ s -> degree-2T (d,)."""
    return field.matmul(x_share.T, s[:, None], cfg.p)[:, 0]


def finish_update(cfg: MPCConfig, w: jax.Array, decoded: jax.Array,
                  xty: jax.Array, eta_over_m: jax.Array) -> jax.Array:
    """Master-side end of one iteration: dequantize + gradient step."""
    xg = quantize.dequantize(decoded, cfg.grad_scale, cfg.p)
    return w - eta_over_m * (xg - xty)


@functools.partial(jax.jit, static_argnums=(0,))
def _step_jit(cfg: MPCConfig, key: jax.Array, w: jax.Array,
              x_shares: jax.Array, xty: jax.Array,
              eta_over_m: jax.Array) -> jax.Array:
    """One BGW iteration, all N workers vectorized — the single-host oracle,
    composed from the same hooks the distributed runtime runs per worker."""
    cbar = jnp.asarray(poly_coeffs(cfg), jnp.int32)
    w_shares, kred = encode_step(cfg, key, w)                       # (N, d, r)
    # round 1: Z_j = X̄ w̄ʲ — secret×secret -> degree 2T, then reduce.
    z = jax.vmap(lambda xs, ws: worker_mul(cfg, xs, ws))(
        x_shares, w_shares)                                         # (N, m, r)
    z = degree_reduce(cfg, kred[0], z)
    # rounds 2..r: running products of z columns (elementwise muls).
    prod = z[..., 0]
    s = s_init(cfg, cbar, prod)
    for i in range(2, cfg.r + 1):
        prod = field.mulmod(prod, z[..., i - 1], cfg.p)             # deg 2T
        prod = degree_reduce(cfg, kred[i - 1], prod)
        s = s_accum(cfg, cbar[i], s, prod)
    # final multiplication: G = X̄ᵀ s — degree 2T, reconstruct directly.
    g_shares = jax.vmap(lambda xs, ss: worker_final(cfg, xs, ss))(
        x_shares, s)                                                # (N, d)
    decoded = reconstruct(cfg, g_shares, 2 * cfg.T)
    return finish_update(cfg, w, decoded, xty, eta_over_m)


def step(cfg: MPCConfig, key: jax.Array, state: MPCState, eta: float
         ) -> MPCState:
    w = _step_jit(cfg, key, state.w, state.x_shares, state.xty,
                  jnp.float32(eta / state.m))
    return dataclasses.replace(state, w=w)


def iteration_key(kloop: jax.Array, t: int) -> jax.Array:
    """Iteration t's protocol key — one derivation shared by train() and
    the cluster runtime (cluster/mpc_runner.py)."""
    return jax.random.fold_in(kloop, t)


def train(cfg: MPCConfig, key: jax.Array, x: jax.Array, y: jax.Array,
          iters: int, eta: float | None = None, eval_every: int = 0
          ) -> tuple[jax.Array, list[dict[str, float]]]:
    from repro.core import protocol as cpml
    ksetup, kloop = jax.random.split(key)
    state = setup(cfg, ksetup, x, y)
    if eta is None:
        eta = cpml.lipschitz_eta(state.xq_real)
    history = []
    for t in range(iters):
        state = step(cfg, iteration_key(kloop, t), state, eta)
        if eval_every and (t + 1) % eval_every == 0:
            l, a = cpml.loss_and_accuracy(state.w, state.xq_real, state.y)
            history.append({"iter": t + 1, "loss": float(l), "acc": float(a)})
    return state.w, history
