"""Finite-field (F_p) arithmetic primitives, int32/MXU-safe.

The paper computes over F_p with p = 15485863 (largest 24-bit prime) using
int64 CPU ops.  TPUs have no fast int64, so every primitive here is built to
stay inside int32 (and, on the matmul path, inside exact bf16/fp32 MXU
arithmetic — see kernels/modmatmul.py).  All functions are shape-polymorphic
jnp ops usable under jit/shard_map.

Conventions:
  * field elements are int32 in [0, p)
  * products of two elements can reach 2*bits(p) — NEVER form a*b directly
    in int32; use mulmod() / matmul() (8-bit limb split) instead.
  * any p < 2^30 is supported (addmod needs 2p < 2^31); the paper's 24-bit
    prime is the faithful default, P30 is our extended-precision option that
    the limb decomposition supports at identical kernel structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# The paper's modulus: largest prime below 2^24 (§5, "CodedPrivateML
# parameters").
P = 15485863
# Extended-precision prime (beyond-paper): 2^30 - 35.  Still int32-safe
# (2p < 2^31) and 8-bit-limb exact on the MXU; gives ~6 extra headroom bits
# against wrap-around, which buys larger lc/lx/lw (see sigmoid_poly.py).
P30 = 1073741789

LIMB_BITS = 8
LIMB_MASK = (1 << LIMB_BITS) - 1


def n_limbs(p: int) -> int:
    """8-bit limbs needed to cover elements of F_p (3 for P, 4 for P30)."""
    return -(-p.bit_length() // LIMB_BITS)


def fmod(x: jax.Array, p: int = P) -> jax.Array:
    """Reduce an int32 array (possibly negative) into [0, p)."""
    r = jnp.remainder(x, jnp.int32(p))
    return r.astype(jnp.int32)


def addmod(a: jax.Array, b: jax.Array, p: int = P) -> jax.Array:
    """(a + b) mod p.  a,b in [0,p): sum < 2p < 2^31, int32-safe."""
    s = a + b
    return jnp.where(s >= p, s - p, s).astype(jnp.int32)


def submod(a: jax.Array, b: jax.Array, p: int = P) -> jax.Array:
    d = a - b
    return jnp.where(d < 0, d + p, d).astype(jnp.int32)


def negmod(a: jax.Array, p: int = P) -> jax.Array:
    return jnp.where(a == 0, 0, p - a).astype(jnp.int32)


def limbs(x: jax.Array, p: int = P) -> list[jax.Array]:
    """Split int32 field elements into 8-bit limbs (low first)."""
    return [((x >> (LIMB_BITS * i)) & LIMB_MASK).astype(jnp.int32)
            for i in range(n_limbs(p))]


def double_mod(x: jax.Array, times: int, p: int) -> jax.Array:
    """x * 2^times mod p via repeated doubling; x stays < 2p < 2^31."""
    for _ in range(times):
        x = x + x
        x = jnp.where(x >= p, x - p, x)
    return x


def mulmod(a: jax.Array, b: jax.Array, p: int = P) -> jax.Array:
    """Element-wise (a * b) mod p without ever exceeding int32.

    Schoolbook limb x limb products (< 2^16, exact) recombined with
    shift-by-doubling mod p.
    """
    a_l = limbs(a, p)
    b_l = limbs(b, p)
    nl = len(a_l)
    acc = jnp.zeros(jnp.broadcast_shapes(jnp.shape(a), jnp.shape(b)), jnp.int32)
    for i in range(nl):
        for j in range(nl):
            prod = a_l[i] * b_l[j]  # < 2^16, exact
            acc = addmod(acc, double_mod(fmod(prod, p), LIMB_BITS * (i + j), p), p)
    return acc


def powmod(a: jax.Array, e: int, p: int = P) -> jax.Array:
    """a^e mod p by square-and-multiply (e is a static python int)."""
    result = jnp.ones_like(a)
    base = a
    while e > 0:
        if e & 1:
            result = mulmod(result, base, p)
        base = mulmod(base, base, p)
        e >>= 1
    return result


def invmod(a: jax.Array, p: int = P) -> jax.Array:
    """Modular inverse via Fermat: a^(p-2) mod p.  a must be nonzero."""
    return powmod(a, p - 2, p)


def matmul(a: jax.Array, b: jax.Array, p: int = P,
           chunk: int = 4096) -> jax.Array:
    """Exact (a @ b) mod p for int32 field matrices, never leaving int32.

    Both operands are split into 8-bit limbs; limb-product partial sums over a
    contraction chunk of size <= 2^15 stay < 2^16 * 2^15 = 2^31.  Limbs are
    recombined with shift-by-doubling mod p.  This is the canonical pure-jnp
    spec; kernels/modmatmul.py is the Pallas/MXU version of the same math.

    a: (M, K), b: (K, N) -> (M, N) int32 in [0, p).
    """
    assert a.ndim == 2 and b.ndim == 2 and b.shape[0] == a.shape[1], (
        a.shape, b.shape)
    if b.shape[1] == 1:
        # XLA strength-reduces width-1 dots into broadcast-multiply-reduce
        # loop fusions whose fused producers are recomputed per output
        # element — 5-20x slower when the limb products sit in a composed
        # graph (the c=1 worker polynomial; DESIGN.md §4).  A duplicated
        # second column keeps every limb product a real dot; the values are
        # identical and the extra column is sliced away.
        return matmul(a, jnp.concatenate([b, b], axis=1), p, chunk)[:, :1]
    K = a.shape[-1]
    chunk = min(chunk, 1 << 15)
    out = jnp.zeros((a.shape[0], b.shape[1]), jnp.int32)
    a_l = limbs(a, p)
    b_l = limbs(b, p)
    nl = len(a_l)
    for start in range(0, K, chunk):
        sl = slice(start, min(start + chunk, K))
        for i in range(nl):
            ai = a_l[i][:, sl]
            for j in range(nl):
                bj = b_l[j][sl, :]
                # int32 matmul: products < 2^16, <=2^15 terms -> < 2^31 exact.
                s = jax.lax.dot_general(
                    ai, bj, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                out = addmod(out, double_mod(fmod(s, p), LIMB_BITS * (i + j), p), p)
    return out


# ---------------------------------------------------------------------------
# Host-side (numpy / python int) helpers for building encode/decode matrices.
# These run once at protocol setup, not in the jit hot path, so python ints
# (arbitrary precision) are fine and are the clearest spec of the math.
# ---------------------------------------------------------------------------

def host_inv(a: int, p: int = P) -> int:
    return pow(int(a) % p, p - 2, p)


def host_lagrange_coeffs(eval_points: np.ndarray, interp_points: np.ndarray,
                         p: int = P) -> np.ndarray:
    """U[i, j] = prod_{l != i} (alpha_j - beta_l) / (beta_i - beta_l) mod p.

    Returns the (len(interp_points), len(eval_points)) encoding matrix of
    Eq. (12): column j encodes evaluation at alpha_j.
    """
    betas = [int(b) % p for b in interp_points]
    alphas = [int(a) % p for a in eval_points]
    kpt = len(betas)
    U = np.zeros((kpt, len(alphas)), dtype=np.int64)
    # denominators: d_i = prod_{l != i} (beta_i - beta_l)
    denom_inv = []
    for i in range(kpt):
        d = 1
        for l in range(kpt):
            if l != i:
                d = d * ((betas[i] - betas[l]) % p) % p
        denom_inv.append(host_inv(d, p))
    for j, alpha in enumerate(alphas):
        for i in range(kpt):
            num = 1
            for l in range(kpt):
                if l != i:
                    num = num * ((alpha - betas[l]) % p) % p
            U[i, j] = num * denom_inv[i] % p
    return U.astype(np.int64)


def host_vandermonde_inv(points: np.ndarray, p: int = P) -> np.ndarray:
    """Inverse of the Vandermonde matrix V[i,j] = points[i]^j over F_p.

    Used to interpolate h(z) coefficients from worker evaluations.
    Gauss-Jordan elimination with modular inverses (host-side, python ints).
    """
    pts = [int(x) % p for x in points]
    n = len(pts)
    M = [[pow(pts[i], j, p) for j in range(n)] + [1 if k == i else 0 for k in range(n)]
         for i in range(n)]
    for col in range(n):
        piv = next(r for r in range(col, n) if M[r][col] % p != 0)
        M[col], M[piv] = M[piv], M[col]
        inv = host_inv(M[col][col], p)
        M[col] = [v * inv % p for v in M[col]]
        for r in range(n):
            if r != col and M[r][col] % p:
                f = M[r][col]
                M[r] = [(M[r][c] - f * M[col][c]) % p for c in range(2 * n)]
    return np.array([[M[i][n + j] for j in range(n)] for i in range(n)],
                    dtype=np.int64)


def to_signed(x: jax.Array, p: int = P) -> jax.Array:
    """phi^{-1} of Eq. (25): map [0,p) back to signed integers."""
    half = (p - 1) // 2
    return jnp.where(x >= half, x - p, x)


def from_signed(x: jax.Array, p: int = P) -> jax.Array:
    """phi of Eq. (7): embed signed integers into [0, p)."""
    return jnp.where(x < 0, x + p, x).astype(jnp.int32)
