"""CodedPrivateML end-to-end protocol (paper Algorithm 1).

Master-side: quantize -> Lagrange-encode -> dispatch -> decode -> update.
Worker-side: f(X̃_i, W̃_i) = X̃_iᵀ ḡ(X̃_i, W̃_i) over F_p (Eq. 20), a degree
(2r+1) polynomial, so any (2r+1)(K+T-1)+1 surviving workers decode (Thm. 1).

Execution backends:
  * "vmap"     — all N workers simulated on one device (tests/benchmarks).
  * "shard"    — shard_map over a mesh axis: one coded share per device,
                 zero collectives in the worker step (the paper's key property),
                 one all_gather for "send results to master".
  * kernel=True routes the worker computation through the fused Pallas kernel
    (kernels/coded_grad.py) instead of the jnp field ops.

Straggler tolerance: results arrive as an (N, d) array + a survivor index
list; the decode matrix for the survivor set is built host-side (static per
pattern) and applied as one field matmul — semantics of "wait for the fastest
R workers" preserved as erasure decoding (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field, lagrange, quantize, sigmoid_poly


@dataclasses.dataclass(frozen=True)
class CPMLConfig:
    N: int                  # workers
    K: int                  # parallelization (dataset split)
    T: int                  # privacy threshold
    r: int = 1              # sigmoid polynomial degree
    lx: int = 2             # dataset quantization scale (paper §5)
    lw: int = 4             # weight quantization scale (paper §5)
    lc: int = 6             # sigmoid-coefficient scale (see sigmoid_poly.py)
    p: int = field.P
    backend: str = "vmap"   # "vmap" | "shard"
    mesh_axis: str = "workers"
    use_kernel: bool = False

    def __post_init__(self):
        need = lagrange.recovery_threshold(self.K, self.T, self.r)
        assert self.N >= need, (
            f"N={self.N} < recovery threshold {need} for "
            f"(K={self.K}, T={self.T}, r={self.r}); Theorem 1 violated")

    @property
    def threshold(self) -> int:
        return lagrange.recovery_threshold(self.K, self.T, self.r)

    @property
    def scheme(self) -> lagrange.CodingScheme:
        return lagrange.CodingScheme(self.N, self.K, self.T, self.p)

    @property
    def grad_scale(self) -> int:
        return sigmoid_poly.gradient_scale_poly(self.lx, self.lw, self.r,
                                                self.lc)

    def headroom_bits(self, x_max: float, m: int) -> float:
        """log2((p-1)/2) - log2(worst-case decoded magnitude).

        Negative => the decoded sub-gradient h(beta_k) can wrap around
        (paper §3.1's overflow error).  Worst case per part: sum over m/K
        samples of x̄ * ḡ at the aligned scale.  Use P30 / smaller lc / larger
        K when this goes negative (r=2 at the paper's 24-bit prime does).
        """
        import math
        per_part = (m / self.K) * (2 ** self.lx * max(x_max, 1e-9)) \
            * 2 ** (self.lc + self.r * (self.lx + self.lw))
        return math.log2((self.p - 1) / 2) - math.log2(per_part)


# ---------------------------------------------------------------------------
# Phase 1+2: quantize + encode the dataset (done once, Algorithm 1 lines 1-3)
# ---------------------------------------------------------------------------

def pad_rows(x: jax.Array, K: int) -> jax.Array:
    m = x.shape[0]
    pad = (-m) % K
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
    return x


def encode_dataset(cfg: CPMLConfig, key: jax.Array, x: jax.Array
                   ) -> tuple[jax.Array, dict[str, Any]]:
    """Returns shares (N, m/K, d) + master-side cleartext context."""
    xq = quantize.quantize_data(x, cfg.lx, cfg.p)          # (m, d) field
    xq = pad_rows(xq, cfg.K)
    mk = xq.shape[0] // cfg.K
    parts = xq.reshape(cfg.K, mk, xq.shape[-1])
    masks = lagrange.draw_masks(key, cfg.T, parts.shape[1:], cfg.p)
    shares = lagrange.encode(cfg.scheme, parts, masks, cfg.p)
    ctx = {"xq": xq, "m_padded": xq.shape[0]}
    return shares, ctx


def encode_weights(cfg: CPMLConfig, key: jax.Array, w: jax.Array) -> jax.Array:
    """Quantize w (Eq. 9-10) and Lagrange-encode W̄ (Eq. 13-14).

    Returns shares (N, d, r).  Note v(beta_i) = W̄ for ALL i <= K (the paper
    repeats the same W̄ at every data interpolation point), with fresh random
    masks V each round.
    """
    kq, km = jax.random.split(key)
    wbar = quantize.quantize_weights(kq, w, cfg.lw, cfg.r, cfg.p)  # (d, r)
    parts = jnp.broadcast_to(wbar[None], (cfg.K, *wbar.shape))
    masks = lagrange.draw_masks(km, cfg.T, wbar.shape, cfg.p)
    return lagrange.encode(cfg.scheme, parts, masks, cfg.p)


# ---------------------------------------------------------------------------
# Phase 3: worker computation (Eq. 20) — polynomial over F_p
# ---------------------------------------------------------------------------

def worker_fn(cfg: CPMLConfig, cbar: jax.Array
              ) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """f(X̃, W̃) = X̃ᵀ ḡ(X̃, W̃) for ONE worker. (mk,d),(d,r) -> (d,)."""

    def f(x_share: jax.Array, w_share: jax.Array) -> jax.Array:
        if cfg.use_kernel:
            from repro.kernels import ops as kernel_ops
            return kernel_ops.coded_grad(x_share, w_share, cbar, cfg.p)
        xw = field.matmul(x_share, w_share, cfg.p)          # (mk, r)
        s = sigmoid_poly.gbar_field(xw, cbar, cfg.p)        # (mk,)
        return field.matmul(x_share.T, s[:, None], cfg.p)[:, 0]  # (d,)

    return f


def all_worker_results(cfg: CPMLConfig, cbar: jax.Array, x_shares: jax.Array,
                       w_shares: jax.Array) -> jax.Array:
    """(N, mk, d) x (N, d, r) -> (N, d) worker results."""
    f = worker_fn(cfg, cbar)
    if cfg.backend == "vmap":
        return jax.vmap(f)(x_shares, w_shares)
    elif cfg.backend == "shard":
        mesh = jax.sharding.get_abstract_mesh()  # inside with-mesh context
        axis = cfg.mesh_axis

        def shard_body(xs, ws):
            res = f(xs[0], ws[0])[None]
            # "send result back to the master": one collective, results
            # replicated so the (replicated) decode can run everywhere.
            return jax.lax.all_gather(res, axis, axis=0, tiled=True)

        from jax.sharding import PartitionSpec as Pspec
        # check_vma=False: the all_gather makes the output replicated, but
        # the static varying-manual-axes check cannot infer that.
        return jax.shard_map(
            shard_body, mesh=mesh,
            in_specs=(Pspec(axis), Pspec(axis)),
            out_specs=Pspec(), check_vma=False)(x_shares, w_shares)
    raise ValueError(cfg.backend)


# ---------------------------------------------------------------------------
# Phase 4: decode + model update (Eq. 23-24, 19)
# ---------------------------------------------------------------------------

def decode_gradient(cfg: CPMLConfig, results: jax.Array,
                    decode_mat: jax.Array) -> jax.Array:
    """Decode the K sub-gradients h(beta_k) and sum them IN THE REAL DOMAIN.

    The paper sums in the field (Eq. 23); summing after per-part
    dequantization is numerically identical when nothing wraps, and buys
    log2(K) bits of wrap-around headroom per part — each h(beta_k) only
    accumulates m/K samples.  results: (R, d) -> real (d,).
    """
    out = field.matmul(decode_mat.T, results, cfg.p)  # (K, d) field
    return quantize.dequantize(out, cfg.grad_scale, cfg.p).sum(axis=0)


def make_decode_matrix(cfg: CPMLConfig, survivors: np.ndarray) -> jax.Array:
    surv = np.asarray(survivors)[: cfg.threshold]
    return jnp.asarray(cfg.scheme.decode_matrix(surv), jnp.int32)


@dataclasses.dataclass
class CPMLState:
    w: jax.Array            # real-domain weights (d,)
    x_shares: jax.Array     # (N, mk, d) coded dataset
    xty: jax.Array          # real-domain Xqᵀ y (master-side clear part)
    m: int                  # number of (unpadded) samples
    xq_real: jax.Array      # dequantized dataset (for loss eval / oracle)
    y: jax.Array


def setup(cfg: CPMLConfig, key: jax.Array, x: jax.Array, y: jax.Array,
          w0: jax.Array | None = None) -> CPMLState:
    kx, kw = jax.random.split(key)
    x_shares, ctx = encode_dataset(cfg, kx, x)
    xq_real = quantize.dequantize(pad_rows(quantize.quantize_data(x, cfg.lx, cfg.p),
                                           cfg.K), cfg.lx, cfg.p)
    y_pad = jnp.concatenate([y, jnp.zeros(ctx["m_padded"] - y.shape[0], y.dtype)])
    xty = xq_real.T @ y_pad.astype(jnp.float32)
    d = x.shape[1]
    w = w0 if w0 is not None else jnp.zeros((d,), jnp.float32)
    return CPMLState(w=w, x_shares=x_shares, xty=xty, m=x.shape[0],
                     xq_real=xq_real, y=y_pad)


@functools.partial(jax.jit, static_argnums=(0,))
def _step_jit(cfg: CPMLConfig, key: jax.Array, w: jax.Array,
              x_shares: jax.Array, xty: jax.Array, decode_mat: jax.Array,
              order: jax.Array, eta_over_m: jax.Array) -> jax.Array:
    cbar = jnp.asarray(
        sigmoid_poly.quantized_coeffs(cfg.r, cfg.lx, cfg.lw, cfg.lc, cfg.p),
        jnp.int32)
    w_shares = encode_weights(cfg, key, w)
    results = all_worker_results(cfg, cbar, x_shares, w_shares)   # (N, d)
    fastest = jnp.take(results, order, axis=0)                    # (R, d)
    xg = decode_gradient(cfg, fastest, decode_mat)                # Xᵀ ḡ real
    grad = (xg - xty)                                             # Xᵀ(ḡ - y)
    return w - eta_over_m * grad


def step(cfg: CPMLConfig, key: jax.Array, state: CPMLState, eta: float,
         survivors: np.ndarray | None = None) -> CPMLState:
    """One master iteration.  survivors: indices of workers that responded
    (None = all N; only the fastest `threshold` are used, like the paper)."""
    surv = np.arange(cfg.N) if survivors is None else np.asarray(survivors)
    assert len(surv) >= cfg.threshold, "not enough survivors to decode"
    surv = surv[: cfg.threshold]
    dmat = make_decode_matrix(cfg, surv)
    order = jnp.asarray(surv, jnp.int32)
    w = _step_jit(cfg, key, state.w, state.x_shares, state.xty, dmat, order,
                  jnp.float32(eta / state.m))
    return dataclasses.replace(state, w=w)


def lipschitz_eta(xq_real: jax.Array) -> float:
    """eta = 1/L.  The cost (Eq. 1) carries a 1/m, so its Hessian is
    (1/m) X̄ᵀ S X̄ with S ⪯ I/4, giving L = max eig(X̄ᵀX̄)/(4m).
    (The paper's Lemma 2 states L = ||X̄||₂²/4, omitting the 1/m that its own
    Eq. (1) introduces — with that L the step size is m× too small to
    reproduce Fig. 3's 25-iteration accuracy.)"""
    # power iteration — avoids O(d^3) eigendecomposition for large d.
    m, d = xq_real.shape
    v = jnp.ones((d,), jnp.float32) / np.sqrt(d)
    for _ in range(50):
        v = xq_real.T @ (xq_real @ v)
        v = v / (jnp.linalg.norm(v) + 1e-30)
    lam = v @ (xq_real.T @ (xq_real @ v))
    return float(4.0 * m / lam)


def sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def loss_and_accuracy(w: jax.Array, x: jax.Array, y: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    z = x @ w
    yhat = sigmoid(z)
    eps = 1e-7
    loss = -jnp.mean(y * jnp.log(yhat + eps) + (1 - y) * jnp.log(1 - yhat + eps))
    acc = jnp.mean((yhat > 0.5) == (y > 0.5))
    return loss, acc


def train(cfg: CPMLConfig, key: jax.Array, x: jax.Array, y: jax.Array,
          iters: int, eta: float | None = None,
          survivor_fn: Callable[[int], np.ndarray] | None = None,
          eval_every: int = 0) -> tuple[jax.Array, list[dict[str, float]]]:
    """Full Algorithm 1.  Returns (w, history)."""
    ksetup, kloop = jax.random.split(key)
    state = setup(cfg, ksetup, x, y)
    if eta is None:
        eta = lipschitz_eta(state.xq_real)
    history: list[dict[str, float]] = []
    for t in range(iters):
        kt = jax.random.fold_in(kloop, t)
        surv = survivor_fn(t) if survivor_fn else None
        state = step(cfg, kt, state, eta, surv)
        if eval_every and (t + 1) % eval_every == 0:
            l, a = loss_and_accuracy(state.w, state.xq_real[: state.m],
                                     state.y[: state.m])
            history.append({"iter": t + 1, "loss": float(l), "acc": float(a)})
    return state.w, history
