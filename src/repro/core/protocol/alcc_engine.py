"""ALCC engine: the float backend behind the exact engine's stage hooks.

Same hook surface as engine.py — ``setup`` / ``round_fn`` / ``update_fn`` /
``encode_round_shares`` / ``round_key`` / ``draw_batch`` / ``survivor_round``
/ ``train_reference`` — with three semantic changes (DESIGN.md §14):

  * no quantization: the dataset, weights and the sigmoid surrogate's
    coefficients stay float (the surrogate itself is shared with the exact
    engine — ``sigmoid_poly.fit_sigmoid`` — so an exact-vs-ALCC comparison
    at equal (K, T, r) isolates the coding arithmetic, not the model);
  * privacy masks are Gaussian (core/alcc.py) and the decode is a real
    least-squares solve, so reconstruction is approximate: every decode
    returns a per-round info dict (condition number, fallback flag,
    a-priori error budget) which drivers surface in ``wait_stats["alcc"]``;
  * "bit-identical" verification becomes two-tier: a SIMULATED run replays
    bit-for-bit through ``train_reference`` (the sim round and the replay
    are the same deterministic numpy calls on the same inputs), while a
    SOCKET run replays to within the decode error budget (real workers
    evaluate under XLA, whose float32 summation order can differ from the
    replay's BLAS einsum in the last bits) — and convergence is judged
    against the *uncoded* ``float_oracle``.

The per-round dataflow (logistic): master encodes W replicated at the K
data points + T Gaussian masks; worker i computes the degree-(2r+1)
polynomial f(X̃_i, W̃_i) = X̃_iᵀ ĝ(X̃_i W̃_i) in float32; any
(2r+1)(K+T-1)+1 responses least-squares-decode to the per-part
sub-gradients X̄_kᵀ ĝ(X̄_k W).

The MLP half (``mlp_*``) is what the exact engine structurally cannot do:
two degree-2 coded phases per step (forward X·W1, backward X̄ᵀδ1) with the
gelu/softmax nonlinearities applied by the master IN THE CLEAR between
them, stitched so the result equals jax.grad of the plaintext
``models/layers.gelu_mlp`` loss up to decode noise (cluster/alcc_mlp.py
drives it through the scheduler).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alcc, sigmoid_poly
from repro.core.protocol import engine as _exact

# float-side helpers shared verbatim with the exact engine (none of these
# touch the field): step size, losses, PRNG schedule, w shape conventions.
lipschitz_eta = _exact.lipschitz_eta
sigmoid = _exact.sigmoid
loss_and_accuracy = _exact.loss_and_accuracy
multiclass_loss_and_accuracy = _exact.multiclass_loss_and_accuracy
round_key = _exact.round_key
draw_batch = _exact.draw_batch
_w_internal = _exact._w_internal
_w_public = _exact._w_public
_eval_metrics = _exact._eval_metrics


@dataclasses.dataclass(frozen=True)
class ALCCConfig:
    """Static parameters of one ALCC deployment (the float CPMLConfig).

    The quantization scales (lx/lw/lc/p) are gone; in their place:
    ``sigma`` — Gaussian mask std, the privacy knob whose cost is decode
    roundoff; ``beta_scale``/``cond_max`` — decode conditioning knobs
    (core/alcc.py).  N/K/T/r/c/batch_rows mean exactly what they mean in
    CPMLConfig, and the logistic recovery threshold is the same
    (2r+1)(K+T-1)+1.
    """
    N: int
    K: int
    T: int
    r: int = 1
    c: int = 1
    sigma: float = 1.0
    batch_rows: int | None = None
    beta_scale: float = 0.45
    cond_max: float = 1e8

    def __post_init__(self):
        need = alcc.recovery_threshold(self.K, self.T, self.r)
        assert self.N >= need, (
            f"N={self.N} < recovery threshold {need} for "
            f"(K={self.K}, T={self.T}, r={self.r})")
        assert self.c >= 1
        assert self.batch_rows is None or self.batch_rows >= 1

    @property
    def threshold(self) -> int:
        """Logistic-round recovery threshold (deg f = 2r+1)."""
        return alcc.recovery_threshold(self.K, self.T, self.r)

    @property
    def mlp_threshold(self) -> int:
        """Per-phase MLP threshold: both coded phases are bilinear
        (deg 2), so 2(K+T-1)+1 responses decode — LESS than the logistic
        round needs at the same (K, T)."""
        return alcc.degree_threshold(self.K, self.T, 2)

    @property
    def scheme(self) -> alcc.AnalogScheme:
        return _scheme(self.N, self.K, self.T, self.sigma,
                       self.beta_scale, self.cond_max)


@functools.lru_cache(maxsize=64)
def _scheme(N, K, T, sigma, beta_scale, cond_max) -> alcc.AnalogScheme:
    # one shared instance per parameter tuple so the cached_property
    # matrices and the decode-matrix lru survive across config copies
    return alcc.AnalogScheme(N=N, K=K, T=T, sigma=sigma,
                             beta_scale=beta_scale, cond_max=cond_max)


@dataclasses.dataclass
class ALCCState:
    """Float mirror of CPMLState (same field names; runner.py reads
    x_shares / xq_real / mk / m / w through either)."""
    w: jax.Array                # (d,) or (d, c) float32
    x_shares: np.ndarray        # (N, mk, d) float32 coded dataset
    xty: np.ndarray             # (d, c) float64 full-data X̄ᵀY
    m: int
    mk: int
    xq_real: jax.Array          # (m_padded, d) float32 plaintext (metrics)
    xq_parts: np.ndarray        # (K, mk, d) float64 split plaintext
    y: jax.Array                # (m_padded,) padded labels
    y_parts: np.ndarray         # (K, mk, c) float64 split targets


def _pad_parts(K: int, x: np.ndarray) -> tuple[np.ndarray, int]:
    """Zero-pad rows to a multiple of K and split: (K, mk, d), mk."""
    m, d = x.shape
    mk = -(-m // K)
    x_pad = np.zeros((mk * K, d), np.float64)
    x_pad[:m] = x
    return x_pad.reshape(K, mk, d), mk


def setup(cfg: ALCCConfig, key: jax.Array, x, y, w0=None,
          dataset_encoder=None) -> ALCCState:
    """Encode the float dataset ONCE + precompute master-side context.

    Mirrors engine.setup minus quantization: rows are zero-padded to K·mk
    (zero rows contribute nothing to X̄ᵀ·anything, so padding never skews a
    gradient), split into K parts, and encoded with T fresh Gaussian masks
    drawn from the setup key.  Shares are shipped float32 (worker
    arithmetic is float32); the encode itself runs float64.
    """
    assert dataset_encoder is None, "sharded masters are exact-engine only"
    kx, _ = jax.random.split(key)
    x = np.asarray(x, np.float64)
    m, d = x.shape
    parts, mk = _pad_parts(cfg.K, x)
    masks = alcc.draw_masks(kx, cfg.T, (mk, d), cfg.sigma)
    x_shares = alcc.encode(cfg.scheme, parts, masks).astype(np.float32)
    y = np.asarray(y)
    y_pad = np.concatenate([y, np.zeros(mk * cfg.K - m, y.dtype)])
    targets = np.asarray(_exact._targets(cfg, jnp.asarray(y_pad)),
                         np.float64)                       # (m_padded, c)
    x_pad = parts.reshape(mk * cfg.K, d)
    xty = x_pad.T @ targets                                # (d, c) float64
    if w0 is None:
        w = jnp.zeros((d,) if cfg.c == 1 else (d, cfg.c), jnp.float32)
    else:
        w = jnp.asarray(w0, jnp.float32)
    return ALCCState(w=w, x_shares=x_shares, xty=xty, m=m, mk=mk,
                     xq_real=jnp.asarray(x_pad, jnp.float32),
                     xq_parts=parts, y=jnp.asarray(y_pad),
                     y_parts=targets.reshape(cfg.K, mk, cfg.c))


def poly_coeffs(cfg: ALCCConfig) -> np.ndarray:
    """The REAL sigmoid-surrogate coefficients ĝ workers evaluate —
    sigmoid_poly.fit_sigmoid's least-squares fit, unquantized (the same
    fit the exact engine rounds to the field)."""
    return np.asarray(sigmoid_poly.fit_sigmoid(cfg.r), np.float32)


def poly_eval(cbar, z):
    """Horner evaluation of the ascending-coefficient surrogate; works on
    numpy and jax arrays alike (shared by the sim path, the real worker's
    jitted fn, and the float oracle)."""
    out = z * 0 + cbar[-1]
    for c in cbar[-2::-1]:
        out = out * z + c
    return out


def worker_eval(cbar, xb, w):
    """The ALCC worker function: f(X̃, W̃) = X̃ᵀ ĝ(X̃ W̃), float32.

    Degree 2r+1 in the coded inputs jointly, hence the recovery threshold.
    Evaluated on coded shares by real workers (launch/cpml_worker.py, jitted)
    and by the vectorized sim path below — both float32, agreeing to within
    a few ulps (XLA and BLAS may sum a dot product in different orders).
    """
    return xb.T @ poly_eval(cbar, xb @ w)


# ---------------------------------------------------------------------------
# Decode + gradient step (master side, float64)
# ---------------------------------------------------------------------------

def survivor_round_info(cfg: ALCCConfig, surv
                        ) -> tuple[np.ndarray, np.ndarray, dict]:
    """Responder set -> (decode matrix (rows, K) float64, order, info).

    Unlike the exact scheme, the rows actually used depend on the
    conditioning: the square path consumes exactly ``threshold``
    responders, the ill-conditioned fallback consumes ALL of them
    (core/alcc.py).  ``order`` lists precisely the responders the decode
    will read, in arrival order.
    """
    surv = np.arange(cfg.N) if surv is None else np.asarray(surv)
    assert len(surv) >= cfg.threshold, (
        f"{len(surv)} survivors < recovery threshold {cfg.threshold}")
    dmat, info = cfg.scheme.decode_matrix(surv, 2 * cfg.r + 1)
    return dmat, surv[: info["rows"]].astype(np.int32), info


def survivor_round(cfg: ALCCConfig, surv) -> tuple[np.ndarray, np.ndarray]:
    """Signature-parity wrapper over survivor_round_info (engine.py's
    (dmat, order) contract)."""
    dmat, order, _ = survivor_round_info(cfg, surv)
    return dmat, order


def _batch_scale(cfg: ALCCConfig, state: ALCCState, eta: float,
                 batch_idx) -> tuple[np.ndarray, float]:
    """(X̄ᵀY over this round's rows, eta / real-row count) — the float
    twin of engine._gradient_step's normalization: padded rows are all
    zero and must not shrink the step."""
    if batch_idx is None:
        return state.xty, eta / state.m
    bidx = np.asarray(batch_idx)
    xqb = state.xq_parts[:, bidx]                    # (K, b, d)
    yb = state.y_parts[:, bidx]                      # (K, b, c)
    xty = np.einsum("kbd,kbc->dc", xqb, yb)
    part0 = np.arange(cfg.K)[:, None] * state.mk
    real = int(np.sum((bidx[None, :] + part0) < state.m))
    return xty, eta / max(real, 1)


def _decode_and_step(cfg: ALCCConfig, state: ALCCState, eta: float,
                     w2, fastest: np.ndarray, order: np.ndarray,
                     batch_idx, info_sink) -> jax.Array:
    """Least-squares decode of the responders' float results + GD step.

    fastest: (R, d, c) float32 evaluations in ``order``.  Decode runs in
    float64; the per-round info (cond / fallback / abs_err_budget /
    observed max |evaluation|) lands in ``info_sink`` for wait_stats.
    """
    xg, info = cfg.scheme.decode_sum(fastest, order, 2 * cfg.r + 1)
    xty, scale = _batch_scale(cfg, state, eta, batch_idx)
    w_new = np.asarray(w2, np.float64) - scale * (xg - xty)
    if info_sink is not None:
        info_sink.append(info)
    return jnp.asarray(w_new, jnp.float32)


def round_fn(cfg: ALCCConfig, state: ALCCState, eta: float,
             info_sink: list | None = None) -> Callable[..., jax.Array]:
    """Per-round hook, simulated compute: ``run(key, w2, order,
    batch_idx=None) -> w2``.

    Same role as engine.round_fn with the decode matrix replaced by the
    responder ORDER (the float decode resolves its own cached
    least-squares matrix, whose row count depends on conditioning).  The
    worker evaluations are computed here in float32 exactly as a real
    worker would, so sim and socket rounds agree to the last bit.
    """
    cbar = poly_coeffs(cfg)

    def run(key, w2, order, batch_idx=None) -> jax.Array:
        w_shares = encode_round_shares(cfg, key, w2)     # (N, d, c) f32
        order_np = np.asarray(order, np.int64)
        xb = (state.x_shares if batch_idx is None
              else state.x_shares[:, np.asarray(batch_idx)])
        xs = xb[order_np].astype(np.float32)             # (R, b, d)
        ws = w_shares[order_np]                          # (R, d, c)
        z = np.einsum("rbd,rdc->rbc", xs, ws).astype(np.float32)
        g = poly_eval(cbar, z).astype(np.float32)
        fastest = np.einsum("rbd,rbc->rdc", xs, g).astype(np.float32)
        return _decode_and_step(cfg, state, eta, w2, fastest, order_np,
                                batch_idx, info_sink)

    return run


def update_fn(cfg: ALCCConfig, state: ALCCState, eta: float,
              info_sink: list | None = None) -> Callable[..., jax.Array]:
    """Decode-and-update hook for results computed ELSEWHERE:
    ``run(w2, fastest, order, batch_idx=None) -> w2`` — fastest are the
    (R, d, c) float32 payloads of the responders in arrival order, e.g.
    received over the socket transport."""

    def run(w2, fastest, order, batch_idx=None) -> jax.Array:
        return _decode_and_step(cfg, state, eta, w2,
                                np.asarray(fastest, np.float32),
                                np.asarray(order, np.int64),
                                batch_idx, info_sink)

    return run


def round_fn_split(cfg, state, eta, info_sink=None):
    """Pipelined encode is exact-engine only (DESIGN.md §9 relies on the
    exact split of the field matmul); ALCC refuses at call time."""
    def run(*a, **k):
        raise RuntimeError("pipeline modes are exact-engine only")
    return run


def update_from_parts_fn(cfg, state, eta, info_sink=None):
    """Streaming decode is exact-engine only; ALCC refuses at call time."""
    def run(*a, **k):
        raise RuntimeError("streaming decode is exact-engine only")
    return run


def encode_round_shares(cfg: ALCCConfig, key, w2) -> np.ndarray:
    """Round-t weight shares (N, d, c) float32: W replicated at the K data
    betas + T FRESH Gaussian masks (fresh per round — reusing a mask
    across rounds would let two rounds' shares cancel the data out)."""
    masks = alcc.draw_masks(key, cfg.T, tuple(np.shape(w2)), cfg.sigma)
    return alcc.encode_replicated(
        cfg.scheme, np.asarray(w2, np.float64), masks).astype(np.float32)


# ---------------------------------------------------------------------------
# Training drivers: reference loop + uncoded float oracle
# ---------------------------------------------------------------------------

def train_reference(cfg: ALCCConfig, key, x, y, iters: int,
                    eta: float | None = None,
                    survivor_fn: Callable[[int], np.ndarray] | None = None,
                    eval_every: int = 0, info_sink: list | None = None):
    """Per-step reference loop over the same hooks (cf. engine.train_reference).

    Replaying a ClusterRunner responder trace through this reproduces the
    run's weights exactly — every float op (encode, worker eval, decode)
    is the same deterministic numpy/jax call on the same inputs.  Returns
    (w, history).
    """
    ksetup, kloop = jax.random.split(jnp.asarray(key))
    state = setup(cfg, ksetup, x, y)
    if eta is None:
        eta = lipschitz_eta(state.xq_real)
    run = round_fn(cfg, state, eta, info_sink=info_sink)
    w2 = _w_internal(cfg, state.w)
    history: list[dict[str, float]] = []
    for t in range(iters):
        surv = survivor_fn(t) if survivor_fn is not None else None
        _, order, _ = survivor_round_info(cfg, surv)
        bidx = (draw_batch(cfg, kloop, iters, state.mk, t)
                if cfg.batch_rows is not None else None)
        w2 = run(round_key(kloop, t), w2, order, bidx)
        if eval_every and (t + 1) % eval_every == 0:
            l, a = _eval_metrics(cfg, w2, state.xq_real[: state.m],
                                 state.y[: state.m])
            history.append({"iter": t + 1, "loss": float(l), "acc": float(a)})
    return _w_public(cfg, w2), history


def float_oracle(cfg: ALCCConfig, key, x, y, iters: int,
                 eta: float | None = None):
    """UNCODED float GD with the same surrogate + batch schedule.

    The convergence oracle for ALCC acceptance: identical model (ĝ from
    fit_sigmoid), identical per-round batches (same kloop stream),
    identical step sizes — the ONLY difference from a coded run is that
    gradients are computed directly instead of decoded, so
    |w_alcc - w_oracle| measures pure coding/decoding float error.
    """
    ksetup, kloop = jax.random.split(jnp.asarray(key))
    state = setup(cfg, ksetup, x, y)   # same padding/xty; coding unused
    if eta is None:
        eta = lipschitz_eta(state.xq_real)
    cbar = poly_coeffs(cfg)
    w2 = np.asarray(_w_internal(cfg, state.w), np.float64)
    for t in range(iters):
        bidx = (np.asarray(draw_batch(cfg, kloop, iters, state.mk, t))
                if cfg.batch_rows is not None else None)
        xqb = (state.xq_parts if bidx is None
               else state.xq_parts[:, bidx]).astype(np.float32)
        z = np.einsum("kbd,dc->kbc", xqb, w2.astype(np.float32))
        g = poly_eval(cbar, z.astype(np.float32)).astype(np.float32)
        xg = np.einsum("kbd,kbc->dc", xqb, g).astype(np.float64)
        xty, scale = _batch_scale(cfg, state, eta, bidx)
        w2 = w2 - scale * (xg - xty)
    return _w_public(cfg, jnp.asarray(w2, jnp.float32))


# ---------------------------------------------------------------------------
# MLP under ALCC: two degree-2 coded phases per step (DESIGN.md §14)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ALCCMLPState:
    """One gelu-MLP training run's master-side state."""
    w1: jax.Array               # (d, hidden) float32
    w2: jax.Array               # (hidden, c) float32
    x_shares: np.ndarray        # (N, mk, d) float32 coded dataset
    xq_parts: np.ndarray        # (K, mk, d) float64 plaintext parts
    y_parts: np.ndarray         # (K, mk, c) float64 one-hot targets
    xq_real: jax.Array          # (m_padded, d) float32 (metrics)
    y: jax.Array                # (m_padded,) labels
    m: int
    mk: int


def mlp_setup(cfg: ALCCConfig, key, x, y, hidden: int) -> ALCCMLPState:
    """Encode the dataset once + init the two dense layers.

    cfg.c must be >= 2 (softmax cross-entropy over c classes); cfg.r is
    unused — both coded phases are degree 2.
    """
    assert cfg.c >= 2, "the ALCC MLP trains a softmax head; need c >= 2"
    kx, kw1, kw2 = jax.random.split(key, 3)
    x = np.asarray(x, np.float64)
    m, d = x.shape
    parts, mk = _pad_parts(cfg.K, x)
    masks = alcc.draw_masks(kx, cfg.T, (mk, d), cfg.sigma)
    x_shares = alcc.encode(cfg.scheme, parts, masks).astype(np.float32)
    y = np.asarray(y)
    y_pad = np.concatenate([y, np.zeros(mk * cfg.K - m, y.dtype)])
    onehot = np.asarray(jax.nn.one_hot(y_pad.astype(np.int32), cfg.c),
                        np.float64)
    w1 = jax.random.normal(kw1, (d, hidden), jnp.float32) / np.sqrt(d)
    w2 = jax.random.normal(kw2, (hidden, cfg.c), jnp.float32) / np.sqrt(hidden)
    return ALCCMLPState(
        w1=w1, w2=w2, x_shares=x_shares, xq_parts=parts,
        y_parts=onehot.reshape(cfg.K, mk, cfg.c),
        xq_real=jnp.asarray(parts.reshape(mk * cfg.K, d), jnp.float32),
        y=jnp.asarray(y_pad), m=m, mk=mk)


def mlp_row_mask(cfg: ALCCConfig, state: ALCCMLPState, batch_idx
                 ) -> np.ndarray:
    """(K, b) 1.0 where part-k row batch_idx[j] is a REAL sample (global
    row k·mk + idx < m), 0.0 on the zero padding — the loss normalizer."""
    rows = (np.arange(state.mk) if batch_idx is None
            else np.asarray(batch_idx))
    part0 = np.arange(cfg.K)[:, None] * state.mk
    return ((rows[None, :] + part0) < state.m).astype(np.float32)


@functools.partial(jax.jit)
def _mlp_middle(z1, w2, yb, mask):
    """The in-the-clear middle of one MLP step, from decoded Z1 = X·W1.

    z1 (n, h), yb (n, c) one-hot, mask (n,) real-row indicator.  Returns
    (gw2, dz1, loss, acc) where dz1 is exactly the VJP of the masked
    softmax-CE loss of gelu(z1) @ w2 — the same chain jax.grad walks
    through layers.gelu_mlp, so stitching X̄ᵀ dz1 (phase B) onto it yields
    the oracle's W1 gradient up to decode noise.
    """
    h, vjp_gelu = jax.vjp(jax.nn.gelu, z1)
    logits = h @ w2
    n = jnp.maximum(mask.sum(), 1.0)
    p = jax.nn.softmax(logits)
    delta2 = (p - yb) * mask[:, None] / n
    gw2 = h.T @ delta2
    (dz1,) = vjp_gelu(delta2 @ w2.T)
    logp = jax.nn.log_softmax(logits)
    loss = -((yb * logp).sum(axis=-1) * mask).sum() / n
    acc = ((jnp.argmax(logits, axis=-1) == jnp.argmax(yb, axis=-1))
           * mask).sum() / n
    return gw2, dz1, loss, acc


def mlp_middle(cfg: ALCCConfig, state: ALCCMLPState, z1_parts, batch_idx):
    """Decoded forward activations -> (gw2, delta1 parts, metrics).

    z1_parts: (K, b, h) decoded per-part X̄_k[batch] @ W1.  The returned
    delta1 (K, b, h) is what phase B encodes (per-part values this time,
    like the dataset — NOT replicated) so the coded backward pass can
    read off sum_k X̄_kᵀ δ1_k.
    """
    K, b, h = np.shape(z1_parts)
    mask = mlp_row_mask(cfg, state, batch_idx).reshape(K * b)
    yb = (state.y_parts if batch_idx is None
          else state.y_parts[:, np.asarray(batch_idx)])
    gw2, dz1, loss, acc = _mlp_middle(
        jnp.asarray(np.reshape(z1_parts, (K * b, h)), jnp.float32),
        state.w2, jnp.asarray(yb.reshape(K * b, -1), jnp.float32),
        jnp.asarray(mask))
    return (gw2, np.asarray(dz1, np.float64).reshape(K, b, h),
            float(loss), float(acc))


def mlp_encode_forward(cfg: ALCCConfig, key, w1) -> np.ndarray:
    """Phase-A shares (N, d, h) float32: W1 replicated + fresh masks."""
    masks = alcc.draw_masks(key, cfg.T, tuple(np.shape(w1)), cfg.sigma)
    return alcc.encode_replicated(
        cfg.scheme, np.asarray(w1, np.float64), masks).astype(np.float32)


def mlp_encode_backward(cfg: ALCCConfig, key, delta1_parts) -> np.ndarray:
    """Phase-B shares (N, b, h) float32: the PER-PART deltas + fresh
    masks (data-style encode — each beta_k carries its own δ1_k)."""
    masks = alcc.draw_masks(key, cfg.T, tuple(np.shape(delta1_parts)[1:]),
                            cfg.sigma)
    return alcc.encode(cfg.scheme, np.asarray(delta1_parts, np.float64),
                       masks).astype(np.float32)


def mlp_worker_eval(phase: int, xb, share):
    """The ALCC MLP worker function, selected by round parity.

    phase 0 (round 2t):   X̃_i @ W̃1_i        -> (b, h)  coded forward
    phase 1 (round 2t+1): X̃_iᵀ @ δ̃1_i       -> (d, h)  coded backward
    Both are bilinear in coded inputs (degree 2) -> mlp_threshold.
    """
    return xb @ share if phase == 0 else xb.T @ share


def mlp_decode_forward(cfg: ALCCConfig, fastest, order):
    """(R, b, h) responses -> ((K, b, h) Z1 parts, info)."""
    return cfg.scheme.decode(np.asarray(fastest, np.float32), order, 2)


def mlp_decode_backward(cfg: ALCCConfig, fastest, order):
    """(R, d, h) responses -> ((d, h) summed W1 gradient, info)."""
    return cfg.scheme.decode_sum(np.asarray(fastest, np.float32), order, 2)


def mlp_oracle(cfg: ALCCConfig, key, x, y, hidden: int, iters: int,
               eta: float):
    """Plaintext jax.grad training of models/layers.gelu_mlp — identical
    init (same keys), batches and step sizes as the coded run; the gap to
    the coded weights is pure ALCC decode noise.  Returns (w1, w2)."""
    from repro.models import layers
    ksetup, kloop = jax.random.split(jnp.asarray(key))
    state = mlp_setup(cfg, ksetup, x, y, hidden)

    def loss_fn(w1, w2, xb, yb, mask):
        logits = layers.gelu_mlp(xb, w1, w2)
        logp = jax.nn.log_softmax(logits)
        n = jnp.maximum(mask.sum(), 1.0)
        return -((yb * logp).sum(axis=-1) * mask).sum() / n

    grad_fn = jax.jit(jax.grad(loss_fn, argnums=(0, 1)))
    w1, w2 = state.w1, state.w2
    for t in range(iters):
        bidx = (np.asarray(draw_batch(cfg, kloop, iters, state.mk, t))
                if cfg.batch_rows is not None else None)
        xqb = (state.xq_parts if bidx is None
               else state.xq_parts[:, bidx])
        yb = (state.y_parts if bidx is None else state.y_parts[:, bidx])
        K, b, d = xqb.shape
        mask = mlp_row_mask(cfg, state, bidx).reshape(K * b)
        g1, g2 = grad_fn(w1, w2,
                         jnp.asarray(xqb.reshape(K * b, d), jnp.float32),
                         jnp.asarray(yb.reshape(K * b, -1), jnp.float32),
                         jnp.asarray(mask))
        w1 = w1 - eta * g1
        w2 = w2 - eta * g2
    return w1, w2


def mlp_metrics(state: ALCCMLPState, w1, w2) -> tuple[float, float]:
    """Full-data loss/accuracy of (w1, w2) on the plaintext dataset."""
    from repro.models import layers
    x, y = state.xq_real[: state.m], state.y[: state.m]
    logits = layers.gelu_mlp(x, w1, w2)
    onehot = jax.nn.one_hot(y.astype(jnp.int32), logits.shape[-1])
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean((onehot * logp).sum(axis=-1))
    acc = jnp.mean(jnp.argmax(logits, axis=-1) == y.astype(jnp.int32))
    return float(loss), float(acc)
