"""CPMLConfig: all static parameters of one CodedPrivateML deployment.

The config is a frozen (hashable) dataclass so it can ride through
`jax.jit(static_argnums=...)` — every downstream stage (encode / compute /
decode / engine) specializes on it at trace time.

Beyond the paper's (N, K, T, r) and quantization scales this adds:
  * ``c``          — number of one-vs-all logistic heads (1 = the paper's
                     binary task).  All heads share the SAME coded dataset
                     shares, so encoding cost is amortized c-ways.
  * ``batch_rows`` — mini-batch SGD: rows-per-part drawn each round from the
                     once-encoded shares (row selection commutes with
                     Lagrange encoding, DESIGN.md §6).  None = full batch.
"""
from __future__ import annotations

import dataclasses

from repro.core import field, lagrange, sigmoid_poly


@dataclasses.dataclass(frozen=True)
class CPMLConfig:
    N: int                  # workers
    K: int                  # parallelization (dataset split)
    T: int                  # privacy threshold
    r: int = 1              # sigmoid polynomial degree
    c: int = 1              # one-vs-all heads (1 = binary logistic regression)
    lx: int = 2             # dataset quantization scale (paper §5)
    lw: int = 4             # weight quantization scale (paper §5)
    lc: int = 6             # sigmoid-coefficient scale (see sigmoid_poly.py)
    p: int = field.P
    backend: str = "vmap"   # "vmap" | "shard"
    mesh_axis: str = "workers"
    use_kernel: bool = False
    batch_rows: int | None = None   # rows per part per round (None = full)

    def __post_init__(self):
        need = lagrange.recovery_threshold(self.K, self.T, self.r)
        assert self.N >= need, (
            f"N={self.N} < recovery threshold {need} for "
            f"(K={self.K}, T={self.T}, r={self.r}); Theorem 1 violated")
        assert self.c >= 1
        assert self.batch_rows is None or self.batch_rows >= 1

    @property
    def threshold(self) -> int:
        return lagrange.recovery_threshold(self.K, self.T, self.r)

    @property
    def scheme(self) -> lagrange.CodingScheme:
        return lagrange.CodingScheme(self.N, self.K, self.T, self.p)

    @property
    def grad_scale(self) -> int:
        return sigmoid_poly.gradient_scale_poly(self.lx, self.lw, self.r,
                                                self.lc)

    def headroom_bits(self, x_max: float, m: int) -> float:
        """log2((p-1)/2) - log2(worst-case decoded magnitude).

        Negative => the decoded sub-gradient h(beta_k) can wrap around
        (paper §3.1's overflow error).  Worst case per part: sum over m/K
        samples of x̄ * ḡ at the aligned scale.  Use P30 / smaller lc / larger
        K when this goes negative (r=2 at the paper's 24-bit prime does).
        Mini-batching HELPS here: only batch_rows samples accumulate.
        """
        import math
        rows = m / self.K if self.batch_rows is None else self.batch_rows
        per_part = rows * (2 ** self.lx * max(x_max, 1e-9)) \
            * 2 ** (self.lc + self.r * (self.lx + self.lw))
        return math.log2((self.p - 1) / 2) - math.log2(per_part)
