"""Engine stage: training driver over the encode/compute/decode stages.

Algorithm 1, generalized three ways beyond the paper (DESIGN.md §6):

  * MULTI-CLASS — W is a (d, c) matrix of c one-vs-all logistic heads; the
    dataset is encoded once and every round's single worker pass serves all
    c heads (compute.py amortizes the X̃ read).
  * MINI-BATCH SGD — each round selects ``cfg.batch_rows`` rows of the
    once-encoded shares.  Row selection commutes with Lagrange encoding
    (encoding is elementwise-linear across the K parts), so a row-subset of
    X̃_i is a valid encoding of the same row-subset of every X̄_k: the paper's
    one-time-encoding property survives mini-batching.
  * FULLY-JITTED SCAN — train() runs ONE jitted jax.lax.scan over all
    iterations: per-round PRNG keys are pre-split, survivor patterns are a
    static schedule whose decode matrices are precomputed host-side and
    stacked, and batch indices are pre-drawn.  No host↔device round trip or
    re-trace per iteration.  ``train_reference`` is the per-step loop the
    scan must match bit-for-bit (tests/test_scan_engine.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize, sigmoid_poly
from repro.core.protocol import compute, decode, encode
from repro.core.protocol.config import CPMLConfig


# ---------------------------------------------------------------------------
# State + setup
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CPMLState:
    w: jax.Array            # real weights: (d,) when c == 1, else (d, c)
    x_shares: jax.Array     # (N, mk, d) coded dataset (encoded ONCE)
    xty: jax.Array          # real X̄ᵀY, full padded data: (d,) or (d, c)
    m: int                  # number of (unpadded) samples
    mk: int                 # rows per part (padded m / K)
    xq_real: jax.Array      # dequantized dataset (m_padded, d) — loss/oracle
    xq_parts: jax.Array     # the same, split (K, mk, d) — mini-batch xty
    y: jax.Array            # padded labels, original form (m_padded,)
    y_parts: jax.Array      # targets split (K, mk, c) real (one-hot if c>1)


def _targets(cfg: CPMLConfig, y: jax.Array) -> jax.Array:
    """(m,) labels -> (m, c) real regression targets for the c heads."""
    if cfg.c == 1:
        return y.astype(jnp.float32)[:, None]
    return jax.nn.one_hot(y.astype(jnp.int32), cfg.c, dtype=jnp.float32)


def setup(cfg: CPMLConfig, key: jax.Array, x: jax.Array, y: jax.Array,
          w0: jax.Array | None = None, dataset_encoder=None) -> CPMLState:
    """Encode the dataset + precompute all master-side cleartext context.

    y: (m,) float 0/1 labels when cfg.c == 1, integer class ids otherwise.
    ``dataset_encoder`` (same signature as encode.encode_dataset) lets a
    sharded master group own the encode (cluster/master_group.py) — it must
    be bit-identical to the default, which the group guarantees by drawing
    all randomness at full shape.
    """
    kx, _ = jax.random.split(key)
    encoder = dataset_encoder or encode.encode_dataset
    x_shares, ctx = encoder(cfg, kx, x)
    xq_real = quantize.dequantize(ctx["xq"], cfg.lx, cfg.p)
    m_padded = ctx["m_padded"]
    mk = m_padded // cfg.K
    y_pad = jnp.concatenate([y, jnp.zeros(m_padded - y.shape[0], y.dtype)])
    targets = _targets(cfg, y_pad)                       # (m_padded, c)
    xty = _w_public(cfg, xq_real.T @ targets)            # (d,) or (d, c)
    d = x.shape[1]
    if w0 is None:
        w = jnp.zeros((d,) if cfg.c == 1 else (d, cfg.c), jnp.float32)
    else:
        w = w0
    return CPMLState(
        w=w, x_shares=x_shares, xty=xty, m=x.shape[0], mk=mk,
        xq_real=xq_real, xq_parts=xq_real.reshape(cfg.K, mk, d),
        y=y_pad, y_parts=targets.reshape(cfg.K, mk, cfg.c))


def _w_internal(cfg: CPMLConfig, w: jax.Array) -> jax.Array:
    return w[:, None] if cfg.c == 1 and w.ndim == 1 else w


def _w_public(cfg: CPMLConfig, w2: jax.Array) -> jax.Array:
    return w2[:, 0] if cfg.c == 1 else w2


# ---------------------------------------------------------------------------
# One protocol round (shared verbatim by step(), train_reference(), and the
# scan body — this sharing is what makes scan-vs-loop bit-identity hold)
# ---------------------------------------------------------------------------

def _gradient_step(cfg: CPMLConfig, w2: jax.Array, xg: jax.Array,
                   xq_parts: jax.Array, y_parts: jax.Array,
                   xty_full: jax.Array, batch_idx: jax.Array | None,
                   eta: jax.Array, m_int: jax.Array) -> jax.Array:
    """Apply one gradient step given the decoded real gradient xg (d, c).

    Batch index i selects global sample k*mk + i from every part k; rows
    with k*mk + i >= m are all-zero padding, so the 1/batch normalization
    counts only the real rows — otherwise rounds touching the padded tail
    would take a systematically smaller step.
    """
    if batch_idx is None:
        xty = xty_full
        scale = eta / m_int.astype(jnp.float32)
    else:
        xqb = jnp.take(xq_parts, batch_idx, axis=1)      # (K, b, d)
        yb = jnp.take(y_parts, batch_idx, axis=1)        # (K, b, c)
        xty = jnp.einsum("kbd,kbc->dc", xqb, yb)
        mk = xq_parts.shape[1]
        part0 = jnp.arange(cfg.K, dtype=jnp.int32) * mk  # global row offsets
        real = jnp.sum((batch_idx[None, :] + part0[:, None]) < m_int)
        scale = eta / real.astype(jnp.float32)
    return w2 - scale * (xg - xty)


def _round_update(cfg: CPMLConfig, w2: jax.Array, fastest: jax.Array,
                  xq_parts: jax.Array, y_parts: jax.Array,
                  xty_full: jax.Array, dmat: jax.Array,
                  batch_idx: jax.Array | None, eta: jax.Array,
                  m_int: jax.Array) -> jax.Array:
    """Decode the survivors' results and apply the gradient step.

    fastest: (R, d, c) field evaluations in responder order — either sliced
    out of a master-side all_worker_results (the simulated paths, _round) or
    received over the wire from real worker processes (runner socket mode).
    Both paths flow through THIS function, so where the worker compute ran
    cannot change what the update computes.
    """
    xg = decode.decode_gradient(cfg, fastest, dmat)                # (d, c)
    return _gradient_step(cfg, w2, xg, xq_parts, y_parts, xty_full,
                          batch_idx, eta, m_int)


def _update_from_parts(cfg: CPMLConfig, w2: jax.Array, parts: jax.Array,
                       xq_parts: jax.Array, y_parts: jax.Array,
                       xty_full: jax.Array, batch_idx: jax.Array | None,
                       eta: jax.Array, m_int: jax.Array) -> jax.Array:
    """Gradient step from ALREADY-DECODED (K, d, c) field parts.

    The streaming-decode path (decode.StreamingDecoder folds shares on the
    host as they arrive) lands here: the parts are exact integers identical
    to decode_parts' output, and parts_to_gradient + _gradient_step are the
    same ops _round_update composes — so a streamed round stays
    bit-identical to the batch-decoded one (tests/test_pipeline.py).
    """
    xg = decode.parts_to_gradient(cfg, parts)
    return _gradient_step(cfg, w2, xg, xq_parts, y_parts, xty_full,
                          batch_idx, eta, m_int)


def _round_body(cfg: CPMLConfig, w_shares: jax.Array, w2: jax.Array,
                x_shares: jax.Array, xq_parts: jax.Array, y_parts: jax.Array,
                xty_full: jax.Array, dmat: jax.Array, order: jax.Array,
                batch_idx: jax.Array | None, eta: jax.Array,
                m_int: jax.Array) -> jax.Array:
    """compute -> decode -> step, given this round's encoded weight shares
    (shared verbatim by the one-key and split-encode round variants)."""
    cbar = jnp.asarray(poly_coeffs(cfg), jnp.int32)
    xb = (x_shares if batch_idx is None
          else jnp.take(x_shares, batch_idx, axis=1))    # (N, b, d): the
    # coded sub-batch is the SAME row subset of every share / part.
    results = compute.all_worker_results(cfg, cbar, xb, w_shares)  # (N, d, c)
    fastest = jnp.take(results, order, axis=0)                     # (R, d, c)
    return _round_update(cfg, w2, fastest, xq_parts, y_parts, xty_full,
                         dmat, batch_idx, eta, m_int)


def _round(cfg: CPMLConfig, key: jax.Array, w2: jax.Array,
           x_shares: jax.Array, xq_parts: jax.Array, y_parts: jax.Array,
           xty_full: jax.Array, dmat: jax.Array, order: jax.Array,
           batch_idx: jax.Array | None, eta: jax.Array, m_int: jax.Array
           ) -> jax.Array:
    """w2 (d, c) -> updated (d, c).  One full encode->compute->decode round
    with the N workers enacted on-device (vmap/shard, DESIGN.md §4)."""
    w_shares = encode.encode_weights(cfg, key, w2)       # (N, d, c, r)
    return _round_body(cfg, w_shares, w2, x_shares, xq_parts, y_parts,
                       xty_full, dmat, order, batch_idx, eta, m_int)


def _round_split(cfg: CPMLConfig, kq: jax.Array, mask_shares: jax.Array,
                 w2: jax.Array, x_shares: jax.Array, xq_parts: jax.Array,
                 y_parts: jax.Array, xty_full: jax.Array, dmat: jax.Array,
                 order: jax.Array, batch_idx: jax.Array | None,
                 eta: jax.Array, m_int: jax.Array) -> jax.Array:
    """_round with the W-independent encode half supplied from outside.

    (kq, mask_shares) come from ``round_mask_context`` — typically built by
    the pipeline prefetcher while the PREVIOUS round was in flight.  The
    encode split is exact, so this is bit-identical to _round on the same
    round key (tests/test_pipeline.py)."""
    w_shares = encode.encode_weights_finish(cfg, kq, mask_shares, w2)
    return _round_body(cfg, w_shares, w2, x_shares, xq_parts, y_parts,
                       xty_full, dmat, order, batch_idx, eta, m_int)


_round_jit = jax.jit(_round, static_argnums=(0,))
_round_split_jit = jax.jit(_round_split, static_argnums=(0,))
_round_update_jit = jax.jit(_round_update, static_argnums=(0,))
_update_from_parts_jit = jax.jit(_update_from_parts, static_argnums=(0,))
_encode_weights_jit = jax.jit(encode.encode_weights, static_argnums=(0,))
_weight_mask_jit = jax.jit(encode.weight_mask_shares, static_argnums=(0, 2))
_encode_finish_jit = jax.jit(encode.encode_weights_finish,
                             static_argnums=(0,))


def _scale_args(cfg: CPMLConfig, eta: float, state: CPMLState):
    """(eta, m) scalars for _round's gradient normalization."""
    return (jnp.float32(eta), jnp.int32(state.m))


def round_fn(cfg: CPMLConfig, state: CPMLState, eta: float
             ) -> Callable[..., jax.Array]:
    """Per-round hook: the EXACT round train()/train_reference() run.

    Returns ``run(key, w2, dmat, order, batch_idx=None) -> w2`` closing over
    the once-encoded dataset state.  External drivers (cluster/runner.py)
    that discover survivor patterns online call this with their observed
    decode matrix + responder order and stay bit-identical to the static
    schedule drivers replaying the same trace.
    """
    scale = _scale_args(cfg, eta, state)
    xty2 = _w_internal(cfg, state.xty)

    def run(key: jax.Array, w2: jax.Array, dmat: jax.Array, order: jax.Array,
            batch_idx: jax.Array | None = None) -> jax.Array:
        return _round_jit(cfg, key, w2, state.x_shares, state.xq_parts,
                          state.y_parts, xty2, dmat, order, batch_idx, *scale)

    return run


def round_fn_split(cfg: CPMLConfig, state: CPMLState, eta: float
                   ) -> Callable[..., jax.Array]:
    """round_fn with the W-independent encode half supplied by the caller.

    Returns ``run(kq, mask_shares, w2, dmat, order, batch_idx=None) -> w2``
    — the pipelined in-process round: (kq, mask_shares) come from
    ``round_mask_context`` built ahead of time, and the result is
    bit-identical to round_fn on the same round key.
    """
    scale = _scale_args(cfg, eta, state)
    xty2 = _w_internal(cfg, state.xty)

    def run(kq: jax.Array, mask_shares: jax.Array, w2: jax.Array,
            dmat: jax.Array, order: jax.Array,
            batch_idx: jax.Array | None = None) -> jax.Array:
        return _round_split_jit(cfg, kq, jnp.asarray(mask_shares), w2,
                                state.x_shares, state.xq_parts,
                                state.y_parts, xty2, dmat, order,
                                batch_idx, *scale)

    return run


def update_from_parts_fn(cfg: CPMLConfig, state: CPMLState, eta: float
                         ) -> Callable[..., jax.Array]:
    """Decode-and-update hook for STREAMED rounds (DESIGN.md §9).

    Returns ``run(w2, parts, batch_idx=None) -> w2`` where ``parts`` is the
    (K, d, c) field output of ``decode.StreamingDecoder.finish`` — the
    already-decoded sub-gradients.  parts_to_gradient + the shared
    _gradient_step make it bit-identical to update_fn on the equivalent
    (fastest, dmat) inputs.
    """
    scale = _scale_args(cfg, eta, state)
    xty2 = _w_internal(cfg, state.xty)

    def run(w2: jax.Array, parts: jax.Array,
            batch_idx: jax.Array | None = None) -> jax.Array:
        return _update_from_parts_jit(cfg, w2, jnp.asarray(parts, jnp.int32),
                                      state.xq_parts, state.y_parts, xty2,
                                      batch_idx, *scale)

    return run


def update_fn(cfg: CPMLConfig, state: CPMLState, eta: float
              ) -> Callable[..., jax.Array]:
    """Decode-and-update hook for drivers whose worker compute ran ELSEWHERE.

    Returns ``run(w2, fastest, dmat, batch_idx=None) -> w2`` where
    ``fastest`` is the (R, d, c) field results of the first ``threshold``
    responders in arrival order — e.g. deserialized from real worker
    processes over a socket transport.  It is the same ``_round_update``
    the in-process round composes, so a distributed round that feeds back
    bit-faithful worker results produces bit-identical weights.
    """
    scale = _scale_args(cfg, eta, state)
    xty2 = _w_internal(cfg, state.xty)

    def run(w2: jax.Array, fastest: jax.Array, dmat: jax.Array,
            batch_idx: jax.Array | None = None) -> jax.Array:
        return _round_update_jit(cfg, w2, fastest, state.xq_parts,
                                 state.y_parts, xty2, dmat, batch_idx, *scale)

    return run


def encode_round_shares(cfg: CPMLConfig, key: jax.Array, w2: jax.Array
                        ) -> jax.Array:
    """Round-t weight shares (N, d, c, r) for external dispatch.

    Same ``encode.encode_weights`` call ``_round`` traces with the same key
    — field elements are exact int32, so shares shipped to worker processes
    are bit-identical to the ones the in-process round would have used.
    """
    return _encode_weights_jit(cfg, key, w2)


def round_mask_context(cfg: CPMLConfig, key: jax.Array,
                       w_shape: tuple[int, ...]
                       ) -> tuple[jax.Array, jax.Array]:
    """W-INDEPENDENT half of round t's weight encode (DESIGN.md §9).

    Everything ``encode_round_shares(cfg, round_key(kloop, t), w2)`` does
    that does not need w2: the key split, the T fresh privacy masks, and
    their encoded contribution.  Returns ``(kq, mask_shares)``; feed them to
    ``encode_round_shares_split`` once the previous round's weights decode.
    Because it only needs (kloop, t, shape), a pipelined master computes it
    while round t-1 is still in flight.
    """
    return _weight_mask_jit(cfg, key, tuple(int(s) for s in w_shape))


def encode_round_shares_split(cfg: CPMLConfig, kq: jax.Array,
                              mask_shares: jax.Array, w2: jax.Array
                              ) -> jax.Array:
    """W-DEPENDENT half: bit-identical to ``encode_round_shares`` when
    (kq, mask_shares) came from ``round_mask_context`` on the same key."""
    return _encode_finish_jit(cfg, kq, jnp.asarray(mask_shares), w2)


def poly_coeffs(cfg: CPMLConfig) -> np.ndarray:
    """The quantized sigmoid-surrogate coefficients c̄ workers evaluate
    (one host-side derivation, shared by _round and worker provisioning)."""
    return np.asarray(
        sigmoid_poly.quantized_coeffs(cfg.r, cfg.lx, cfg.lw, cfg.lc, cfg.p),
        dtype=np.int32)


def step(cfg: CPMLConfig, key: jax.Array, state: CPMLState, eta: float,
         survivors: np.ndarray | None = None,
         batch_idx: jax.Array | None = None) -> CPMLState:
    """One master iteration.  survivors: indices of workers that responded
    (None = all N; only the fastest `threshold` are used, like the paper).
    batch_idx: (batch_rows,) row indices for this round's coded sub-batch
    (required iff cfg.batch_rows is set)."""
    surv = np.arange(cfg.N) if survivors is None else np.asarray(survivors)
    assert len(surv) >= cfg.threshold, "not enough survivors to decode"
    surv = surv[: cfg.threshold]
    dmat = decode.make_decode_matrix(cfg, surv)
    order = jnp.asarray(surv, jnp.int32)
    assert (batch_idx is not None) == (cfg.batch_rows is not None), \
        "batch_idx must be given exactly when cfg.batch_rows is set"
    w2 = _round_jit(cfg, key, _w_internal(cfg, state.w), state.x_shares,
                    state.xq_parts, state.y_parts, _w_internal(cfg, state.xty),
                    dmat, order, batch_idx, *_scale_args(cfg, eta, state))
    return dataclasses.replace(state, w=_w_public(cfg, w2))


# ---------------------------------------------------------------------------
# Static per-round schedule (keys / survivor decode matrices / batches)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Schedule:
    """Everything the scan needs per round, precomputed and stacked."""
    keys: jax.Array               # (iters, key) per-round weight-encode keys
    decode_mats: jax.Array        # (iters, R, K) int32 — survivor decode
    orders: jax.Array             # (iters, R) int32 — survivor indices
    batch_idx: jax.Array | None   # (iters, b) int32 or None (full batch)


def round_key(kloop: jax.Array, t: int) -> jax.Array:
    """Round t's weight-encode key — one derivation shared by the static
    schedule (make_schedule) and online drivers (cluster/runner.py)."""
    return jax.random.fold_in(kloop, t)


def draw_batch(cfg: CPMLConfig, kloop: jax.Array, iters: int, mk: int,
               t: int) -> jax.Array:
    """Round t's coded sub-batch indices (batch_rows,) int32.

    Keyed at ``iters + t`` so batch draws never collide with round_key's
    ``t`` stream.  Shared by make_schedule and online drivers so replaying
    a responder trace reproduces the identical batches bit-for-bit.
    """
    assert cfg.batch_rows is not None
    assert cfg.batch_rows <= mk, (
        f"batch_rows={cfg.batch_rows} exceeds the {mk} rows per "
        f"encoded part (padded m / K)")
    bkey = jax.random.fold_in(kloop, iters + t)
    return jax.random.choice(bkey, mk, (cfg.batch_rows,),
                             replace=False).astype(jnp.int32)


def survivor_round(cfg: CPMLConfig, surv: np.ndarray | None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Survivor indices -> (decode matrix (R, K), order (R,)) for one round."""
    surv = np.arange(cfg.N) if surv is None else np.asarray(surv)
    assert len(surv) >= cfg.threshold, (
        f"{len(surv)} survivors < recovery threshold {cfg.threshold}")
    surv = surv[: cfg.threshold]
    return (np.asarray(decode.make_decode_matrix(cfg, surv)),
            surv.astype(np.int32))


def make_schedule(cfg: CPMLConfig, kloop: jax.Array, iters: int, mk: int,
                  survivor_fn: Callable[[int], np.ndarray] | None = None
                  ) -> Schedule:
    keys = jax.vmap(lambda t: round_key(kloop, t))(jnp.arange(iters))
    dmats, orders = [], []
    for t in range(iters):
        surv = survivor_fn(t) if survivor_fn is not None else None
        try:
            dmat, order = survivor_round(cfg, surv)
        except AssertionError as e:
            raise AssertionError(f"round {t}: {e}") from None
        dmats.append(dmat)
        orders.append(order)
    batch_idx = None
    if cfg.batch_rows is not None:
        batch_idx = jnp.stack([draw_batch(cfg, kloop, iters, mk, t)
                               for t in range(iters)])
    return Schedule(keys=keys,
                    decode_mats=jnp.asarray(np.stack(dmats), jnp.int32),
                    orders=jnp.asarray(np.stack(orders), jnp.int32),
                    batch_idx=batch_idx)


# ---------------------------------------------------------------------------
# Training drivers: one jitted scan (production) + per-step reference loop
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 1))
def _train_scan(cfg: CPMLConfig, eval_every: int, w0: jax.Array,
                x_shares: jax.Array, xq_parts: jax.Array, y_parts: jax.Array,
                xty_full: jax.Array, keys: jax.Array, dmats: jax.Array,
                orders: jax.Array, batch_idx: jax.Array | None,
                eta: jax.Array, m_int: jax.Array,
                x_eval: jax.Array, y_eval: jax.Array):
    def body(w2, xs):
        t, key, dmat, order, bidx = xs
        w_new = _round(cfg, key, w2, x_shares, xq_parts, y_parts, xty_full,
                       dmat, order, bidx, eta, m_int)
        if eval_every:
            # full-data metrics only on the rounds train() will report
            metrics = jax.lax.cond(
                (t + 1) % eval_every == 0,
                lambda w: _eval_metrics(cfg, w, x_eval, y_eval),
                lambda w: (jnp.float32(0), jnp.float32(0)),
                w_new)
            return w_new, metrics
        return w_new, None

    ts = jnp.arange(keys.shape[0])
    return jax.lax.scan(body, w0, (ts, keys, dmats, orders, batch_idx))


def train(cfg: CPMLConfig, key: jax.Array, x: jax.Array, y: jax.Array,
          iters: int, eta: float | None = None,
          survivor_fn: Callable[[int], np.ndarray] | None = None,
          eval_every: int = 0) -> tuple[jax.Array, list[dict[str, float]]]:
    """Full Algorithm 1 as ONE jitted scan.  Returns (w, history)."""
    ksetup, kloop = jax.random.split(key)
    state = setup(cfg, ksetup, x, y)
    if eta is None:
        eta = lipschitz_eta(state.xq_real)
    sched = make_schedule(cfg, kloop, iters, state.mk, survivor_fn)
    w2, metrics = _train_scan(
        cfg, int(eval_every), _w_internal(cfg, state.w), state.x_shares,
        state.xq_parts, state.y_parts, _w_internal(cfg, state.xty), sched.keys,
        sched.decode_mats, sched.orders, sched.batch_idx,
        *_scale_args(cfg, eta, state),
        state.xq_real[: state.m], state.y[: state.m])
    history: list[dict[str, float]] = []
    if eval_every:
        losses, accs = metrics
        for t in range(eval_every - 1, iters, eval_every):
            history.append({"iter": t + 1, "loss": float(losses[t]),
                            "acc": float(accs[t])})
    return _w_public(cfg, w2), history


def train_reference(cfg: CPMLConfig, key: jax.Array, x: jax.Array,
                    y: jax.Array, iters: int, eta: float | None = None,
                    survivor_fn: Callable[[int], np.ndarray] | None = None,
                    eval_every: int = 0
                    ) -> tuple[jax.Array, list[dict[str, float]]]:
    """Per-step loop over the SAME schedule/round function as train().

    Exists as the bit-exactness oracle for the scan engine (and as the
    debuggable path: each round is a separate jit call you can inspect).
    """
    ksetup, kloop = jax.random.split(key)
    state = setup(cfg, ksetup, x, y)
    if eta is None:
        eta = lipschitz_eta(state.xq_real)
    sched = make_schedule(cfg, kloop, iters, state.mk, survivor_fn)
    run = round_fn(cfg, state, eta)
    w2 = _w_internal(cfg, state.w)
    history: list[dict[str, float]] = []
    for t in range(iters):
        bidx = None if sched.batch_idx is None else sched.batch_idx[t]
        w2 = run(sched.keys[t], w2, sched.decode_mats[t], sched.orders[t],
                 bidx)
        if eval_every and (t + 1) % eval_every == 0:
            l, a = _eval_metrics(cfg, w2, state.xq_real[: state.m],
                                 state.y[: state.m])
            history.append({"iter": t + 1, "loss": float(l), "acc": float(a)})
    return _w_public(cfg, w2), history


# ---------------------------------------------------------------------------
# Cleartext-side helpers: step size, metrics
# ---------------------------------------------------------------------------

def lipschitz_eta(xq_real: jax.Array) -> float:
    """eta = 1/L.  The cost (Eq. 1) carries a 1/m, so its Hessian is
    (1/m) X̄ᵀ S X̄ with S ⪯ I/4, giving L = max eig(X̄ᵀX̄)/(4m).
    (The paper's Lemma 2 states L = ||X̄||₂²/4, omitting the 1/m that its own
    Eq. (1) introduces — with that L the step size is m× too small to
    reproduce Fig. 3's 25-iteration accuracy.)  One-vs-all heads share the
    same X, hence the same L."""
    # power iteration — avoids O(d^3) eigendecomposition for large d.
    m, d = xq_real.shape
    v = jnp.ones((d,), jnp.float32) / np.sqrt(d)
    for _ in range(50):
        v = xq_real.T @ (xq_real @ v)
        v = v / (jnp.linalg.norm(v) + 1e-30)
    lam = v @ (xq_real.T @ (xq_real @ v))
    return float(4.0 * m / lam)


def sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def cleartext_baseline(cfg: CPMLConfig, x: jax.Array, y: jax.Array,
                       iters: int, eta: float | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Non-private GD on the quantized dataset with the TRUE sigmoid.

    The comparison baseline the paper's Fig. 3/4 plots against: same X̄ as
    the coded engine sees, no polynomial surrogate, no coding.  Returns
    (w, xq) with w shaped like train()'s output ((d,) when c == 1) and xq
    the dequantized dataset for metric evaluation.
    """
    xq = quantize.dequantize(quantize.quantize_data(x, cfg.lx, cfg.p),
                             cfg.lx, cfg.p)
    m = x.shape[0]
    if eta is None:
        eta = lipschitz_eta(xq)
    targets = _targets(cfg, y)                           # (m, c)
    w = jnp.zeros((x.shape[1], cfg.c))
    for _ in range(iters):
        w = w - eta * (xq.T @ (sigmoid(xq @ w) - targets)) / m
    return _w_public(cfg, w), xq


def loss_and_accuracy(w: jax.Array, x: jax.Array, y: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Binary logistic loss + accuracy (w (d,), y (m,) in {0,1})."""
    z = x @ w
    yhat = sigmoid(z)
    eps = 1e-7
    loss = -jnp.mean(y * jnp.log(yhat + eps) + (1 - y) * jnp.log(1 - yhat + eps))
    acc = jnp.mean((yhat > 0.5) == (y > 0.5))
    return loss, acc


def multiclass_loss_and_accuracy(w: jax.Array, x: jax.Array, labels: jax.Array
                                 ) -> tuple[jax.Array, jax.Array]:
    """One-vs-all logistic loss (mean over heads) + argmax accuracy.

    w (d, c), labels (m,) integer class ids.
    """
    z = x @ w                                            # (m, c)
    yhat = sigmoid(z)
    onehot = jax.nn.one_hot(labels.astype(jnp.int32), w.shape[1],
                            dtype=jnp.float32)
    eps = 1e-7
    loss = -jnp.mean(onehot * jnp.log(yhat + eps)
                     + (1 - onehot) * jnp.log(1 - yhat + eps))
    acc = jnp.mean(jnp.argmax(z, axis=1) == labels.astype(jnp.int32))
    return loss, acc


def per_class_accuracy(w: jax.Array, x: jax.Array, labels: jax.Array
                       ) -> jax.Array:
    """(c,) recall per class under the argmax decision rule."""
    pred = jnp.argmax(x @ w, axis=1)
    labels = labels.astype(jnp.int32)
    c = w.shape[1]
    hit = jnp.zeros((c,)).at[labels].add(pred == labels)
    cnt = jnp.zeros((c,)).at[labels].add(1.0)
    return hit / jnp.maximum(cnt, 1.0)


def _eval_metrics(cfg: CPMLConfig, w2: jax.Array, x: jax.Array,
                  y: jax.Array) -> tuple[jax.Array, jax.Array]:
    if cfg.c == 1:
        return loss_and_accuracy(w2[:, 0], x, y)
    return multiclass_loss_and_accuracy(w2, x, y)
