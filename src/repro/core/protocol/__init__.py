"""CodedPrivateML layered protocol engine (paper Algorithm 1).

The protocol is a pipeline of four composable stages, one module each:

  encode.py   quantize -> Lagrange-encode (dataset once, weights per round)
  compute.py  worker polynomial f (Eq. 20); backends: vmap / shard / kernel
  decode.py   survivor pattern -> cached decode matrix -> dequantize
  engine.py   training drivers: scan-jitted train(), per-step reference,
              multi-class one-vs-all heads, coded mini-batch SGD
  config.py   the static CPMLConfig every stage specializes on

This package re-exports the full public API, so ``from repro.core import
protocol`` keeps working exactly as it did when protocol was one module.
See DESIGN.md §4-§6 for the stage contracts and backend matrix.
"""
from repro.core.protocol.config import CPMLConfig
from repro.core.protocol.encode import (
    encode_dataset,
    encode_weights,
    encode_weights_finish,
    pad_rows,
    weight_mask_shares,
)
from repro.core.protocol.compute import (
    all_worker_results,
    worker_fn,
)
from repro.core.protocol.decode import (
    DecodePlan,
    StreamingDecoder,
    decode_gradient,
    decode_parts,
    make_decode_matrix,
    parts_to_gradient,
    prefix_decode_plan,
)
from repro.core.protocol.engine import (
    CPMLState,
    Schedule,
    cleartext_baseline,
    draw_batch,
    encode_round_shares,
    encode_round_shares_split,
    lipschitz_eta,
    loss_and_accuracy,
    make_schedule,
    multiclass_loss_and_accuracy,
    per_class_accuracy,
    poly_coeffs,
    round_fn,
    round_fn_split,
    round_key,
    round_mask_context,
    setup,
    sigmoid,
    step,
    survivor_round,
    train,
    train_reference,
    update_fn,
    update_from_parts_fn,
)

__all__ = [
    "CPMLConfig",
    "CPMLState",
    "DecodePlan",
    "Schedule",
    "StreamingDecoder",
    "all_worker_results",
    "cleartext_baseline",
    "decode_gradient",
    "decode_parts",
    "draw_batch",
    "encode_dataset",
    "encode_round_shares",
    "encode_round_shares_split",
    "encode_weights",
    "encode_weights_finish",
    "lipschitz_eta",
    "loss_and_accuracy",
    "make_decode_matrix",
    "make_schedule",
    "multiclass_loss_and_accuracy",
    "pad_rows",
    "parts_to_gradient",
    "per_class_accuracy",
    "poly_coeffs",
    "prefix_decode_plan",
    "round_fn",
    "round_fn_split",
    "round_key",
    "round_mask_context",
    "setup",
    "sigmoid",
    "step",
    "survivor_round",
    "train",
    "train_reference",
    "update_fn",
    "update_from_parts_fn",
    "weight_mask_shares",
    "worker_fn",
]
