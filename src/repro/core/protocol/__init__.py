"""CodedPrivateML layered protocol engine (paper Algorithm 1).

The protocol is a pipeline of four composable stages, one module each:

  encode.py   quantize -> Lagrange-encode (dataset once, weights per round)
  compute.py  worker polynomial f (Eq. 20); backends: vmap / shard / kernel
  decode.py   survivor pattern -> cached decode matrix -> dequantize
  engine.py   training drivers: scan-jitted train(), per-step reference,
              multi-class one-vs-all heads, coded mini-batch SGD
  config.py   the static CPMLConfig every stage specializes on

This package re-exports the full public API, so ``from repro.core import
protocol`` keeps working exactly as it did when protocol was one module.
See DESIGN.md §4-§6 for the stage contracts and backend matrix.

Stage-interface hooks (the surface `cluster/runner.py` drives)
--------------------------------------------------------------

The cluster runtime never computes; a coded-arithmetic BACKEND is any
module exposing these hooks (registered in ``ClusterRunner.ENGINES``),
and ``alcc_engine.py`` in this package implements the same surface over
real-valued ALCC coding (DESIGN.md §14):

  setup(cfg, key, x, y) -> State
      one-time master-side preparation: pad/quantize (exact) or
      real-normalize (alcc) the dataset, encode it into per-worker
      shares (``State.x_shares``), precompute X^T y.
  encode_round_shares(cfg, key, w2) -> (N, d, c) shares
      round-t weight broadcast: the current weights encoded with FRESH
      masks drawn from the (kloop, t) round key — replayable from the
      key alone, which is what makes ``train_reference`` possible.
  round_fn(cfg, state, eta, ...) -> run(key, w2, survivors..., bidx)
      one full simulated round (encode -> worker compute -> decode ->
      SGD step) as a jit-friendly closure; the sim backend's unit of
      bit-exact replay.
  update_fn(cfg, state, eta, ...) -> update(w2, results..., bidx)
      the decode + step half only, for the socket backend where worker
      results arrive as real bytes instead of being computed in-process.
  round_fn_split / update_from_parts_fn
      the §9 pipelined variants (mask-row prefetch, streaming decode);
      exact-engine only — the alcc module's stubs refuse at call time.
  survivor_round(cfg, survivors) / survivor_round_info(...)
      responder trace -> whatever the decode needs (exact: an int32
      decode matrix; alcc: the responder ORDER plus a conditioning info
      dict — float decode matrices must not ride the int32 plumbing).

Engines differ in ARITHMETIC, not shape: the runner moves opaque
payloads between the same hooks, so `--engine {exact,alcc}` is a pure
backend swap (per-backend guarantees in README's backend matrix).
"""
from repro.core.protocol.config import CPMLConfig
from repro.core.protocol.encode import (
    encode_dataset,
    encode_weights,
    encode_weights_finish,
    pad_rows,
    weight_mask_shares,
)
from repro.core.protocol.compute import (
    all_worker_results,
    worker_fn,
)
from repro.core.protocol.decode import (
    DecodePlan,
    StreamingDecoder,
    decode_gradient,
    decode_parts,
    make_decode_matrix,
    parts_to_gradient,
    prefix_decode_plan,
)
from repro.core.protocol.engine import (
    CPMLState,
    Schedule,
    cleartext_baseline,
    draw_batch,
    encode_round_shares,
    encode_round_shares_split,
    lipschitz_eta,
    loss_and_accuracy,
    make_schedule,
    multiclass_loss_and_accuracy,
    per_class_accuracy,
    poly_coeffs,
    round_fn,
    round_fn_split,
    round_key,
    round_mask_context,
    setup,
    sigmoid,
    step,
    survivor_round,
    train,
    train_reference,
    update_fn,
    update_from_parts_fn,
)

__all__ = [
    "CPMLConfig",
    "CPMLState",
    "DecodePlan",
    "Schedule",
    "StreamingDecoder",
    "all_worker_results",
    "cleartext_baseline",
    "decode_gradient",
    "decode_parts",
    "draw_batch",
    "encode_dataset",
    "encode_round_shares",
    "encode_round_shares_split",
    "encode_weights",
    "encode_weights_finish",
    "lipschitz_eta",
    "loss_and_accuracy",
    "make_decode_matrix",
    "make_schedule",
    "multiclass_loss_and_accuracy",
    "pad_rows",
    "parts_to_gradient",
    "per_class_accuracy",
    "poly_coeffs",
    "prefix_decode_plan",
    "round_fn",
    "round_fn_split",
    "round_key",
    "round_mask_context",
    "setup",
    "sigmoid",
    "step",
    "survivor_round",
    "train",
    "train_reference",
    "update_fn",
    "update_from_parts_fn",
    "weight_mask_shares",
    "worker_fn",
]
