"""Compute stage: the worker-side polynomial f (paper Eq. 20), per backend.

f(X̃, W̃) = X̃ᵀ ḡ(X̃, W̃) over F_p — degree (2r+1) in the encoding variable,
so any (2r+1)(K+T-1)+1 surviving workers decode (Thm. 1).  The multi-head
generalization stacks c one-vs-all polynomials over the SAME share:
W̃ (d, c, r) -> result (d, c); the dominant X̃ read is amortized across heads.

Backend matrix (DESIGN.md §4):
  * "vmap"     — all N workers simulated on one device (tests/benchmarks).
  * "shard"    — shard_map over a mesh axis: one coded share per device,
                 zero collectives in the worker step (the paper's key
                 property), one all_gather for "send results to master".
  * use_kernel — routes the per-worker computation through the fused Pallas
                 kernel (kernels/coded_grad.py) on EITHER backend.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.core.protocol.config import CPMLConfig


def worker_fn(cfg: CPMLConfig, cbar: jax.Array
              ) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """f(X̃, W̃) for ONE worker. (mk, d), (d, c, r) -> (d, c).

    Legacy binary shape (d, r) is also accepted and returns (d,) — the
    pre-multi-class contract, still used by benchmarks/phases.py.
    """

    def f(x_share: jax.Array, w_share: jax.Array) -> jax.Array:
        if w_share.ndim == 2:
            return f(x_share, w_share[:, None, :])[:, 0]
        c = w_share.shape[1]
        if cfg.use_kernel:
            from repro.kernels import ops as kernel_ops
            if c == 1:
                return kernel_ops.coded_grad(
                    x_share, w_share[:, 0, :], cbar, cfg.p)[:, None]
            return kernel_ops.coded_grad_mc(x_share, w_share, cbar, cfg.p)
        # the unfused jnp path IS the kernel oracle (itself pinned to a
        # python-int ground truth in test_kernels.py)
        from repro.kernels import ref
        return ref.coded_grad_mc_ref(x_share, w_share, cbar, cfg.p)

    return f


def all_worker_results(cfg: CPMLConfig, cbar: jax.Array, x_shares: jax.Array,
                       w_shares: jax.Array) -> jax.Array:
    """(N, mk, d) x (N, d, c, r) -> (N, d, c) worker results."""
    f = worker_fn(cfg, cbar)
    if cfg.backend == "vmap":
        return jax.vmap(f)(x_shares, w_shares)
    elif cfg.backend == "shard":
        from repro.parallel import compat
        mesh = compat.ambient_mesh()  # inside with-mesh / set_mesh context
        axis = cfg.mesh_axis

        def shard_body(xs, ws):
            res = f(xs[0], ws[0])[None]
            # "send result back to the master": one collective, results
            # replicated so the (replicated) decode can run everywhere.
            return jax.lax.all_gather(res, axis, axis=0, tiled=True)

        from jax.sharding import PartitionSpec as Pspec
        # check=False: the all_gather makes the output replicated, but the
        # static replication check cannot infer that.
        return compat.shard_map(shard_body, mesh,
                                (Pspec(axis), Pspec(axis)),
                                Pspec())(x_shares, w_shares)
    raise ValueError(cfg.backend)
