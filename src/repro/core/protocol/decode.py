"""Decode stage: survivor pattern -> decode matrix -> field decode -> real.

Straggler tolerance as erasure decoding (DESIGN.md §3): results arrive as an
(N, d, c) array + a survivor index list; the decode matrix for the survivor
set is built host-side (static per pattern, cacheable across rounds) and
applied as one field matmul — the semantics of "wait for the fastest R
workers" with zero recomputation.

STREAMING decode (DESIGN.md §9): the batch matmul only starts after the
threshold-th arrival, so the whole K x R fold sits on the critical path
after the last needed share.  ``StreamingDecoder`` folds each share into
the Lagrange reconstruction AS IT ARRIVES against a predicted responder
order (``prefix_decode_plan``): when arrivals match the prediction, the
work remaining after the last needed share is ONE fold, not R.  A miss
falls back to the batch decode over the observed order — every path is
exact integer arithmetic mod p, so streamed, fallback, and device-matmul
decodes are bit-identical.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field, quantize
from repro.core.protocol.config import CPMLConfig


def make_decode_matrix(cfg: CPMLConfig, survivors: np.ndarray) -> jax.Array:
    surv = np.asarray(survivors)[: cfg.threshold]
    return jnp.asarray(_cached_decode_matrix(cfg.scheme, tuple(int(i) for i in surv)),
                       jnp.int32)


@functools.lru_cache(maxsize=512)
def _cached_decode_matrix(scheme, survivors: tuple[int, ...]) -> np.ndarray:
    """Host Lagrange-coefficient solve, cached per (scheme, pattern).

    Training loops reuse a handful of survivor patterns across thousands of
    rounds; the O(R^2 K) host solve runs once per pattern.
    """
    return scheme.decode_matrix(np.asarray(survivors))


def decode_parts(cfg: CPMLConfig, results: jax.Array,
                 decode_mat: jax.Array) -> jax.Array:
    """Recover the K per-part field results h(beta_k) from survivors.

    results: (R, d, c) field evaluations h(alpha_i) in survivor order.
    Returns (K, d, c) field elements — EXACTLY X̄_kᵀ ḡ(X̄_k, W̄) mod p.
    """
    flat = results.reshape(results.shape[0], -1)
    out = field.matmul(decode_mat.T, flat, cfg.p)          # (K, d*c)
    return out.reshape(cfg.K, *results.shape[1:])


def parts_to_gradient(cfg: CPMLConfig, parts: jax.Array) -> jax.Array:
    """(K, d, c) decoded field parts -> real (d, c) gradient.

    Shared by the batch path (decode_gradient) and the streaming path
    (engine update_from_parts hook), so both dequantize-and-sum with the
    exact same op sequence — the float side of streamed-vs-batch
    bit-identity.
    """
    return quantize.dequantize(parts, cfg.grad_scale, cfg.p).sum(axis=0)


def decode_gradient(cfg: CPMLConfig, results: jax.Array,
                    decode_mat: jax.Array) -> jax.Array:
    """Decode the K sub-gradients h(beta_k) and sum them IN THE REAL DOMAIN.

    The paper sums in the field (Eq. 23); summing after per-part
    dequantization is numerically identical when nothing wraps, and buys
    log2(K) bits of wrap-around headroom per part — each h(beta_k) only
    accumulates m/K samples.  results: (R, d, c) -> real (d, c).
    """
    return parts_to_gradient(cfg, decode_parts(cfg, results, decode_mat))


# ---------------------------------------------------------------------------
# Streaming threshold decode (DESIGN.md §9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """Decode-coefficient structure for one PREDICTED responder subset.

    ``cols[w]`` is worker w's (K,) Lagrange coefficient column for the
    predicted first-`threshold` responder SET.  The column depends only on
    (set, w) — never on arrival order — and the decoded parts are
    order-invariant too (permuting survivors permutes D's rows and the
    result rows consistently; exact mod-p sums commute).  So the streaming
    fold hits whenever the observed threshold SET matches the prediction,
    in ANY arrival order — the stable quantity under persistent stragglers.
    Built (plus plausible one-displacement variants, cache-warmed) by
    ``prefix_decode_plan`` ahead of the round, off the critical path.
    """
    subset: frozenset[int]          # predicted first-`threshold` responders
    cols: dict[int, np.ndarray]     # worker -> (K,) int64 coefficients


def prefix_decode_plan(cfg: CPMLConfig, predicted: np.ndarray | None
                       ) -> DecodePlan | None:
    """Precompute decode coefficients for a predicted responder prefix.

    ``predicted`` is any observed/forecast arrival order with at least
    ``threshold`` entries (shorter predictions yield no plan).  Besides the
    predicted threshold prefix itself, the host decode-matrix cache is
    warmed for every plausible NEAR-MISS subset prefix: each single
    displacement where one predicted responder is late and the next
    predicted worker slides into the threshold set — so even a fallback
    decode usually finds its coefficients precomputed.
    """
    if predicted is None:
        return None
    pred = [int(w) for w in np.asarray(predicted).ravel()]
    R = cfg.threshold
    if len(pred) < R:
        return None
    prefix = tuple(pred[:R])
    dmat = np.asarray(_cached_decode_matrix(cfg.scheme, prefix), np.int64)
    if len(pred) > R:
        nxt = pred[R]
        for i in range(R):                   # one-displacement variants
            variant = prefix[:i] + prefix[i + 1:] + (nxt,)
            _cached_decode_matrix(cfg.scheme, variant)
    return DecodePlan(subset=frozenset(prefix),
                      cols={w: dmat[i] for i, w in enumerate(prefix)})


class StreamingDecoder:
    """Fold survivor shares into the Lagrange reconstruction as they arrive.

    Host-side exact integer arithmetic mod p (int64 never overflows: each
    coefficient-share product is < p^2 < 2^60 and the accumulator is
    reduced after every fold).  With a plan whose predicted SUBSET matches
    the observed threshold responders (any arrival order), the decode
    remaining after the threshold-th share lands is ONE fold; on a miss
    (or with no plan) ``finish`` batch-decodes the retained shares over
    the observed order.  All paths produce the same bits as
    ``decode_parts`` on device.
    """

    def __init__(self, cfg: CPMLConfig, plan: DecodePlan | None = None):
        self.cfg = cfg
        self.plan = plan
        self._R = cfg.threshold
        self._shares: dict[int, np.ndarray] = {}   # worker -> (d, c) field
        self._arrived: list[int] = []              # accepted arrival order
        self._acc: np.ndarray | None = None        # (K, d*c) int64 mod p
        self._on_plan = plan is not None
        self.streamed = False                      # set by finish()

    def fold(self, worker: int, result) -> None:
        """Ingest one accepted arrival (in order).  O(K * d * c) when it
        belongs to the predicted subset; O(d * c) bookkeeping otherwise."""
        worker = int(worker)
        h = np.asarray(result, dtype=np.int32)
        pos = len(self._arrived)
        self._arrived.append(worker)
        self._shares[worker] = h
        if pos >= self._R:
            return                                  # beyond the threshold
        if not (self._on_plan and worker in self.plan.cols):
            self._on_plan = False                   # off-subset arrival in
            return                                  # the threshold prefix
        col = self.plan.cols[worker]                # (K,) int64 < p
        prod = col[:, None] * h.reshape(-1).astype(np.int64)    # < p^2
        if self._acc is None:
            self._acc = prod % self.cfg.p
        else:
            self._acc = (self._acc + prod) % self.cfg.p

    def finish(self, order: np.ndarray) -> np.ndarray:
        """Decoded (K, d, c) field parts for the OBSERVED first-threshold
        responder ``order`` — streamed accumulator on a subset-prediction
        hit (any arrival order), batch fallback otherwise."""
        order_t = tuple(int(w) for w in np.asarray(order).ravel())[: self._R]
        assert len(order_t) == self._R, (
            f"{len(order_t)} responders < threshold {self._R}")
        shape = next(iter(self._shares.values())).shape
        if (self._on_plan and self._acc is not None
                and frozenset(self._arrived[: self._R]) == self.plan.subset
                and frozenset(order_t) == self.plan.subset):
            self.streamed = True
            return self._acc.reshape(self.cfg.K, *shape).astype(np.int32)
        dmat = np.asarray(_cached_decode_matrix(self.cfg.scheme, order_t),
                          np.int64)                  # (R, K)
        acc = np.zeros((self.cfg.K, int(np.prod(shape))), np.int64)
        for i, w in enumerate(order_t):             # reduce after each fold:
            h = self._shares[w].reshape(-1).astype(np.int64)
            acc = (acc + dmat[i][:, None] * h) % self.cfg.p
        self.streamed = False
        return acc.reshape(self.cfg.K, *shape).astype(np.int32)
