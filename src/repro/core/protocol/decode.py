"""Decode stage: survivor pattern -> decode matrix -> field decode -> real.

Straggler tolerance as erasure decoding (DESIGN.md §3): results arrive as an
(N, d, c) array + a survivor index list; the decode matrix for the survivor
set is built host-side (static per pattern, cacheable across rounds) and
applied as one field matmul — the semantics of "wait for the fastest R
workers" with zero recomputation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field, quantize
from repro.core.protocol.config import CPMLConfig


def make_decode_matrix(cfg: CPMLConfig, survivors: np.ndarray) -> jax.Array:
    surv = np.asarray(survivors)[: cfg.threshold]
    return jnp.asarray(_cached_decode_matrix(cfg.scheme, tuple(int(i) for i in surv)),
                       jnp.int32)


@functools.lru_cache(maxsize=512)
def _cached_decode_matrix(scheme, survivors: tuple[int, ...]) -> np.ndarray:
    """Host Lagrange-coefficient solve, cached per (scheme, pattern).

    Training loops reuse a handful of survivor patterns across thousands of
    rounds; the O(R^2 K) host solve runs once per pattern.
    """
    return scheme.decode_matrix(np.asarray(survivors))


def decode_parts(cfg: CPMLConfig, results: jax.Array,
                 decode_mat: jax.Array) -> jax.Array:
    """Recover the K per-part field results h(beta_k) from survivors.

    results: (R, d, c) field evaluations h(alpha_i) in survivor order.
    Returns (K, d, c) field elements — EXACTLY X̄_kᵀ ḡ(X̄_k, W̄) mod p.
    """
    flat = results.reshape(results.shape[0], -1)
    out = field.matmul(decode_mat.T, flat, cfg.p)          # (K, d*c)
    return out.reshape(cfg.K, *results.shape[1:])


def decode_gradient(cfg: CPMLConfig, results: jax.Array,
                    decode_mat: jax.Array) -> jax.Array:
    """Decode the K sub-gradients h(beta_k) and sum them IN THE REAL DOMAIN.

    The paper sums in the field (Eq. 23); summing after per-part
    dequantization is numerically identical when nothing wraps, and buys
    log2(K) bits of wrap-around headroom per part — each h(beta_k) only
    accumulates m/K samples.  results: (R, d, c) -> real (d, c).
    """
    out = decode_parts(cfg, results, decode_mat)
    return quantize.dequantize(out, cfg.grad_scale, cfg.p).sum(axis=0)
