"""Encode stage: quantize + Lagrange-encode datasets and weights.

Algorithm 1 lines 1-3.  The dataset is encoded ONCE (the paper's one-time
encoding property); weights are re-encoded every round because W changes.
Both are shape-generic: weights may be (d,) binary vectors or (d, c)
one-vs-all matrices — quantization, masking and encoding all act
elementwise/linearly, so the c heads ride through a single encode.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lagrange, quantize
from repro.core.protocol.config import CPMLConfig


def pad_rows(x: jax.Array, K: int) -> jax.Array:
    m = x.shape[0]
    pad = (-m) % K
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
    return x


def encode_dataset(cfg: CPMLConfig, key: jax.Array, x: jax.Array
                   ) -> tuple[jax.Array, dict[str, Any]]:
    """Returns shares (N, m/K, d) + master-side cleartext context."""
    xq = quantize.quantize_data(x, cfg.lx, cfg.p)          # (m, d) field
    xq = pad_rows(xq, cfg.K)
    mk = xq.shape[0] // cfg.K
    parts = xq.reshape(cfg.K, mk, xq.shape[-1])
    masks = lagrange.draw_masks(key, cfg.T, parts.shape[1:], cfg.p)
    shares = lagrange.encode(cfg.scheme, parts, masks, cfg.p)
    ctx = {"xq": xq, "m_padded": xq.shape[0]}
    return shares, ctx


def encode_weights(cfg: CPMLConfig, key: jax.Array, w: jax.Array) -> jax.Array:
    """Quantize w (Eq. 9-10) and Lagrange-encode W̄ (Eq. 13-14).

    w: (d,) or (d, c) real weights.  Returns shares (N, *w.shape, r).
    Note v(beta_i) = W̄ for ALL i <= K (the paper repeats the same W̄ at every
    data interpolation point), with fresh random masks V each round.
    """
    kq, km = jax.random.split(key)
    wbar = quantize.quantize_weights(kq, w, cfg.lw, cfg.r, cfg.p)
    parts = jnp.broadcast_to(wbar[None], (cfg.K, *wbar.shape))
    masks = lagrange.draw_masks(km, cfg.T, wbar.shape, cfg.p)
    return lagrange.encode(cfg.scheme, parts, masks, cfg.p)
