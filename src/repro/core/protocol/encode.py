"""Encode stage: quantize + Lagrange-encode datasets and weights.

Algorithm 1 lines 1-3.  The dataset is encoded ONCE (the paper's one-time
encoding property); weights are re-encoded every round because W changes.
Both are shape-generic: weights may be (d,) binary vectors or (d, c)
one-vs-all matrices — quantization, masking and encoding all act
elementwise/linearly, so the c heads ride through a single encode.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import field, lagrange, quantize
from repro.core.protocol.config import CPMLConfig


def pad_rows(x: jax.Array, K: int) -> jax.Array:
    m = x.shape[0]
    pad = (-m) % K
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
    return x


def encode_dataset(cfg: CPMLConfig, key: jax.Array, x: jax.Array
                   ) -> tuple[jax.Array, dict[str, Any]]:
    """Returns shares (N, m/K, d) + master-side cleartext context."""
    xq = quantize.quantize_data(x, cfg.lx, cfg.p)          # (m, d) field
    xq = pad_rows(xq, cfg.K)
    mk = xq.shape[0] // cfg.K
    parts = xq.reshape(cfg.K, mk, xq.shape[-1])
    masks = lagrange.draw_masks(key, cfg.T, parts.shape[1:], cfg.p)
    shares = lagrange.encode(cfg.scheme, parts, masks, cfg.p)
    ctx = {"xq": xq, "m_padded": xq.shape[0]}
    return shares, ctx


def encode_weights(cfg: CPMLConfig, key: jax.Array, w: jax.Array) -> jax.Array:
    """Quantize w (Eq. 9-10) and Lagrange-encode W̄ (Eq. 13-14).

    w: (d,) or (d, c) real weights.  Returns shares (N, *w.shape, r).
    Note v(beta_i) = W̄ for ALL i <= K (the paper repeats the same W̄ at every
    data interpolation point), with fresh random masks V each round.
    """
    kq, km = jax.random.split(key)
    wbar = quantize.quantize_weights(kq, w, cfg.lw, cfg.r, cfg.p)
    parts = jnp.broadcast_to(wbar[None], (cfg.K, *wbar.shape))
    masks = lagrange.draw_masks(km, cfg.T, wbar.shape, cfg.p)
    return lagrange.encode(cfg.scheme, parts, masks, cfg.p)


# ---------------------------------------------------------------------------
# Split weight encode: the W-INDEPENDENT half (key split + fresh masks +
# their encoded contribution) can run while the previous round is still in
# flight; only the W-DEPENDENT half (quantize + data-row encode) must wait
# for the decoded weights.  Exactness of the field ops makes the split
# bit-identical to encode_weights (pinned in tests/test_pipeline.py).
# ---------------------------------------------------------------------------

def weight_mask_shares(cfg: CPMLConfig, key: jax.Array,
                       w_shape: tuple[int, ...]
                       ) -> tuple[jax.Array, jax.Array]:
    """W-independent half of ``encode_weights``.

    Splits the round key exactly as encode_weights does, draws the T fresh
    privacy masks (shape depends only on (d, c, r) — known before W is),
    and encodes their contribution.  Returns ``(kq, mask_shares)`` where
    ``kq`` is the stochastic-quantization key the W-dependent half consumes
    and ``mask_shares`` is (N, *w_shape, r).
    """
    kq, km = jax.random.split(key)
    wbar_shape = (*w_shape, cfg.r)
    masks = lagrange.draw_masks(km, cfg.T, wbar_shape, cfg.p)
    return kq, lagrange.encode_masks(cfg.scheme, masks, cfg.p)


def encode_weights_finish(cfg: CPMLConfig, kq: jax.Array,
                          mask_shares: jax.Array, w: jax.Array) -> jax.Array:
    """W-dependent half: quantize w, encode the data rows, add the masks.

    ``encode_weights_finish(cfg, *weight_mask_shares(cfg, key, w.shape), w)
    == encode_weights(cfg, key, w)`` bit-for-bit.
    """
    wbar = quantize.quantize_weights(kq, w, cfg.lw, cfg.r, cfg.p)
    parts = jnp.broadcast_to(wbar[None], (cfg.K, *wbar.shape))
    data = lagrange.encode_data(cfg.scheme, parts, cfg.p)
    return field.addmod(data, mask_shares, cfg.p)
