"""Beyond-paper: Lagrange-coded tensor-parallel linear layer.

The paper codes the *training data* for privacy + stragglers.  The same
machinery applies to a pure-matmul INFERENCE layer (the LM head): partition
the weight matrix W (d, v) into K column blocks, add T random mask blocks,
Lagrange-encode into N shares W̃_i — one per TP device.  Every device computes
Y_i = H @ W̃_i; since f is degree-1 in W̃, ANY K+T of the N results reconstruct
all K true column blocks (recovery threshold K+T, Theorem 1 with 'deg f'=1).

What this buys on a 1000+-node cluster:
  * straggler/failure tolerance for TP: N-(K+T) device losses survivable per
    coded group without recomputation;
  * T-collusion privacy of the *model weights* against compromised hosts
    (and of activations, in the dual activation-coded mode).
Cost: N/K compute overhead and quantization of H/W (lh/lw fixed-point bits).

This is `--coded-head` in launch/serve.py; tests/test_coded_linear.py checks
exactness of the field path and the end-to-end fp error bound.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field, lagrange, quantize


@dataclasses.dataclass(frozen=True)
class CodedLinearConfig:
    N: int              # TP shards (devices in the coded group)
    K: int              # data blocks (useful fraction = K/N)
    T: int              # privacy threshold
    lh: int = 6         # activation quantization bits (scale 2^lh)
    lw: int = 6         # weight quantization bits
    p: int = field.P30  # 30-bit prime: more headroom for d-long dot products

    def __post_init__(self):
        assert self.N >= self.K + self.T, "need N >= K+T (deg-1 threshold)"

    @property
    def threshold(self) -> int:
        return lagrange.degree_threshold(self.K, self.T, deg_f=1)

    @property
    def scheme(self) -> lagrange.CodingScheme:
        return lagrange.CodingScheme(self.N, self.K, self.T, self.p)


def encode_weights(cfg: CodedLinearConfig, key: jax.Array, w: jax.Array
                   ) -> jax.Array:
    """w: (d, v) real -> coded shares (N, d, v/K) in F_p.  Done once."""
    d, v = w.shape
    assert v % cfg.K == 0, f"vocab {v} must divide into K={cfg.K} blocks"
    wq = quantize.quantize_data(w, cfg.lw, cfg.p)
    parts = wq.reshape(d, cfg.K, v // cfg.K).transpose(1, 0, 2)  # (K, d, v/K)
    masks = lagrange.draw_masks(key, cfg.T, parts.shape[1:], cfg.p)
    return lagrange.encode(cfg.scheme, parts, masks, cfg.p)


def worker_matmul(cfg: CodedLinearConfig, h_q: jax.Array, w_share: jax.Array
                  ) -> jax.Array:
    """One shard's compute: H̄ @ W̃_i over F_p.  (m, d) x (d, v/K)."""
    return field.matmul(h_q, w_share, cfg.p)


def decode_output(cfg: CodedLinearConfig, results: jax.Array,
                  survivors: np.ndarray) -> jax.Array:
    """(S, m, v/K) survivor results -> (m, v) real logits."""
    dec = lagrange.decode(cfg.scheme, results, survivors, deg_f=1, p=cfg.p)
    out = quantize.dequantize(dec, cfg.lh + cfg.lw, cfg.p)  # (K, m, v/K)
    return out.transpose(1, 0, 2).reshape(results.shape[1], -1)


def coded_head_apply(cfg: CodedLinearConfig, h: jax.Array,
                     w_shares: jax.Array,
                     survivors: np.ndarray | None = None) -> jax.Array:
    """Full coded projection: h (m, d) real -> logits (m, v) real.

    `survivors=None` uses the first K+T shards (no failures); pass any index
    set of size >= K+T to simulate stragglers/failures.
    """
    surv = np.arange(cfg.N) if survivors is None else np.asarray(survivors)
    h_q = quantize.quantize_data(h, cfg.lh, cfg.p)
    results = jax.vmap(lambda ws: worker_matmul(cfg, h_q, ws))(
        w_shares[jnp.asarray(surv[: cfg.threshold])])
    return decode_output(cfg, results, surv[: cfg.threshold])


def coded_head_apply_sharded(cfg: CodedLinearConfig, mesh, axis: str,
                             h: jax.Array, w_shares: jax.Array,
                             survivors: tuple[int, ...] | None = None
                             ) -> jax.Array:
    """shard_map version: one share per device along `axis` (size N).

    `survivors` is a STATIC index tuple (the runtime's heartbeat monitor
    picks it; each pattern compiles once — patterns change at node-failure
    frequency, i.e. rarely).  Every device computes its share's matmul with
    zero collectives; one all_gather plays "send to master"; the decode is a
    replicated (threshold x K) field matmul.  Used by launch/serve.py
    --coded-head and the coded-head dry-run cell.
    """
    from jax.sharding import PartitionSpec as Pspec
    surv = np.arange(cfg.N) if survivors is None else np.asarray(survivors)
    h_q = quantize.quantize_data(h, cfg.lh, cfg.p)

    def body(ws):
        res = worker_matmul(cfg, h_q, ws[0])[None]          # (1, m, v/K)
        return jax.lax.all_gather(res, axis, axis=0, tiled=True)  # (N, m, v/K)

    from repro.parallel import compat
    results = compat.shard_map(body, mesh, (Pspec(axis),), Pspec(),
                               check=True)(w_shares)
    picked = jnp.take(results, jnp.asarray(surv[: cfg.threshold]), axis=0)
    return decode_output(cfg, picked, surv[: cfg.threshold])
