"""Lagrange coded computing (paper §3.2, §3.4; Yu et al. 2019).

Encoding: split X̄ into K submatrices, append T uniform random masks, fit the
degree-(K+T-1) interpolant u with u(beta_i) = X̄_i (i<=K) / Z_i (i>K), and
evaluate at N points alpha -> shares X̃_i = u(alpha_i).  Equivalently a
mod-p matmul against the (K+T, N) encoding matrix U (Eq. 12).

Decoding: worker i returns h(alpha_i) where h = f(u(z), v(z)) has degree
<= deg(f)·(K+T-1).  Any R = deg(f)·(K+T-1)+1 surviving evaluations determine
h; we read off h(beta_k) via a second Lagrange-coefficient matrix (no
Vandermonde inversion needed on the hot path).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field


def recovery_threshold(K: int, T: int, r: int) -> int:
    """Minimum surviving workers: (2r+1)(K+T-1)+1 (Theorem 1)."""
    return (2 * r + 1) * (K + T - 1) + 1


def degree_threshold(K: int, T: int, deg_f: int) -> int:
    """Threshold for an arbitrary polynomial worker function of degree deg_f."""
    return deg_f * (K + T - 1) + 1


@dataclasses.dataclass(frozen=True)
class CodingScheme:
    """All static data of one Lagrange code: evaluation points + matrices."""
    N: int          # number of workers / shares
    K: int          # parallelization (dataset split)
    T: int          # privacy threshold
    p: int = field.P

    def __post_init__(self):
        assert self.K >= 1 and self.T >= 0 and self.N >= self.K + self.T, (
            f"need N >= K+T, got N={self.N} K={self.K} T={self.T}")

    @functools.cached_property
    def betas(self) -> np.ndarray:
        # K+T distinct interpolation points: 1..K+T (disjoint from alphas).
        return np.arange(1, self.K + self.T + 1, dtype=np.int64)

    @functools.cached_property
    def alphas(self) -> np.ndarray:
        # N distinct evaluation points, disjoint from betas.
        start = self.K + self.T + 1
        return np.arange(start, start + self.N, dtype=np.int64)

    @functools.cached_property
    def encode_matrix(self) -> np.ndarray:
        """U in F_p^{(K+T) x N} of Eq. (12)."""
        return field.host_lagrange_coeffs(self.alphas, self.betas, self.p)

    def decode_matrix(self, survivors: np.ndarray) -> np.ndarray:
        """D in F_p^{len(survivors) x K}: h(beta_k) = sum_i D[i,k] h(alpha_i).

        survivors: indices (into [N]) of workers whose results arrived.
        """
        pts = self.alphas[np.asarray(survivors)]
        return field.host_lagrange_coeffs(self.betas[: self.K], pts, self.p)

    def coeff_matrix(self, survivors: np.ndarray) -> np.ndarray:
        """V^{-1}: recovers the coefficients of h from survivor evaluations."""
        pts = self.alphas[np.asarray(survivors)]
        return field.host_vandermonde_inv(pts, self.p)


def encode(scheme: CodingScheme, x_parts: jax.Array, masks: jax.Array,
           p: int | None = None) -> jax.Array:
    """Encode stacked parts+masks into N shares (Eq. 12).

    x_parts: (K, *part_shape) int32 field elements.
    masks:   (T, *part_shape) uniform field elements (the Z_i / V_i).
    Returns shares: (N, *part_shape).
    """
    p = p or scheme.p
    stacked = jnp.concatenate([x_parts, masks], axis=0) if scheme.T else x_parts
    part_shape = stacked.shape[1:]
    flat = stacked.reshape(scheme.K + scheme.T, -1)
    U = jnp.asarray(scheme.encode_matrix, jnp.int32)  # (K+T, N)
    shares = field.matmul(U.T, flat, p)               # (N, prod(part_shape))
    return shares.reshape(scheme.N, *part_shape)


def _encode_rows(scheme: CodingScheme, stacked: jax.Array, rows: slice,
                 p: int) -> jax.Array:
    """Shares contributed by a contiguous row-slice of the encode matrix U."""
    part_shape = stacked.shape[1:]
    flat = stacked.reshape(stacked.shape[0], -1)
    U = jnp.asarray(scheme.encode_matrix[rows], jnp.int32)   # (nrows, N)
    shares = field.matmul(U.T, flat, p)                      # (N, prod(shape))
    return shares.reshape(scheme.N, *part_shape)


def encode_data(scheme: CodingScheme, x_parts: jax.Array,
                p: int | None = None) -> jax.Array:
    """The data-row contribution U[:K]ᵀ X̄ of a split encode.

    ``addmod(encode_data(parts), encode_masks(masks)) == encode(parts,
    masks)`` bit-for-bit: field.matmul/addmod are exact mod p, so splitting
    the (K+T)-row matmul into its K-row and T-row halves changes nothing.
    This is the W-DEPENDENT half of a round's weight encode — the only part
    that must wait for the previous round's decoded weights.
    """
    p = p or scheme.p
    return _encode_rows(scheme, x_parts, slice(0, scheme.K), p)


def encode_masks(scheme: CodingScheme, masks: jax.Array,
                 p: int | None = None) -> jax.Array:
    """The mask-row contribution U[K:]ᵀ Z of a split encode.

    Depends only on the round's random masks — never on the data or the
    weights — so a pipelined master precomputes it for round k+1 while
    round k is still in flight (cluster/pipeline.py).  T == 0 contributes
    nothing (zeros), mirroring encode()'s no-mask path.
    """
    p = p or scheme.p
    if scheme.T == 0:
        return jnp.zeros((scheme.N, *masks.shape[1:]), jnp.int32)
    return _encode_rows(scheme, masks,
                        slice(scheme.K, scheme.K + scheme.T), p)


def draw_masks(key: jax.Array, T: int, part_shape: tuple[int, ...],
               p: int = field.P) -> jax.Array:
    """T i.i.d. uniform matrices over F_p (the privacy masks)."""
    if T == 0:
        return jnp.zeros((0, *part_shape), jnp.int32)
    return jax.random.randint(key, (T, *part_shape), 0, p, dtype=jnp.int32)


def decode(scheme: CodingScheme, results: jax.Array, survivors: np.ndarray,
           deg_f: int, p: int | None = None) -> jax.Array:
    """Recover {h(beta_k)}_{k in [K]} from survivor evaluations (§3.4).

    results:   (S, *res_shape) field elements, S = len(survivors) evaluations
               h(alpha_i) in survivor order.
    survivors: static numpy index array; len >= deg_f*(K+T-1)+1.
    Returns (K, *res_shape): the K decoded sub-results.
    """
    p = p or scheme.p
    need = degree_threshold(scheme.K, scheme.T, deg_f)
    assert len(survivors) >= need, (
        f"need {need} survivors for deg(f)={deg_f}, got {len(survivors)}")
    survivors = np.asarray(survivors)[:need]
    res_shape = results.shape[1:]
    flat = results[: need].reshape(need, -1)
    D = jnp.asarray(scheme.decode_matrix(survivors), jnp.int32)  # (S, K)
    out = field.matmul(D.T, flat, p)  # (K, prod(res_shape))
    return out.reshape(scheme.K, *res_shape)


def decode_sum(scheme: CodingScheme, results: jax.Array,
               survivors: np.ndarray, deg_f: int,
               p: int | None = None) -> jax.Array:
    """sum_k h(beta_k) — the paper's Eq. (23) — in one matmul."""
    p = p or scheme.p
    decoded = decode(scheme, results, survivors, deg_f, p)
    out = decoded[0]
    for k in range(1, scheme.K):
        out = field.addmod(out, decoded[k], p)
    return out
