"""Stochastic & deterministic quantization between R and F_p (paper §3.1).

  * dataset:  X̄ = phi(Round(2^lx · X))                      (Eq. 6)
  * weights:  w̄^j = phi(Round_stoc(2^lw · w)), j = 1..r      (Eqs. 8-10)
  * inverse:  Q_p^{-1}(x̄; l) = 2^{-l} · phi^{-1}(x̄)          (Eq. 24)

Stochastic rounding is unbiased (E[Round_stoc(x)] = x), which Lemma 1 needs
for the gradient estimator.  All functions are jit-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import field


def quantize_data(x: jax.Array, lx: int, p: int = field.P) -> jax.Array:
    """Deterministic round-half-up quantization of the dataset (Eq. 5-6)."""
    scaled = x * (2.0 ** lx)
    rounded = jnp.floor(scaled + 0.5).astype(jnp.int32)  # Round() of Eq. (5)
    return field.from_signed(rounded, p)


def quantize_weights(key: jax.Array, w: jax.Array, lw: int, r: int,
                     p: int = field.P) -> jax.Array:
    """r independent stochastic quantizations of w (Eq. 9-10).

    Returns W̄ of shape (*w.shape, r): column j is one unbiased realization.
    """
    scaled = w * (2.0 ** lw)
    floor = jnp.floor(scaled)
    frac = scaled - floor
    u = jax.random.uniform(key, (*w.shape, r))
    rounded = floor[..., None] + (u < frac[..., None]).astype(scaled.dtype)
    return field.from_signed(rounded.astype(jnp.int32), p)


def dequantize(x: jax.Array, l: int, p: int = field.P) -> jax.Array:
    """Q_p^{-1} of Eq. (24): field -> real with total scale 2^{-l}."""
    return field.to_signed(x, p).astype(jnp.float32) * (2.0 ** (-l))


def gradient_scale(lx: int, lw: int, r: int) -> int:
    """Total fixed-point scale of the decoded gradient, l = lx + r(lx+lw).

    f = X̃ᵀ ḡ(X̃·W̃): the degree-(r) product term carries r factors of
    (2^lx · 2^lw) and the outer X̃ᵀ one more 2^lx (paper, below Eq. 24).
    """
    return lx + r * (lx + lw)


def required_prime_bits(x_max: float, lx: int) -> int:
    """Minimum bits so p >= 2^(lx+1) max|X| + 1 (no wrap-around, §3.1)."""
    import math
    return max(1, math.ceil(math.log2(2 ** (lx + 1) * max(x_max, 1e-9) + 1)))


def wire_itemsize(p: int = field.P) -> int:
    """Bytes/element needed to ship field elements of F_p losslessly.

    Quantized shares are ints in [0, p), so ceil(bits(p-1) / 8) bytes carry
    them bit-exactly: 3 for the 24-bit P, 4 for the 30-bit P30.  Wire v2's
    PACKED encoding (cluster/wire.py, DESIGN.md §10) narrows int32 payloads
    to exactly this width on the wire — dtype narrowing, never lossy
    quantization (optim/compress.py is a different, opt-in animal and stays
    off every protocol path).
    """
    return max(1, ((p - 1).bit_length() + 7) // 8)
