"""whisper-tiny — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

The audio conv frontend is a stub: input_specs() provides precomputed frame
embeddings (batch, enc_frames, d_model).  Positional scheme normalized to
RoPE across the pool (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, act="gelu",
    is_encoder_decoder=True, num_encoder_layers=4, encoder_seq_len=1500,
    frontend="audio",
    block_pattern=(("dec", 4),),
    source="[arXiv:2212.04356; unverified]",
)
