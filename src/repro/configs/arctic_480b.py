"""arctic-480b — 128-expert top-2 MoE + dense residual [hf:Snowflake; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, experts_per_token=2, moe_d_ff=4864,
    dense_residual_d_ff=4864,
    source="[hf:Snowflake/snowflake-arctic-base; hf]",
)
