"""h2o-danube-3-4b — llama/mistral mix with SWA [arXiv:2401.16818; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    d_ff=10240, vocab_size=32000, sliding_window=4096,
    source="[arXiv:2401.16818; unverified]",
)
