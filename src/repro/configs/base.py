"""Model / run configuration schema.

One ModelConfig per assigned architecture lives in src/repro/configs/<id>.py;
`repro.configs.registry` resolves `--arch <id>`.  Configs are frozen
dataclasses — hashable, usable as jit static args.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["dense", "moe", "mamba", "hybrid", "enc", "dec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention ---
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    sliding_window: int | None = None   # window size; None = full attention
    global_layer_every: int = 0         # hybrid: every k-th layer full attn
    attn_logit_softcap: float | None = None
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE (stub)

    # --- mlp ---
    act: str = "silu"                # silu (swiglu) | gelu
    tie_embeddings: bool = False

    # --- moe ---
    num_experts: int = 0
    experts_per_token: int = 2
    moe_d_ff: int = 0                # expert hidden dim (0 -> d_ff)
    dense_residual_d_ff: int = 0     # arctic: parallel dense FFN branch
    capacity_factor: float = 1.25

    # --- ssm (mamba1) ---
    ssm_state: int = 0
    d_inner: int = 0                 # 0 -> 2*d_model when mamba is used
    dt_rank: int = 0                 # 0 -> ceil(d_model/16)
    conv_width: int = 4

    # --- structure ---
    block_pattern: tuple[tuple[str, int], ...] = ()   # [(kind, count), ...]
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper audio frames after conv stub
    frontend: str = "none"           # none | audio | vision  (stubs)
    norm_eps: float = 1e-5
    source: str = ""                 # provenance note [source; verified-tier]

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_state and not self.d_inner:
            object.__setattr__(self, "d_inner", 2 * self.d_model)
        if self.ssm_state and not self.dt_rank:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))
        if self.num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if not self.block_pattern:
            kind = ("moe" if self.num_experts else
                    "mamba" if self.ssm_state and not self.num_heads else
                    "dense")
            object.__setattr__(self, "block_pattern",
                               ((kind, self.num_layers),))
        assert sum(c for _, c in self.block_pattern) == self.num_layers, (
            self.name, self.block_pattern, self.num_layers)

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is feasible (SSM/hybrid/SWA)."""
        if self.ssm_state and not self.num_heads:
            return True                          # pure SSM
        if self.sliding_window is not None:
            return True                          # SWA (maybe + few global)
        return False

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                   # embedding
        if not self.tie_embeddings:
            total += v * d                              # lm head
        for kind, count in self.block_pattern:
            total += count * self._block_params(kind)
        total += d                                      # final norm
        if self.is_encoder_decoder:
            total += self.num_encoder_layers * self._block_params("enc")
        return total

    def _attn_params(self) -> int:
        d, h, kh, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        n = d * h * hd + 2 * d * kh * hd + h * hd * d
        if self.qkv_bias:
            n += h * hd + 2 * kh * hd
        return n

    def _mlp_params(self, ff: int) -> int:
        d = self.d_model
        if self.act == "silu":
            return 3 * d * ff        # swiglu: w1, w3, w2
        return 2 * d * ff

    def _mamba_params(self) -> int:
        d, di, n, dtr, cw = (self.d_model, self.d_inner, self.ssm_state,
                             self.dt_rank, self.conv_width)
        return (d * 2 * di            # in_proj (x, z)
                + di * cw             # depthwise conv
                + di * (dtr + 2 * n)  # x_proj -> (dt, B, C)
                + dtr * di + di       # dt_proj
                + di * n + di         # A_log, D
                + di * d)             # out_proj

    def _block_params(self, kind: str) -> int:
        kind = kind.replace("_global", "")
        d = self.d_model
        norms = 2 * d
        if kind == "dense":
            return self._attn_params() + self._mlp_params(self.d_ff) + norms
        if kind == "moe":
            n = self._attn_params() + norms + d * self.num_experts
            n += self.num_experts * self._mlp_params(self.moe_d_ff) // 1
            if self.dense_residual_d_ff:
                n += self._mlp_params(self.dense_residual_d_ff) + d
            return n
        if kind == "mamba":
            return self._mamba_params() + d  # one norm
        if kind == "hybrid":
            return (self._attn_params() + self._mamba_params()
                    + self._mlp_params(self.d_ff) + norms + d)
        if kind == "enc":
            return self._attn_params() + self._mlp_params(self.d_ff) + norms
        if kind == "dec":  # self-attn + cross-attn + mlp
            return (2 * self._attn_params() + self._mlp_params(self.d_ff)
                    + 3 * d)
        raise ValueError(kind)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving hyper-parameters + parallelism knobs."""
    seq_len: int = 4096
    global_batch: int = 256
    microbatch: int = 0              # 0 = no microbatching
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    param_dtype: str = "bfloat16"
    remat: str = "block"             # none | block | full
    seq_parallel: bool = False       # shard activation seq dim over model axis
    loss_chunk: int = 512            # vocab-loss seq chunking
    q_block: int = 512               # blockwise attention tiles
    kv_block: int = 1024
    attn_dtype: str = "f32"          # score/PV matmul input dtype (bf16|f32)
    scan_chunk: int = 128            # mamba chunked-scan length
    ssm_dtype: str = "f32"           # mamba a/b tensor dtype (bf16|f32)
    moe_impl: str = "einsum"         # einsum | sort
    moe_combine_dtype: str = "f32"   # GShard combine-weights dtype
    moe_group_size: int = 0          # tokens per dispatch group (0 = one
                                     # group per batch row — GShard default)
    coded_head: bool = False         # Lagrange-coded LM head (core/coded_linear)
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
