"""hymba-1.5b — hybrid parallel attn+mamba heads [arXiv:2411.13676; hf]."""
from repro.configs.base import ModelConfig

# 32 layers, 3 full-attention layers (first / middle / last — Hymba paper),
# sliding-window attention elsewhere; every block runs attention ∥ mamba.
CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, ssm_state=16,
    sliding_window=1024,
    block_pattern=(("hybrid_global", 1), ("hybrid", 14), ("hybrid_global", 1),
                   ("hybrid", 14), ("hybrid_global", 1), ("hybrid", 1)),
    source="[arXiv:2411.13676; hf]",
)
