"""qwen2-vl-7b — M-RoPE, dynamic-resolution ViT (STUB frontend)
[arXiv:2409.12191; hf].

The vision tower is a stub: input_specs() feeds precomputed patch embeddings
(batch, seq, d_model).  M-RoPE degenerates to 1-D RoPE for text-only
position streams; the (t,h,w) section split is recorded for provenance.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), frontend="vision",
    source="[arXiv:2409.12191; hf]",
)
