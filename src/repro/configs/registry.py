"""--arch <id> resolution + per-cell input_specs (ShapeDtypeStruct only).

`input_specs` builds the exact abstract inputs each (arch x shape) cell
lowers with: token ids for LM archs, precomputed patch/frame embeddings for
the stubbed [vlm]/[audio] frontends, decode caches for decode cells.
No device memory is ever allocated here.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ModelConfig, RunConfig, ShapeConfig

ARCHS: dict[str, str] = {
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "arctic-480b": "repro.configs.arctic_480b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1p1b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch]).CONFIG


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one step, no NaNs)."""
    import dataclasses
    scale = {}
    d = 64
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0
    pattern = tuple((kind, min(count, 2)) for kind, count in
                    cfg.block_pattern[:2])
    layers = sum(c for _, c in pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d,
        num_heads=heads, num_kv_heads=kv,
        head_dim=(d // heads if heads else 0),
        d_ff=(128 if cfg.d_ff else 0),
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4),
        moe_d_ff=(64 if cfg.num_experts else 0),
        dense_residual_d_ff=(64 if cfg.dense_residual_d_ff else 0),
        d_inner=(128 if cfg.ssm_state else 0),
        dt_rank=(8 if cfg.ssm_state else 0),
        sliding_window=(32 if cfg.sliding_window else None),
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_seq_len=16,
        block_pattern=pattern,
    )


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs; reason when skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k dense decode is "
                       "O(S^2)-infeasible; skipped per brief (DESIGN.md §4)")
    return True, ""


def _tok(mesh, shape, batch_axes):
    from jax.sharding import NamedSharding, PartitionSpec as P
    b = shape[0]
    spec = P(batch_axes if b % _size(mesh, batch_axes) == 0 else None,
             *([None] * (len(shape) - 1)))
    return NamedSharding(mesh, spec)


def _size(mesh, axes):
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                rc: RunConfig | None = None) -> dict:
    """Abstract inputs for one cell.  Decode cells include the cache tree."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import model as M
    from repro.parallel.rules import spec_for

    rc = rc or RunConfig()
    B, S = shape.global_batch, shape.seq_len
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bax = batch_axes if (len(batch_axes) and B % _size(mesh, batch_axes) == 0) \
        else ()
    bspec = bax if len(bax) > 1 else (bax[0] if bax else None)

    def sd(shape_, dtype, spec):
        return jax.ShapeDtypeStruct(shape_, dtype,
                                    sharding=NamedSharding(mesh, spec))

    stub_embeds = cfg.frontend in ("vision", "audio")
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        if stub_embeds and not cfg.is_encoder_decoder:
            specs["embeds"] = sd((B, S, cfg.d_model), jnp.bfloat16,
                                 P(bspec, None, None))
        else:
            specs["tokens"] = sd((B, S), jnp.int32, P(bspec, None))
        if cfg.is_encoder_decoder:
            specs["enc_embeds"] = sd((B, cfg.encoder_seq_len, cfg.d_model),
                                     jnp.bfloat16, P(bspec, None, None))
        if shape.kind == "train":
            specs["labels"] = sd((B, S), jnp.int32, P(bspec, None))
        return specs

    # decode: one new token + cache of length S
    specs["tokens"] = sd((B, 1), jnp.int32, P(bspec, None))
    if cfg.is_encoder_decoder:
        specs["enc_out"] = sd((B, cfg.encoder_seq_len, cfg.d_model),
                              jnp.bfloat16, P(bspec, None, None))
    cache_shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, rc, B, S))
    cache = {}
    for key, seg in cache_shapes.items():
        if key == "index":
            cache[key] = jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P()))
            continue
        seg_specs = {}
        for name, leaf in seg.items():
            logical = {
                "k": (None, "batch", "kv_seq", "kv_heads", None),
                "v": (None, "batch", "kv_seq", "kv_heads", None),
                "conv": (None, "batch", None, "inner"),
                "ssm": (None, "batch", "inner", None),
            }[name]
            spec = spec_for(mesh, leaf.shape, logical)
            seg_specs[name] = jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec))
        cache[key] = seg_specs
    specs["cache"] = cache
    return specs
