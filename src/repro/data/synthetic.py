"""Deterministic synthetic datasets (offline stand-ins for MNIST + LM data).

The paper trains binary MNIST (3 vs 7), (m, d) = (12396, 1568) / (12396, 784).
The container has no dataset downloads, so we generate a distribution-faithful
stand-in: sparse non-negative pixel-like features in [0, 1] with a planted
linear separator passed through a sigmoid label model.  Same m, d, same
feature range, same "most pixels near zero" sparsity — so quantization/
overflow behaviour matches the real thing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mnist_like(key: jax.Array, m: int = 12396, d: int = 784,
               sparsity: float = 0.8, margin: float = 4.0
               ) -> tuple[jax.Array, jax.Array]:
    """Binary classification with pixel-like features. Returns (X, y)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.uniform(k1, (m, d))
    mask = jax.random.uniform(k2, (m, d)) > sparsity
    x = jnp.where(mask, x, 0.0)                      # mostly-zero "pixels"
    w_true = jax.random.normal(k3, (d,)) / np.sqrt(d)
    logits = margin * (x @ w_true)
    logits = logits - jnp.median(logits)             # balanced classes
    y = (jax.random.uniform(k4, (m,)) < jax.nn.sigmoid(logits)).astype(
        jnp.float32)
    return x, y


def multiclass_mnist_like(key: jax.Array, m: int = 12396, d: int = 784,
                          c: int = 10, sparsity: float = 0.8,
                          margin: float = 6.0
                          ) -> tuple[jax.Array, jax.Array]:
    """c-class classification with pixel-like features. Returns (X, labels).

    Same feature distribution as mnist_like (sparse, [0, 1]) so quantization
    and wrap-around behaviour match; labels are sampled from a softmax over c
    planted linear scores — the one-vs-all coded engine's natural target.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.uniform(k1, (m, d))
    mask = jax.random.uniform(k2, (m, d)) > sparsity
    x = jnp.where(mask, x, 0.0)                      # mostly-zero "pixels"
    w_true = jax.random.normal(k3, (d, c)) / np.sqrt(d)
    logits = margin * (x @ w_true)
    labels = jax.random.categorical(k4, logits, axis=-1).astype(jnp.int32)
    return x, labels


def lm_batch(key: jax.Array, batch: int, seq: int, vocab: int
             ) -> dict[str, jax.Array]:
    """Synthetic next-token-prediction batch (tokens + shifted labels)."""
    tokens = jax.random.randint(key, (batch, seq + 1), 0, vocab,
                                dtype=jnp.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def feature_probe_data(key: jax.Array, m: int, d_feat: int
                       ) -> tuple[jax.Array, jax.Array]:
    """Frozen-LM-feature probe task: features ~ N(0,1)/sqrt(d), binary label.

    Used by the paper-faithful private head training on top of an LM: the
    "dataset" X is a feature matrix extracted by the (frozen) backbone.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, d_feat)) / np.sqrt(d_feat)
    # shift to non-negative range like post-GeLU features, keep |x| <= 1
    x = jnp.clip(x + 0.5, 0.0, 1.0)
    w_true = jax.random.normal(k2, (d_feat,))
    y = (jax.random.uniform(k3, (m,)) < jax.nn.sigmoid(4.0 * (x @ w_true))
         ).astype(jnp.float32)
    return x, y
