"""Sharding-aware synthetic data pipeline.

Deterministic (seed + step -> batch), host-side generation with device_put
onto the mesh's batch sharding, and a one-batch prefetch thread so host
generation overlaps device compute — the structure a real tokenized-shard
loader would have, minus the filesystem.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class LMBatchLoader:
    """Use as a context manager (``with LMBatchLoader(...) as loader:``) or
    call ``close()`` explicitly: the prefetch thread is joined on close, so
    a finished run never leaks a producer blocked on a full queue."""

    def __init__(self, mesh: Mesh | None, batch: int, seq: int, vocab: int,
                 seed: int = 0, prefetch: int = 2):
        self.mesh, self.batch, self.seq, self.vocab = mesh, batch, seq, vocab
        self.seed = seed
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _sharding(self):
        if self.mesh is None:
            return None
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        total = int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1
        spec = P(axes if len(axes) > 1 else (axes[0] if axes else None), None)
        if self.batch % max(total, 1):
            spec = P(None, None)
        return NamedSharding(self.mesh, spec)

    def _make(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _produce(self):
        step = 0
        while not self._stop.is_set():
            batch = self._make(step)
            try:
                self._q.put(batch, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        host = self._q.get()
        sh = self._sharding()
        if sh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, sh) for k, v in host.items()}

    def close(self):
        """Stop and JOIN the prefetch thread (idempotent).

        The producer may be blocked in a bounded-queue put; its 1s put
        timeout re-checks the stop flag, and draining the queue here
        unblocks it immediately instead.
        """
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "LMBatchLoader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
