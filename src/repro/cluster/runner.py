"""ClusterRunner: coded training driven by the cluster runtime.

Division of labor (DESIGN.md §7): the scheduler moves messages and time;
ALL gradient numerics run through the exact round/update functions
train()/train_reference() use, with the decode matrix and responder order
observed from the runtime.  In the in-process simulation the whole round is
``engine.round_fn`` on the master; over the socket transport real worker
processes evaluate f(X̃_i, W̃_i) and their deserialized payloads feed
``engine.update_fn`` — the same decode+step the simulated round composes.
Consequence: a ClusterRunner run — simulated or live — is BIT-IDENTICAL to
``engine.train_reference`` replaying the same responder trace
(tests/test_cluster.py, tests/test_socket_cluster.py), so the cluster
layer can never silently change training semantics, only timing and
placement.

The ``engine`` knob (DESIGN.md §14) picks the coded-arithmetic backend
behind those hooks: ``"exact"`` (default) is the quantized field protocol
above; ``"alcc"`` swaps in ``protocol/alcc_engine`` — real-valued Lagrange
coding with Gaussian analog masks and a least-squares decode.  The runner
code is shared; only three things change: weight shares ship as float32
(v2-only FROUND/FRESULT wire frames on the socket transport), the decode
hooks take the responder ORDER instead of an int32 decode matrix, and
every decode's condition number / error budget / fallback flag is
collected into ``wait_stats()["alcc"]``, the ``cpml_alcc_*`` metrics and
``alcc_decode`` trace instants.  The replay invariant becomes two-tier:
sim runs stay bit-identical to ``alcc_engine.train_reference``; socket
runs agree within the decode error budget (XLA-vs-BLAS float32 summation
order).  Exact-only machinery — ``pipeline`` modes, ``masters > 1``,
spares/joins — is refused at construction.

Resilience integration (runtime/resilience.py):

  * HeartbeatMonitor — results/acks feed it on the SIMULATED clock; workers
    that stop heartbeating (dead) drop out of the dispatch set, and known
    stragglers are speculatively excluded from dispatch while the fast set
    STRICTLY exceeds the recovery threshold (exact coverage leaves no slack
    for an undetected death).
  * ResilientLoop + CheckpointManager — ``run_resilient(...)``
    checkpoints every k rounds; a round that starves (fewer than
    ``threshold`` responses inside the timeout) raises ClusterDecodeError,
    the loop restores the last checkpoint, and the ``on_restore`` hook
    reprovisions dead workers (latency.revive + monitor.revive) before
    replay — mid-run worker death costs a rollback, not the run.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time as _time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.latency import LatencyModel
from repro.cluster.master_group import MasterGroup
from repro.cluster.membership import ClusterMembership, MembershipView
from repro.cluster.messages import (
    MASTER,
    PROVISION_ROUND,
    SHUTDOWN_ROUND,
    EncodeShare,
    Epoch,
    Heartbeat,
    Join,
    worker_endpoint,
)
from repro.cluster.pipeline import PIPELINE_MODES, RoundContext, RoundPrefetcher
from repro.cluster.scheduler import ClusterDecodeError, EventScheduler, RoundTrace
from repro.cluster.wire import WIRE_V2
from repro.cluster.transport import Transport
from repro.core.protocol import alcc_engine, decode, engine
from repro.core.protocol.config import CPMLConfig

# the runner's engine is pluggable (DESIGN.md §14): "exact" is the field
# protocol (bit-identical decode), "alcc" the float backend (least-squares
# decode with a tracked error budget).  Both expose the same hook factories.
ENGINES = {"exact": engine, "alcc": alcc_engine}
from repro.obs.metrics import MetricsRegistry
from repro.runtime.resilience import HeartbeatMonitor, ResilientLoop


def wait_summary(a) -> dict[str, float]:
    """mean/p50/p95/total of a wait-time series (zeroed when empty).

    The one aggregation both runner.wait_stats and bench_cluster.py report,
    so BENCH_cluster.json and live stats can never disagree on keys.  An
    EMPTY series — no completed rounds, or an all-starved trace — returns a
    well-formed all-zero summary: numpy would warn and NaN on a mean over
    nothing, and inf placeholders poison downstream ratio math (inf/inf)
    (pinned by tests/test_obs.py)."""
    a = np.asarray(a, dtype=float)
    if a.size == 0:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "total": 0.0}
    return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)), "total": float(a.sum())}


def await_worker_acks(transport: Transport, clock_fn, expect,
                      monitor, timeout_s: float,
                      control: list | None = None) -> None:
    """Block until every worker in ``expect`` has acked provisioning with a
    Heartbeat (shared by ClusterRunner and MPCClusterRunner, so both
    protocols start their wall clocks after worker warmup).

    ``expect`` is an int (the historical contract: workers 0..n-1) or an
    explicit set of slots — elastic provisioning waits on exactly the
    subset it just shipped shares to, e.g. a single mid-run joiner.
    ``control`` (when given) collects JOIN frames drained off the master
    inbox here instead of dropping them — a late joiner may announce itself
    while the initial fleet is still acking.
    """
    expect = (set(range(expect)) if isinstance(expect, int)
              else {int(w) for w in expect})
    deadline = clock_fn() + timeout_s
    acked: set[int] = set()
    while not expect <= acked:
        nxt = transport.next_delivery(MASTER)
        if nxt is None:
            if clock_fn() >= deadline:
                raise TimeoutError(
                    f"workers never acked provisioning: "
                    f"{sorted(expect - acked)}")
            continue
        for at, msg in transport.recv(MASTER, nxt):
            if isinstance(msg, Heartbeat):
                if monitor is not None:
                    monitor.heartbeat(msg.worker, now=at)
                acked.add(msg.worker)
            elif isinstance(msg, Join) and control is not None:
                control.append((at, msg))


@dataclasses.dataclass
class RoundRecord:
    """Per-round outcome: who decoded, and what each wait policy cost.

    A thin VIEW over the scheduler's RoundTrace (DESIGN.md §11): every
    timing/wire number is read from the one trace the scheduler observed —
    the same source the flight recorder's spans are emitted from — so
    wait_stats, the recorder, and the benches can never drift apart.  The
    record adds only what the runner itself decided: the decode order used,
    and the replay/pipeline flags.
    """
    round: int
    trace: RoundTrace            # the single timing source for this round
    survivors: np.ndarray        # decode order used (first `threshold`)
    replayed: bool = False       # True when re-run after a restore
    prefetched: bool = False     # W-independent half built ahead of time
    streamed: bool = False       # decode was the incremental fold (hit)

    @property
    def n_responders(self) -> int:           # responses in by loop exit
        return len(self.trace.responders)

    @property
    def dispatched(self) -> np.ndarray:
        return self.trace.dispatched

    @property
    def coded_wait_s(self) -> float:         # wait-for-fastest-T
        return self.trace.coded_wait_s

    @property
    def all_wait_s(self) -> float:           # wait-for-all (inf = dead)
        return self.trace.all_wait_s

    @property
    def encode_s(self) -> float:             # master encode, critical path
        return self.trace.encode_s

    @property
    def decode_s(self) -> float:             # master decode+step
        return self.trace.decode_s

    @property
    def tx_bytes(self) -> int:               # wire accounting (zeros on
        return self.trace.tx_bytes           # the simulated backend)

    @property
    def rx_bytes(self) -> int:
        return self.trace.rx_bytes

    @property
    def tx_frames(self) -> int:
        return self.trace.tx_frames

    @property
    def rx_frames(self) -> int:
        return self.trace.rx_frames

    @property
    def critical_path_s(self) -> float:
        return self.trace.critical_path_s


class ClusterRunner:
    """Drives ``iters`` protocol rounds through the event scheduler.

    One runner = one training run (like engine.train); ``run()`` starts
    from the initial weights every call.

    Two transports, one round loop (DESIGN.md §7):

      * ``latency`` given — in-process simulation: the scheduler enacts the
        workers and the runner computes the whole round on the master via
        ``engine.round_fn`` with the observed responder order.
      * ``latency=None`` + a real transport (socket_transport.py) — actual
        worker processes evaluate f(X̃_i, W̃_i); the runner encodes + ships
        the round's weight shares, decodes the first-``threshold`` received
        payloads via ``engine.update_fn``, and the wall clock replaces the
        simulated clock.  ``provision()`` must run once before rounds.

    Pipelining (DESIGN.md §9) — ``pipeline`` selects how much master-side
    work leaves the critical path; every mode stays bit-identical to
    ``train_reference`` on the observed trace:

      * ``"off"``       — the sequential loop: encode -> dispatch -> wait ->
        decode, all serial.
      * ``"prefetch"``  — a RoundPrefetcher thread builds round t+1's
        W-independent context (key split, fresh masks + their encoded
        contribution, batch draw, decode-coefficient prefixes) while round
        t is in flight; the critical path keeps only the W-dependent encode
        half.
      * ``"streaming"`` — decode.StreamingDecoder folds each share into the
        Lagrange reconstruction as it arrives (predicted-order coefficient
        columns); after the threshold-th arrival only ONE fold remains.
      * ``"full"``      — both.

    On a real transport the overlap is EXECUTED (threads + incremental
    folds, components measured on the wall clock); in simulation it is
    MODELED — ``encode_cost_s``/``decode_cost_s`` are charged to the
    SimClock, scaled down by what each mode hides: prefetch leaves the
    K/(K+T) data-row fraction of the encode; streaming leaves 1/threshold
    of the decode on rounds whose subset prediction hits, and the FULL
    decode cost on misses (the fallback batch decode a real decoder pays).

    Knobs beyond the common cfg/latency/transport:

      * ``engine`` — ``"exact"`` (field protocol, default) or ``"alcc"``
        (real-valued coding, DESIGN.md §14; see the module docstring for
        what changes — and what is refused — under ALCC).
      * ``eta`` — step size; None auto-tunes 1/L by power iteration.
      * ``round_timeout_s`` / ``heartbeat_timeout_s`` — starvation and
        failure-detector walls (sim clock when simulated, wall clock live).
      * ``straggler_factor`` / ``exclude_stragglers`` — EWMA-based
        speculative exclusion of known-slow workers while the fast set
        strictly exceeds the recovery threshold.
      * ``collect_all`` — hold rounds open past the decode so the
        wait-for-all counterfactual is measured on the same trace.
      * ``spares`` / ``masters`` / ``join_schedule`` — elastic membership
        and the sharded master role (DESIGN.md §13, exact engine only).
      * ``recorder`` / ``metrics`` — the §11 flight recorder hooks; free
        when None.
    """

    def __init__(self, cfg: CPMLConfig, key, x, y,
                 latency: LatencyModel | None = None, *,
                 eta: float | None = None,
                 transport: Transport | None = None,
                 round_timeout_s: float = math.inf,
                 heartbeat_timeout_s: float = math.inf,
                 straggler_factor: float = 3.0,
                 master_overhead_s: float = 0.0,
                 exclude_stragglers: bool = True,
                 collect_all: bool = False,
                 pipeline: str = "off",
                 encode_cost_s: float = 0.0,
                 decode_cost_s: float = 0.0,
                 recorder=None,
                 metrics: MetricsRegistry | None = None,
                 spares: int = 0,
                 masters: int = 1,
                 join_schedule: dict[int, int] | None = None,
                 engine: str = "exact"):
        # heartbeat_timeout_s defaults to inf: in the simulation, true
        # deaths surface as round starvation (-> mark_failed) and slowness
        # as the EWMA straggler stat; a finite timeout models a gossip-style
        # failure detector and must exceed the worst healthy round, or a
        # single long round makes healthy-but-quiet workers look dead.
        assert pipeline in PIPELINE_MODES, (
            f"pipeline={pipeline!r} not in {PIPELINE_MODES}")
        assert engine in ENGINES, f"engine={engine!r} not in {set(ENGINES)}"
        self.engine_name = engine
        self.eng = ENGINES[engine]
        if engine == "alcc":
            # the float engine keeps the round loop but not the exact-only
            # machinery: pipelining splits a FIELD matmul, and the sharded
            # master / elastic spare points rely on bit-identical re-encode
            assert pipeline == "off", "pipeline modes are exact-engine only"
            assert masters == 1 and spares == 0 and not join_schedule, (
                "sharded masters / elastic membership are exact-engine only")
        # Elastic membership (DESIGN.md §13): ``spares`` extra Lagrange
        # evaluation points are encoded up front — the coding scheme's
        # points are consecutive, so extending N to N+spares leaves shares
        # 0..N-1 and every decode over them bit-identical to the fixed-N
        # scheme.  A spare slot carries no live worker until a JOIN (late
        # Join frame over the wire, or ``join_schedule={slot: round}`` in
        # simulation) or a LEAVE replacement admits it.  spares == 0 and no
        # join schedule keeps today's fixed-fleet behavior exactly.
        self.base_n = cfg.N
        if spares:
            cfg = dataclasses.replace(cfg, N=cfg.N + spares)
        self.cfg = cfg
        self.elastic = spares > 0 or bool(join_schedule)
        # Sharded master group (DESIGN.md §13): S > 1 splits the master's
        # per-round encode + streaming-decode over contiguous d-slices.
        # Bit-identical (randomness at full shape); used on the distributed
        # paths — the in-process simulation traces the whole round as one
        # jitted function, where sharding the master has nothing to shard.
        self.masters = int(masters)
        self.master_group = (MasterGroup(cfg, self.masters)
                             if self.masters > 1 else None)
        ksetup, self.kloop = jax.random.split(key)
        self.state = self.eng.setup(
            cfg, ksetup, x, y,
            dataset_encoder=(self.master_group.encode_dataset
                             if self.master_group is not None else None))
        self.eta = (self.eng.lipschitz_eta(self.state.xq_real)
                    if eta is None else eta)
        if engine == "alcc":
            # every least-squares decode appends its conditioning / error-
            # budget info here; wait_stats["alcc"] and the obs instants
            # read it back per round
            self.alcc_info: list[dict] = []
            self._round = self.eng.round_fn(cfg, self.state, self.eta,
                                            info_sink=self.alcc_info)
            self._update = self.eng.update_fn(cfg, self.state, self.eta,
                                              info_sink=self.alcc_info)
        else:
            self.alcc_info = None
            self._round = self.eng.round_fn(cfg, self.state, self.eta)
            self._update = self.eng.update_fn(cfg, self.state, self.eta)
        self._round_split = self.eng.round_fn_split(cfg, self.state, self.eta)
        self._update_parts = self.eng.update_from_parts_fn(cfg, self.state,
                                                           self.eta)
        self.pipeline = pipeline
        self.encode_cost_s = encode_cost_s
        self.decode_cost_s = decode_cost_s
        self._w_shape = (x.shape[1], cfg.c)       # internal w2 shape
        self._prefetcher: RoundPrefetcher | None = None
        self._last_order: np.ndarray | None = None    # prediction source
        self.latency = latency
        self.round_timeout_s = round_timeout_s
        self.exclude_stragglers = exclude_stragglers
        self.collect_all = collect_all
        self.scheduler = EventScheduler(cfg.N, latency, transport,
                                        master_overhead_s=master_overhead_s,
                                        recorder=recorder)
        # flight recorder (DESIGN.md §11): bound to the SCHEDULER's clock so
        # sim and wall runs emit the same span shape through the same call
        # sites; the default NullRecorder keeps every site a no-op.
        self.obs = self.scheduler.obs
        self.obs.bind_clock(self.scheduler.time.now)
        # metrics are always on, like the wire byte counters they aggregate
        # (a handful of float ops per round; gated with the recorder in
        # bench_cluster's trace_overhead entry)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._init_metrics()
        if self.distributed and math.isinf(round_timeout_s):
            # a real cluster must be able to give up on silence
            self.round_timeout_s = 300.0
        self.monitor = HeartbeatMonitor(self.base_n,
                                        timeout_s=heartbeat_timeout_s,
                                        straggler_factor=straggler_factor,
                                        now=self.scheduler.clock)
        # membership starts as the base fleet; the spare slots (base_n..N-1)
        # hold pre-encoded shares awaiting admission.  The scheduler reads
        # its default worker set off the live membership from here on.
        self.membership = ClusterMembership(
            range(self.base_n), monitor=self.monitor,
            spares=range(self.base_n, cfg.N))
        self.scheduler.bind_membership(self.membership)
        for w, at_round in (join_schedule or {}).items():
            self.membership.schedule_join(w, at_round)
        self.w2 = self.eng._w_internal(cfg, self.state.w)
        self.records: dict[int, RoundRecord] = {}
        self.traces: dict[int, RoundTrace] = {}
        self.restarts = 0

    @property
    def distributed(self) -> bool:
        """True when real worker processes compute (socket transport)."""
        return self.latency is None

    # ------------------------------------------------------------------
    # Observability (DESIGN.md §11)
    # ------------------------------------------------------------------

    def _init_metrics(self) -> None:
        m = self.metrics
        self._m_rounds = m.counter(
            "cpml_rounds_total", "completed training rounds")
        self._m_starved = m.counter(
            "cpml_starved_rounds_total",
            "rounds with fewer than threshold responses in the timeout")
        self._m_excluded = m.counter(
            "cpml_straggler_exclusions_total",
            "worker-rounds speculatively excluded from dispatch")
        self._m_marked_dead = m.counter(
            "cpml_heartbeat_misses_total",
            "workers marked dead after round-timeout silence")
        self._m_prefetch = m.counter(
            "cpml_prefetch_hits_total",
            "rounds served from a prefetched W-independent context")
        self._m_folds = m.counter(
            "cpml_stream_folds_total", "eager streaming-decoder folds")
        self._m_streamed = m.counter(
            "cpml_streamed_rounds_total",
            "rounds decoded by the incremental fold (prediction hits)")
        self._m_tx = m.counter(
            "cpml_wire_tx_bytes_total", "wire bytes enqueued during rounds")
        self._m_rx = m.counter(
            "cpml_wire_rx_bytes_total", "wire bytes received during rounds")
        self._m_wait = m.histogram(
            "cpml_round_wait_seconds",
            "dispatch to threshold-th arrival, per round")
        self._m_cp = m.histogram(
            "cpml_round_critical_path_seconds",
            "encode + wait + decode, per round")
        self._m_alive = m.gauge(
            "cpml_workers_alive", "dispatchable workers at last round")
        self._m_epoch = m.gauge(
            "cpml_epoch", "membership epoch at the last round fence")
        self._m_members = m.gauge(
            "cpml_members_alive", "member slots at the last round fence")
        self._m_joins = m.counter(
            "cpml_member_joins_total", "workers admitted mid-run")
        self._m_leaves = m.counter(
            "cpml_member_leaves_total", "members permanently retired")
        self._m_warm = m.gauge(
            "cpml_xla_warm_compile_seconds",
            "max worker-reported XLA warm-compile wall (needs tracing + v2 "
            "wire)")
        if self.engine_name == "alcc":
            self._m_alcc_cond = m.gauge(
                "cpml_alcc_decode_cond",
                "condition number of the last round's least-squares decode")
            self._m_alcc_budget = m.gauge(
                "cpml_alcc_error_budget",
                "a-priori absolute decode-error bound of the last round "
                "(cond * eps32 * max|evaluation|)")
            self._m_alcc_fallback = m.counter(
                "cpml_alcc_decode_fallbacks_total",
                "rounds decoded by the overdetermined all-responder "
                "fallback (square system over cond_max)")

    def _observe_round(self, t: int, trace: RoundTrace,
                       rec: RoundRecord) -> None:
        """Emit the round's derived spans + update the metrics registry.

        Runs while the ``round`` span is still open, so the derived spans
        nest under it.  The encode/wait/decode intervals are reconstructed
        from the SAME RoundTrace fields wait_stats aggregates — on the sim
        clock they are the pre/post charges, on the wall clock the measured
        components — which is what makes the recorder and wait_stats
        reconcile exactly (tests/test_obs.py, bench trace gates).
        """
        obs = self.obs
        if obs.enabled:
            if trace.encode_s > 0:
                obs.add_span("encode", trace.t_start - trace.encode_s,
                             trace.t_start, round=t)
            obs.add_span("wait", trace.t_start, trace.t_first_R, round=t,
                         responders=rec.n_responders)
            t_ready = trace.t_ready
            if math.isfinite(t_ready) and trace.decode_s > 0:
                obs.add_span("decode", t_ready - trace.decode_s, t_ready,
                             round=t, streamed=rec.streamed)
            for w, spans in trace.worker_traces.items():
                obs.add_process_spans(f"worker{int(w)}", spans, round=t)
        self._m_rounds.inc()
        if self.alcc_info:
            # the decode that just ran appended its conditioning info
            info = self.alcc_info[-1]
            self._m_alcc_cond.set(float(info["cond"]))
            self._m_alcc_budget.set(float(info["abs_err_budget"]))
            if info["fallback"]:
                self._m_alcc_fallback.inc()
            self.obs.instant("alcc_decode", round=t,
                             cond=float(info["cond"]),
                             err_budget=float(info["abs_err_budget"]),
                             fallback=bool(info["fallback"]))
        if rec.prefetched:
            self._m_prefetch.inc()
        if rec.streamed:
            self._m_streamed.inc()
        self._m_tx.inc(trace.tx_bytes)
        self._m_rx.inc(trace.rx_bytes)
        self._m_wait.observe(trace.coded_wait_s)
        self._m_cp.observe(trace.critical_path_s)
        self._m_alive.set(len(self._alive(self.scheduler.clock)))
        for spans in trace.worker_traces.values():
            for item in spans:
                # the worker attaches its provisioning-window XLA compile
                # to its first traced result (launch/cpml_worker.py)
                if item and item[0] == "warm_compile" and len(item) == 3:
                    self._m_warm.set(max(self._m_warm.value,
                                         float(item[2]) - float(item[1])))

    # ------------------------------------------------------------------
    # Pipeline plumbing (DESIGN.md §9)
    # ------------------------------------------------------------------

    @property
    def prefetching(self) -> bool:
        return self.pipeline in ("prefetch", "full")

    @property
    def streaming(self) -> bool:
        return self.pipeline in ("streaming", "full")

    def _predicted_order(self) -> np.ndarray | None:
        """Forecast next round's responder order: last round's arrivals.

        Read racily by the prefetch thread — the prediction only steers
        which decode coefficients are precomputed/folded eagerly, never
        which decode runs, so staleness costs a fallback, not correctness.
        """
        return self._last_order

    def _build_ctx(self, t: int, iters: int) -> RoundContext:
        """Round t's W-independent context (runs on the prefetch thread)."""
        cfg = self.cfg
        key_t = self.eng.round_key(self.kloop, t)
        kq, mask_shares = self.eng.round_mask_context(cfg, key_t, self._w_shape)
        bidx = next_np = None
        if cfg.batch_rows is not None:
            bidx = self.eng.draw_batch(cfg, self.kloop, iters,
                                     self.state.mk, t)
            if self.distributed and t + 1 < iters:
                # round t+1's indices ride in round t's dispatch so the
                # workers pre-slice their coded sub-batch while idle
                next_np = np.asarray(self.eng.draw_batch(
                    cfg, self.kloop, iters, self.state.mk, t + 1))
        plan = (decode.prefix_decode_plan(cfg, self._predicted_order())
                if self.streaming else None)
        # racy epoch read (prefetch thread): a transition between build and
        # use is caught at the fence, which invalidates only the plan
        return RoundContext(t=t, kq=kq,
                            mask_shares=np.asarray(mask_shares),
                            batch_idx=bidx, plan=plan, next_batch=next_np,
                            epoch=self.membership.epoch)

    def _pipeline_scope(self, iters: int):
        """Context manager owning the prefetch thread for one training run."""
        if not self.prefetching:
            return contextlib.nullcontext()
        self._prefetcher = RoundPrefetcher(
            lambda t: self._build_ctx(t, iters), start=0, stop=iters,
            recorder=self.obs)

        @contextlib.contextmanager
        def scope():
            try:
                yield
            finally:
                self._prefetcher.close()
                self._prefetcher = None

        return scope()

    def _sim_charges(self) -> tuple[float, float]:
        """(pre_s, post_s) master-side charges for the SimClock, scaled by
        what the active pipeline mode hides (class docstring).  post_s is
        the prediction-HIT fold; step_round tops it up to the full decode
        cost on rounds whose subset prediction missed."""
        cfg = self.cfg
        pre = self.encode_cost_s
        if self.prefetching:
            pre *= cfg.K / (cfg.K + cfg.T)    # mask rows precomputed
        post = self.decode_cost_s
        if self.streaming:
            post /= cfg.threshold             # one fold left after arrival
        return pre, post

    # ------------------------------------------------------------------
    # Distributed-mode provisioning: one-time worker state over the wire
    # ------------------------------------------------------------------

    def provision(self, workers=None, timeout_s: float = 60.0) -> None:
        """Ship each worker its coded dataset share + static round context.

        Sent as an EncodeShare with ``round == PROVISION_ROUND``; the worker
        acks with a Heartbeat once its share is loaded, and rounds only
        start after every dispatched worker has acked (so round-0 timing
        does not absorb worker warmup).

        ``workers=None`` provisions the current members (the historical
        whole-fleet call); an explicit subset provisions exactly those
        slots — a mid-run joiner picking up its pre-encoded spare share, or
        a resilient-restore respawn reprovisioning one dead slot.
        """
        assert self.distributed, "provision() is for real transports only"
        if workers is None:
            workers = list(self.membership.view().members)
        workers = [int(w) for w in workers]
        wall0 = _time.perf_counter()
        with self.obs.span("provision", workers=len(workers)):
            tr = self.scheduler.transport
            x_shares = np.asarray(self.state.x_shares)
            cbar = self.eng.poly_coeffs(self.cfg)
            if self.engine_name == "alcc":
                # float engine: no quantization scales to ship; the worker
                # selects its float round fn off the "protocol" marker
                cfg_kw = {"N": self.cfg.N, "K": self.cfg.K, "T": self.cfg.T,
                          "r": self.cfg.r, "c": self.cfg.c,
                          "sigma": self.cfg.sigma,
                          "batch_rows": self.cfg.batch_rows}
            else:
                cfg_kw = {"N": self.cfg.N, "K": self.cfg.K, "T": self.cfg.T,
                          "r": self.cfg.r, "c": self.cfg.c, "lx": self.cfg.lx,
                          "lw": self.cfg.lw, "lc": self.cfg.lc, "p": self.cfg.p,
                          "batch_rows": self.cfg.batch_rows}
            now = self.scheduler.clock
            for w in workers:
                payload = {"cfg": cfg_kw, "x_share": x_shares[w],
                           "cbar": cbar,
                           # ask the workers to record + piggy-back their
                           # own per-round spans (v2 wire only; a v1 peer
                           # drops the field)
                           "trace": bool(self.obs.enabled)}
                if self.engine_name == "alcc":
                    payload["protocol"] = "alcc"
                tr.send(worker_endpoint(w),
                        EncodeShare(PROVISION_ROUND, w, payload),
                        at=now)
            await_worker_acks(tr, lambda: self.scheduler.clock, set(workers),
                              self.monitor, timeout_s,
                              control=self.scheduler.control_inbox)
        self.metrics.gauge(
            "cpml_provision_seconds",
            "wall seconds from provisioning dispatch to the last worker "
            "ack (includes worker XLA warmup)").set(
                _time.perf_counter() - wall0)

    def shutdown_workers(self) -> None:
        """Ask every live member's process to exit its serve loop (departed
        slots' processes are already dead; never-admitted spares have no
        process to stop)."""
        assert self.distributed
        now = self.scheduler.clock
        for w in self.membership.view().members:
            self.scheduler.transport.send(
                worker_endpoint(w), EncodeShare(SHUTDOWN_ROUND, w), at=now)

    # ------------------------------------------------------------------
    # Elastic membership: the per-round epoch fence (DESIGN.md §13)
    # ------------------------------------------------------------------

    def _broadcast_epoch(self, view: MembershipView, t: int) -> None:
        """Fan the new epoch out to the live members (informational — the
        fence is master-side).  Epoch is a wire v2 frame; v1 peers are
        skipped so their byte stream stays bit-identical to fixed-fleet."""
        if not self.distributed:
            return
        tr = self.scheduler.transport
        peer_version = getattr(tr, "peer_version", None)
        now = self.scheduler.clock
        for w in view.members:
            ep = worker_endpoint(w)
            if peer_version is not None and peer_version(ep) < WIRE_V2:
                continue
            tr.send(ep, Epoch(view.epoch, view.members, t), at=now)

    def _admit(self, worker: int, t: int) -> None:
        """Admit one slot at the fence: distributed mode first provisions
        the joiner's pre-encoded spare share and waits for its ack, so a
        member is never dispatched before it can answer."""
        if self.distributed:
            t0 = self.scheduler.clock
            self.provision(workers=[worker], timeout_s=60.0)
            # the ack barrier (it includes the joiner's XLA warmup) stalls
            # round dispatch — credit the live fleet, whose only heartbeat
            # source is the per-round acks the stall suspended
            self.monitor.credit_stall(self.scheduler.clock - t0,
                                      now=self.scheduler.clock)
        now = self.scheduler.clock
        self.membership.admit(worker, t, now=now)
        self._m_joins.inc()
        self.obs.instant("member_join", round=t, worker=int(worker),
                         epoch=self.membership.epoch)

    def _membership_fence(self, t: int) -> MembershipView:
        """The round fence: apply every due membership transition, then
        snapshot.  Round t's dispatch set, decode matrix and DecodePlan all
        derive from the ONE view returned here — a transition can never mix
        two fleets inside a round.  Non-elastic runs take the no-transition
        fast path and keep the historical per-round speculative exclusion
        semantics bit-identically."""
        if self.elastic:
            now = self.scheduler.clock
            # JOIN requests drained off the wire (socket: late HELLO+Join)
            for _, msg in self.scheduler.control_inbox:
                self.membership.schedule_join(msg.worker, msg.at_round)
            self.scheduler.control_inbox.clear()
            span = None
            pre_epoch = self.membership.epoch
            # LEAVE: a member the failure detector declared dead is retired
            # for good (not re-excluded every round); in simulation a spare
            # immediately replaces it (the scheduler enacts the new slot) —
            # on a real transport replacements arrive as JOINs from actual
            # late worker processes.
            for w in list(self.membership.view().members):
                if w in self.monitor.workers and self.monitor.is_dead(
                        w, now=now):
                    if span is None:
                        span = self.obs.begin("membership_transition",
                                              round=t)
                    self.membership.leave(w, t, now=now)
                    self._m_leaves.inc()
                    self.obs.instant("member_leave", round=t, worker=int(w),
                                     epoch=self.membership.epoch)
                    if not self.distributed:
                        spare = self.membership.take_spare()
                        if spare is not None:
                            self._admit(spare, t)
            for w in self.membership.due_joins(t):
                if span is None:
                    span = self.obs.begin("membership_transition", round=t)
                self._admit(w, t)
            view = self.membership.view()
            if view.epoch != pre_epoch:
                self._broadcast_epoch(view, t)
            if span is not None:
                self.obs.end(span)
        else:
            view = self.membership.view()
        self._m_epoch.set(view.epoch)
        self._m_members.set(len(view))
        return view

    # ------------------------------------------------------------------
    # Dispatch-set policy: monitor-alive workers, minus known stragglers
    # while the fast set strictly exceeds the recovery threshold.
    # ------------------------------------------------------------------

    def _alive(self, now: float) -> np.ndarray:
        return np.array(
            [i for i in self.monitor.workers
             if not self.monitor.is_dead(i, now=now)],
            dtype=np.int64)

    def dispatch_set(self, view: MembershipView | None = None) -> np.ndarray:
        now = self.scheduler.clock
        alive = self._alive(now)
        if view is not None:
            # epoch fence: only this round's membership snapshot dispatches
            # (the monitor tracks members exactly, so this is a no-op guard
            # against a transition racing between fence and dispatch)
            alive = np.asarray([w for w in alive if w in view],
                               dtype=np.int64)
        if self.exclude_stragglers:
            fast = self.monitor.survivors(now=now)
            if view is not None:
                fast = np.asarray([w for w in fast if w in view],
                                  dtype=np.int64)
            # STRICTLY more than threshold: speculative exclusion must leave
            # slack, because the fast set can still contain an undetected
            # dead worker — dispatching exactly `threshold` workers means a
            # single silent failure starves the round.
            if len(fast) > self.cfg.threshold:
                self._m_excluded.inc(len(alive) - len(fast))
                return fast
        return alive

    # ------------------------------------------------------------------
    # One round
    # ------------------------------------------------------------------

    def step_round(self, t: int, iters: int, replayed: bool = False
                   ) -> RoundTrace:
        """One traced protocol round: the ``round`` span brackets the whole
        critical path, the derived encode/wait/decode spans and metrics are
        emitted while it is open (so they nest), and a starved round leaves
        an instant marker + counter bump before the error propagates to the
        resilient loop."""
        rspan = self.obs.begin("round", round=t, replayed=replayed)
        try:
            trace = self._step_round_inner(t, iters, replayed)
            self._observe_round(t, trace, self.records[t])
            return trace
        except ClusterDecodeError:
            self.obs.instant("starved", round=t)
            self._m_starved.inc()
            raise
        finally:
            self.obs.end(rspan)

    def _step_round_inner(self, t: int, iters: int, replayed: bool = False
                          ) -> RoundTrace:
        cfg = self.cfg
        view = self._membership_fence(t)
        workers = self.dispatch_set(view)
        if len(workers) < cfg.threshold:
            raise ClusterDecodeError(
                f"round {t}: only {len(workers)} dispatchable workers < "
                f"recovery threshold {cfg.threshold}")
        ctx = (self._prefetcher.get(t)
               if self._prefetcher is not None else None)
        if ctx is not None and ctx.epoch != view.epoch:
            # the context was prefetched under an older fleet: only its
            # DecodePlan referenced that fleet (predicted responders) — the
            # key split, masks and batch are pure functions of (kloop, t)
            # and stay valid.  Drop the plan; the decode falls back to the
            # observed-order path (a performance miss, never a wrong decode)
            ctx.plan = None
            ctx.epoch = view.epoch
            self.obs.instant("prefetch_epoch_invalidated", round=t,
                             epoch=view.epoch)
        key_t = None if ctx is not None else self.eng.round_key(self.kloop, t)
        # the subset the streaming decode would fold against this round
        # (ctx.plan when prefetched — possibly one round staler — else the
        # last observed order); used for the decoder plan in distributed
        # mode and for honest streamed-flag reporting in simulation
        pred_subset = None
        if self.streaming:
            if ctx is not None and ctx.plan is not None:
                pred_subset = ctx.plan.subset
            elif ctx is None:
                pred = self._predicted_order()
                if pred is not None and len(pred) >= cfg.threshold:
                    pred_subset = frozenset(
                        int(w) for w in pred[: cfg.threshold])
        if ctx is not None:
            bidx = ctx.batch_idx
        else:
            bidx = (self.eng.draw_batch(cfg, self.kloop, iters,
                                      self.state.mk, t)
                    if cfg.batch_rows is not None else None)
        payloads = None
        enc_t0 = _time.perf_counter()
        if self.distributed:
            # encode THIS round's weight shares and ship one to each worker;
            # field elements are exact int32, so the share a worker process
            # receives is bit-identical to the one the in-process round
            # would have traced from the same key.  With a prefetched ctx
            # only the W-dependent half runs here (DESIGN.md §9).
            if self.master_group is not None:
                # sharded masters: each of the S masters encodes its own
                # contiguous d-slice (bit-identical: randomness full-shape)
                w_shares = (self.master_group.encode_round_shares_split(
                                ctx.kq, ctx.mask_shares, self.w2)
                            if ctx is not None else
                            self.master_group.encode_round_shares(
                                key_t, self.w2))       # (N, d, c, r)
            elif ctx is not None:
                w_shares = np.asarray(self.eng.encode_round_shares_split(
                    cfg, ctx.kq, ctx.mask_shares, self.w2))  # (N, d, c, r)
            else:
                w_shares = np.asarray(self.eng.encode_round_shares(
                    cfg, key_t, self.w2))
            batch_np = None if bidx is None else np.asarray(bidx)
            # round t+1's batch indices were drawn by the prefetch thread,
            # off the critical path (ctx.next_batch); sequential mode ships
            # none and the worker slices on receipt as before
            next_np = None if ctx is None else ctx.next_batch
            payloads = {int(w): {"w_share": w_shares[int(w)],
                                 "batch": batch_np,
                                 "next_batch": next_np}
                        for w in workers}
        encode_wall_s = _time.perf_counter() - enc_t0

        decoder = None
        on_result = None
        if self.streaming and self.distributed:
            plan = (ctx.plan if ctx is not None and ctx.plan is not None
                    else decode.prefix_decode_plan(
                        cfg, self._predicted_order()))
            decoder = (self.master_group.make_decoder(plan,
                                                      self._w_shape[0])
                       if self.master_group is not None
                       else decode.StreamingDecoder(cfg, plan))

            def on_result(w, payload, _d=decoder):
                self._m_folds.inc()
                self.obs.instant("fold", round=t, worker=int(w))
                _d.fold(w, payload)
        pre_s = post_s = 0.0
        if not self.distributed:
            pre_s, post_s = self._sim_charges()
        if self._prefetcher is not None:
            # critical-path master work is done; let the producer build
            # round t+1's context during the collect wait we enter now
            self._prefetcher.release()
        trace = self.scheduler.dispatch_round(
            t, cfg.threshold, workers=workers, monitor=self.monitor,
            timeout_s=self.round_timeout_s, payloads=payloads,
            collect_all=self.collect_all, pre_s=pre_s, post_s=post_s,
            on_result=on_result)
        if not math.isfinite(trace.t_first_R):
            # non-responders within the timeout are presumed dead
            for w in workers:
                if int(w) not in trace.arrivals:
                    self.monitor.mark_failed(int(w))
                    self._m_marked_dead.inc()
            raise ClusterDecodeError(
                f"round {t}: {len(trace.responders)} responses < threshold "
                f"{cfg.threshold} within {self.round_timeout_s}s")

        streamed = False
        alcc = self.engine_name == "alcc"
        dec_t0 = _time.perf_counter()
        if decoder is not None:
            # the streaming path never needs the batch decode matrix on a
            # hit — the decoder's accumulator IS the decode, and on a miss
            # finish() resolves its own (cached) matrix inside the timed
            # window below, so the fallback solve is attributed honestly
            order = np.asarray(trace.responders[: cfg.threshold],
                               dtype=np.int32)
        elif alcc:
            # float engine: the least-squares decode picks its own row
            # count (the ill-conditioned fallback reads ALL responders)
            _, order, _ = self.eng.survivor_round_info(cfg, trace.responders)
        else:
            dmat, order = engine.survivor_round(cfg, trace.responders)
        if self.distributed:
            if decoder is not None:
                # the shares are already folded (or retained) — finish is
                # one fold on a prediction hit, a batch decode on a miss
                parts = decoder.finish(order)
                streamed = decoder.streamed
                self.w2 = self._update_parts(self.w2, parts, bidx)
            elif alcc:
                fastest = np.stack([np.asarray(trace.payloads[int(w)],
                                               dtype=np.float32)
                                    for w in order])
                self.w2 = self._update(self.w2, fastest, order, bidx)
            else:
                # decode from the payloads the responders actually sent
                fastest = np.stack([np.asarray(trace.payloads[int(w)],
                                               dtype=np.int32)
                                    for w in order])
                self.w2 = self._update(self.w2, jnp.asarray(fastest),
                                       jnp.asarray(dmat, jnp.int32), bidx)
            self.w2.block_until_ready()   # honest decode_s measurement
        elif ctx is not None:
            self.w2 = self._round_split(ctx.kq, ctx.mask_shares, self.w2,
                                        jnp.asarray(dmat, jnp.int32),
                                        jnp.asarray(order, jnp.int32), bidx)
        elif alcc:
            self.w2 = self._round(key_t, self.w2, order, bidx)
        else:
            self.w2 = self._round(key_t, self.w2,
                                  jnp.asarray(dmat, jnp.int32),
                                  jnp.asarray(order, jnp.int32), bidx)
        decode_wall_s = _time.perf_counter() - dec_t0
        if self.distributed:
            # real transport: the scheduler cannot see master-side encode/
            # decode walls — record the measured components on the trace
            trace.encode_s = encode_wall_s
            trace.decode_s = decode_wall_s
            trace.t_ready = self.scheduler.clock
        else:
            # simulation: was this round a streaming hit?  A real decoder
            # folds eagerly only when the observed threshold subset matches
            # the prediction — on a miss it pays the full batch decode, so
            # charge the remaining decode cost to the clock (the optimistic
            # 1/threshold fold was charged inside dispatch_round)
            streamed = (self.streaming and pred_subset is not None
                        and frozenset(int(w) for w in order) == pred_subset)
            if self.streaming and not streamed:
                miss_extra = self.decode_cost_s - post_s
                if miss_extra > 0:
                    self.scheduler.time.advance_to(
                        self.scheduler.clock + miss_extra)
                    trace.decode_s = post_s + miss_extra
                    trace.t_ready = self.scheduler.clock
        self._last_order = np.asarray(trace.responders).copy()
        self.traces[t] = trace
        self.records[t] = RoundRecord(
            round=t, trace=trace, survivors=order.copy(), replayed=replayed,
            prefetched=ctx is not None, streamed=streamed)
        return trace

    # ------------------------------------------------------------------
    # Training drivers
    # ------------------------------------------------------------------

    def run(self, iters: int):
        """Plain run: any starved round raises ClusterDecodeError."""
        self._reset()
        with self._pipeline_scope(iters):
            for t in range(iters):
                self.step_round(t, iters)
        return self.eng._w_public(self.cfg, self.w2)

    def run_resilient(self, iters: int, ckpt_manager,
                      checkpoint_every: int = 5, max_retries: int = 3,
                      respawn: Callable[[int, int], None] | None = None):
        """Checkpointed run: a starved round restores the last checkpoint,
        reprovisions dead workers, and replays.

        ``respawn(worker, step)`` is the real-transport replacement hook:
        called for each dead slot after a restore, it must start a fresh
        worker process for that slot (the caller owns process management);
        the runner then reprovisions the slot over the wire and waits for
        its ack before replaying.  In simulation the latency model's
        ``revive`` plays the same role and ``respawn`` is unused.
        """
        self._reset()
        replaying = {"flag": False}

        def step_fn(state, t):
            self.w2 = jnp.asarray(state["train"]["w2"])
            self.step_round(t, iters, replayed=replaying["flag"])
            return {"train": {"w2": np.asarray(self.w2)}}

        def on_restore(step):
            replaying["flag"] = True
            t0 = self.scheduler.clock
            for i, ws in list(self.monitor.workers.items()):
                if not ws.alive:
                    if self.latency is not None:
                        self.latency.revive(i, at_round=step)
                    elif respawn is not None:
                        # real transport: spawn a fresh process for the dead
                        # slot, re-ship its share, and only revive the slot
                        # once the new process acked provisioning
                        respawn(i, step)
                        self.provision(workers=[i], timeout_s=60.0)
                    self.monitor.revive(i, now=self.scheduler.clock)
            # respawn + reprovision blocked dispatch; credit the healthy
            # fleet the stall so the replay's first fence doesn't read
            # their barrier-long silence as death
            self.monitor.credit_stall(self.scheduler.clock - t0,
                                      now=self.scheduler.clock)

        loop = ResilientLoop(ckpt_manager, checkpoint_every=checkpoint_every,
                             max_retries=max_retries, on_restore=on_restore)
        state0 = {"train": {"w2": np.asarray(self.w2)}}
        ckpt_manager.save(0, state0)
        ckpt_manager.wait()
        with self._pipeline_scope(iters):
            # a restore rewinds t; RoundPrefetcher.get resets its producer,
            # and contexts are pure functions of (kloop, t), so the replay
            # re-derives identical masks/batches
            loop.run(state0, step_fn, start_step=0, num_steps=iters)
        self.restarts = loop.restarts
        return self.eng._w_public(self.cfg, self.w2)

    def _reset(self):
        self.w2 = self.eng._w_internal(self.cfg, self.state.w)
        self.records.clear()
        self.traces.clear()
        self._last_order = None
        if self.alcc_info is not None:
            self.alcc_info.clear()

    # ------------------------------------------------------------------
    # Trace export + stats
    # ------------------------------------------------------------------

    def survivor_fn(self) -> Callable[[int], np.ndarray]:
        """Responder trace -> survivor_fn for engine.train/train_reference.

        Replaying it through the static-schedule drivers reproduces this
        run's weights bit-for-bit (the decode order fed to round_fn is
        identical).
        """
        trace = {t: rec.survivors for t, rec in self.records.items()}
        return lambda t: trace[t]

    def wait_stats(self) -> dict[str, dict[str, float]]:
        """Per-round completion-time stats: coded first-T vs wait-for-all,
        plus the master-side encode/decode components and the critical path
        (encode + wait + decode) the pipeline modes shrink."""
        recs = sorted(self.records.values(), key=lambda r: r.round)
        coded = np.array([r.coded_wait_s for r in recs])
        allw = np.array([r.all_wait_s for r in recs])
        enc = np.array([r.encode_s for r in recs])
        dec = np.array([r.decode_s for r in recs])
        stats = {"coded_T": wait_summary(coded),
                 "wait_all": wait_summary(allw[np.isfinite(allw)]),
                 "encode": wait_summary(enc),
                 "decode": wait_summary(dec),
                 "critical_path": wait_summary(enc + coded + dec),
                 # per-round bytes/frames on the wire (socket backend; all
                 # zero on the simulation, where nothing is serialized)
                 "wire_tx_bytes": wait_summary([r.tx_bytes for r in recs]),
                 "wire_rx_bytes": wait_summary([r.rx_bytes for r in recs]),
                 "wire_tx_frames": wait_summary([r.tx_frames for r in recs]),
                 "wire_rx_frames": wait_summary([r.rx_frames for r in recs]),
                 "rounds": {"n": float(len(recs)),
                            "dead_rounds": float(np.sum(~np.isfinite(allw))),
                            "prefetched": float(sum(r.prefetched
                                                    for r in recs)),
                            "streamed": float(sum(r.streamed
                                                  for r in recs))}}
        wire_totals = getattr(self.scheduler.transport, "wire_totals", None)
        if wire_totals is not None:
            # run-level totals include provisioning (the big x_share ship)
            # and heartbeats that landed between rounds
            stats["wire_totals"] = {k: float(v)
                                    for k, v in wire_totals().items()}
        # elastic membership summary (BENCH_cluster.json rides these):
        # epoch 0 / joins 0 / leaves 0 on a fixed-membership run
        trans = self.membership.transitions
        stats["membership"] = {
            "epoch": float(self.membership.epoch),
            "members": float(len(self.membership)),
            "spares_left": float(len(self.membership.spares)),
            "joins": float(sum(tr.kind == "join" for tr in trans)),
            "leaves": float(sum(tr.kind == "leave" for tr in trans)),
        }
        if self.master_group is not None:
            stats["masters"] = self.master_group.group_stats()
        if self.alcc_info:
            # analog-decode health: conditioning of the per-round solve and
            # the a-priori float error bound (cond * eps32 * max|eval|) —
            # the quantities DESIGN.md §14's tolerance argument rests on
            stats["alcc"] = {
                "cond": wait_summary([i["cond"] for i in self.alcc_info]),
                "abs_err_budget": wait_summary(
                    [i["abs_err_budget"] for i in self.alcc_info]),
                "fallbacks": {"n": float(sum(
                    1 for i in self.alcc_info if i["fallback"]))},
            }
        return stats
