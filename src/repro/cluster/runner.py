"""ClusterRunner: coded training driven by the cluster runtime.

Division of labor (DESIGN.md §7): the scheduler moves messages and time;
ALL gradient numerics run through the exact round/update functions
train()/train_reference() use, with the decode matrix and responder order
observed from the runtime.  In the in-process simulation the whole round is
``engine.round_fn`` on the master; over the socket transport real worker
processes evaluate f(X̃_i, W̃_i) and their deserialized payloads feed
``engine.update_fn`` — the same decode+step the simulated round composes.
Consequence: a ClusterRunner run — simulated or live — is BIT-IDENTICAL to
``engine.train_reference`` replaying the same responder trace
(tests/test_cluster.py, tests/test_socket_cluster.py), so the cluster
layer can never silently change training semantics, only timing and
placement.

Resilience integration (runtime/resilience.py):

  * HeartbeatMonitor — results/acks feed it on the SIMULATED clock; workers
    that stop heartbeating (dead) drop out of the dispatch set, and known
    stragglers are speculatively excluded from dispatch while the fast set
    STRICTLY exceeds the recovery threshold (exact coverage leaves no slack
    for an undetected death).
  * ResilientLoop + CheckpointManager — ``run_resilient(...)``
    checkpoints every k rounds; a round that starves (fewer than
    ``threshold`` responses inside the timeout) raises ClusterDecodeError,
    the loop restores the last checkpoint, and the ``on_restore`` hook
    reprovisions dead workers (latency.revive + monitor.revive) before
    replay — mid-run worker death costs a rollback, not the run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.latency import LatencyModel
from repro.cluster.messages import (
    MASTER,
    PROVISION_ROUND,
    SHUTDOWN_ROUND,
    EncodeShare,
    Heartbeat,
    worker_endpoint,
)
from repro.cluster.scheduler import ClusterDecodeError, EventScheduler, RoundTrace
from repro.cluster.transport import Transport
from repro.core.protocol import engine
from repro.core.protocol.config import CPMLConfig
from repro.runtime.resilience import HeartbeatMonitor, ResilientLoop


def wait_summary(a) -> dict[str, float]:
    """mean/p50/p95/total of a wait-time series (inf stats when empty).

    The one aggregation both runner.wait_stats and bench_cluster.py report,
    so BENCH_cluster.json and live stats can never disagree on keys."""
    a = np.asarray(a, dtype=float)
    if a.size == 0:
        return {"mean": math.inf, "p50": math.inf, "p95": math.inf,
                "total": math.inf}
    return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)), "total": float(a.sum())}


def await_worker_acks(transport: Transport, clock_fn, n_workers: int,
                      monitor, timeout_s: float) -> None:
    """Block until every worker process has acked provisioning with a
    Heartbeat (shared by ClusterRunner and MPCClusterRunner, so both
    protocols start their wall clocks after worker warmup)."""
    deadline = clock_fn() + timeout_s
    acked: set[int] = set()
    while len(acked) < n_workers:
        nxt = transport.next_delivery(MASTER)
        if nxt is None:
            if clock_fn() >= deadline:
                raise TimeoutError(
                    f"workers never acked provisioning: "
                    f"{sorted(set(range(n_workers)) - acked)}")
            continue
        for at, msg in transport.recv(MASTER, nxt):
            if isinstance(msg, Heartbeat):
                if monitor is not None:
                    monitor.heartbeat(msg.worker, now=at)
                acked.add(msg.worker)


@dataclasses.dataclass
class RoundRecord:
    """Per-round outcome: who decoded, and what each wait policy cost."""
    round: int
    survivors: np.ndarray        # decode order used (first `threshold`)
    n_responders: int            # responses in by the decode instant
    dispatched: np.ndarray
    coded_wait_s: float          # wait-for-fastest-T (the paper's policy)
    all_wait_s: float            # wait-for-all counterfactual (inf = dead)
    replayed: bool = False       # True when re-run after a restore


class ClusterRunner:
    """Drives ``iters`` protocol rounds through the event scheduler.

    One runner = one training run (like engine.train); ``run()`` starts
    from the initial weights every call.

    Two transports, one round loop (DESIGN.md §7):

      * ``latency`` given — in-process simulation: the scheduler enacts the
        workers and the runner computes the whole round on the master via
        ``engine.round_fn`` with the observed responder order.
      * ``latency=None`` + a real transport (socket_transport.py) — actual
        worker processes evaluate f(X̃_i, W̃_i); the runner encodes + ships
        the round's weight shares, decodes the first-``threshold`` received
        payloads via ``engine.update_fn``, and the wall clock replaces the
        simulated clock.  ``provision()`` must run once before rounds.
    """

    def __init__(self, cfg: CPMLConfig, key, x, y,
                 latency: LatencyModel | None = None, *,
                 eta: float | None = None,
                 transport: Transport | None = None,
                 round_timeout_s: float = math.inf,
                 heartbeat_timeout_s: float = math.inf,
                 straggler_factor: float = 3.0,
                 master_overhead_s: float = 0.0,
                 exclude_stragglers: bool = True,
                 collect_all: bool = False):
        # heartbeat_timeout_s defaults to inf: in the simulation, true
        # deaths surface as round starvation (-> mark_failed) and slowness
        # as the EWMA straggler stat; a finite timeout models a gossip-style
        # failure detector and must exceed the worst healthy round, or a
        # single long round makes healthy-but-quiet workers look dead.
        self.cfg = cfg
        ksetup, self.kloop = jax.random.split(key)
        self.state = engine.setup(cfg, ksetup, x, y)
        self.eta = (engine.lipschitz_eta(self.state.xq_real)
                    if eta is None else eta)
        self._round = engine.round_fn(cfg, self.state, self.eta)
        self._update = engine.update_fn(cfg, self.state, self.eta)
        self.latency = latency
        self.round_timeout_s = round_timeout_s
        self.exclude_stragglers = exclude_stragglers
        self.collect_all = collect_all
        self.scheduler = EventScheduler(cfg.N, latency, transport,
                                        master_overhead_s=master_overhead_s)
        if self.distributed and math.isinf(round_timeout_s):
            # a real cluster must be able to give up on silence
            self.round_timeout_s = 300.0
        self.monitor = HeartbeatMonitor(cfg.N, timeout_s=heartbeat_timeout_s,
                                        straggler_factor=straggler_factor,
                                        now=self.scheduler.clock)
        self.w2 = engine._w_internal(cfg, self.state.w)
        self.records: dict[int, RoundRecord] = {}
        self.traces: dict[int, RoundTrace] = {}
        self.restarts = 0

    @property
    def distributed(self) -> bool:
        """True when real worker processes compute (socket transport)."""
        return self.latency is None

    # ------------------------------------------------------------------
    # Distributed-mode provisioning: one-time worker state over the wire
    # ------------------------------------------------------------------

    def provision(self, timeout_s: float = 60.0) -> None:
        """Ship each worker its coded dataset share + static round context.

        Sent as an EncodeShare with ``round == PROVISION_ROUND``; the worker
        acks with a Heartbeat once its share is loaded, and rounds only
        start after every dispatched worker has acked (so round-0 timing
        does not absorb worker warmup).
        """
        assert self.distributed, "provision() is for real transports only"
        tr = self.scheduler.transport
        x_shares = np.asarray(self.state.x_shares)
        cbar = engine.poly_coeffs(self.cfg)
        cfg_kw = {"N": self.cfg.N, "K": self.cfg.K, "T": self.cfg.T,
                  "r": self.cfg.r, "c": self.cfg.c, "lx": self.cfg.lx,
                  "lw": self.cfg.lw, "lc": self.cfg.lc, "p": self.cfg.p,
                  "batch_rows": self.cfg.batch_rows}
        now = self.scheduler.clock
        for w in range(self.cfg.N):
            tr.send(worker_endpoint(w),
                    EncodeShare(PROVISION_ROUND, w,
                                {"cfg": cfg_kw, "x_share": x_shares[w],
                                 "cbar": cbar}),
                    at=now)
        await_worker_acks(tr, lambda: self.scheduler.clock, self.cfg.N,
                          self.monitor, timeout_s)

    def shutdown_workers(self) -> None:
        """Ask every worker process to exit its serve loop."""
        assert self.distributed
        now = self.scheduler.clock
        for w in range(self.cfg.N):
            self.scheduler.transport.send(
                worker_endpoint(w), EncodeShare(SHUTDOWN_ROUND, w), at=now)

    # ------------------------------------------------------------------
    # Dispatch-set policy: monitor-alive workers, minus known stragglers
    # while the fast set strictly exceeds the recovery threshold.
    # ------------------------------------------------------------------

    def _alive(self, now: float) -> np.ndarray:
        return np.array(
            [i for i in self.monitor.workers
             if not self.monitor.is_dead(i, now=now)],
            dtype=np.int64)

    def dispatch_set(self) -> np.ndarray:
        now = self.scheduler.clock
        alive = self._alive(now)
        if self.exclude_stragglers:
            fast = self.monitor.survivors(now=now)
            # STRICTLY more than threshold: speculative exclusion must leave
            # slack, because the fast set can still contain an undetected
            # dead worker — dispatching exactly `threshold` workers means a
            # single silent failure starves the round.
            if len(fast) > self.cfg.threshold:
                return fast
        return alive

    # ------------------------------------------------------------------
    # One round
    # ------------------------------------------------------------------

    def step_round(self, t: int, iters: int, replayed: bool = False
                   ) -> RoundTrace:
        cfg = self.cfg
        workers = self.dispatch_set()
        if len(workers) < cfg.threshold:
            raise ClusterDecodeError(
                f"round {t}: only {len(workers)} dispatchable workers < "
                f"recovery threshold {cfg.threshold}")
        key_t = engine.round_key(self.kloop, t)
        bidx = (engine.draw_batch(cfg, self.kloop, iters, self.state.mk, t)
                if cfg.batch_rows is not None else None)
        payloads = None
        if self.distributed:
            # encode THIS round's weight shares and ship one to each worker;
            # field elements are exact int32, so the share a worker process
            # receives is bit-identical to the one the in-process round
            # would have traced from the same key.
            w_shares = np.asarray(engine.encode_round_shares(
                cfg, key_t, self.w2))                    # (N, d, c, r)
            batch_np = None if bidx is None else np.asarray(bidx)
            payloads = {int(w): {"w_share": w_shares[int(w)],
                                 "batch": batch_np}
                        for w in workers}
        trace = self.scheduler.dispatch_round(
            t, cfg.threshold, workers=workers, monitor=self.monitor,
            timeout_s=self.round_timeout_s, payloads=payloads,
            collect_all=self.collect_all)
        if not math.isfinite(trace.t_first_R):
            # non-responders within the timeout are presumed dead
            for w in workers:
                if int(w) not in trace.arrivals:
                    self.monitor.mark_failed(int(w))
            raise ClusterDecodeError(
                f"round {t}: {len(trace.responders)} responses < threshold "
                f"{cfg.threshold} within {self.round_timeout_s}s")

        dmat, order = engine.survivor_round(cfg, trace.responders)
        if self.distributed:
            # decode from the payloads the responders actually sent
            fastest = np.stack([np.asarray(trace.payloads[int(w)],
                                           dtype=np.int32) for w in order])
            self.w2 = self._update(self.w2, jnp.asarray(fastest),
                                   jnp.asarray(dmat, jnp.int32), bidx)
        else:
            self.w2 = self._round(key_t, self.w2,
                                  jnp.asarray(dmat, jnp.int32),
                                  jnp.asarray(order, jnp.int32), bidx)
        self.traces[t] = trace
        self.records[t] = RoundRecord(
            round=t, survivors=order.copy(),
            n_responders=len(trace.responders),
            dispatched=trace.dispatched.copy(),
            coded_wait_s=trace.coded_wait_s, all_wait_s=trace.all_wait_s,
            replayed=replayed)
        return trace

    # ------------------------------------------------------------------
    # Training drivers
    # ------------------------------------------------------------------

    def run(self, iters: int):
        """Plain run: any starved round raises ClusterDecodeError."""
        self._reset()
        for t in range(iters):
            self.step_round(t, iters)
        return engine._w_public(self.cfg, self.w2)

    def run_resilient(self, iters: int, ckpt_manager,
                      checkpoint_every: int = 5, max_retries: int = 3):
        """Checkpointed run: a starved round restores the last checkpoint,
        reprovisions dead workers, and replays."""
        self._reset()
        replaying = {"flag": False}

        def step_fn(state, t):
            self.w2 = jnp.asarray(state["train"]["w2"])
            self.step_round(t, iters, replayed=replaying["flag"])
            return {"train": {"w2": np.asarray(self.w2)}}

        def on_restore(step):
            replaying["flag"] = True
            now = self.scheduler.clock
            for i, ws in self.monitor.workers.items():
                if not ws.alive:
                    if self.latency is not None:
                        self.latency.revive(i, at_round=step)
                    self.monitor.revive(i, now=now)

        loop = ResilientLoop(ckpt_manager, checkpoint_every=checkpoint_every,
                             max_retries=max_retries, on_restore=on_restore)
        state0 = {"train": {"w2": np.asarray(self.w2)}}
        ckpt_manager.save(0, state0)
        ckpt_manager.wait()
        loop.run(state0, step_fn, start_step=0, num_steps=iters)
        self.restarts = loop.restarts
        return engine._w_public(self.cfg, self.w2)

    def _reset(self):
        self.w2 = engine._w_internal(self.cfg, self.state.w)
        self.records.clear()
        self.traces.clear()

    # ------------------------------------------------------------------
    # Trace export + stats
    # ------------------------------------------------------------------

    def survivor_fn(self) -> Callable[[int], np.ndarray]:
        """Responder trace -> survivor_fn for engine.train/train_reference.

        Replaying it through the static-schedule drivers reproduces this
        run's weights bit-for-bit (the decode order fed to round_fn is
        identical).
        """
        trace = {t: rec.survivors for t, rec in self.records.items()}
        return lambda t: trace[t]

    def wait_stats(self) -> dict[str, dict[str, float]]:
        """Per-round completion-time stats: coded first-T vs wait-for-all."""
        recs = sorted(self.records.values(), key=lambda r: r.round)
        coded = np.array([r.coded_wait_s for r in recs])
        allw = np.array([r.all_wait_s for r in recs])
        return {"coded_T": wait_summary(coded),
                "wait_all": wait_summary(allw[np.isfinite(allw)]),
                "rounds": {"n": float(len(recs)),
                           "dead_rounds": float(np.sum(~np.isfinite(allw)))}}
