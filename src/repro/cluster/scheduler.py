"""Event-loop scheduler: round dispatch + first-T collect on either clock.

The scheduler owns the clock — simulated or wall, behind one ``Clock``
abstraction.  One round (DESIGN.md §7):

  1. DISPATCH  at clock t0: send an EncodeShare to every worker in the
     dispatch set.  With a ``latency`` model the scheduler also ENACTS the
     workers (the in-process simulation): each alive worker acks with a
     Heartbeat after a small network delay and sends its WorkerResult after
     its sampled latency (latency.py); dead workers (latency = inf) send
     nothing.  With ``latency=None`` the transport is real
     (socket_transport.py) and actual worker processes produce the replies.
  2. COLLECT   pop master deliveries in time order, advancing the clock to
     each arrival, until ``threshold`` results of THIS round are in (late
     results of earlier rounds still update the heartbeat monitor — a late
     reply proves the worker is alive, just slow).  On a wall clock
     "advancing" is a no-op: time already passed; the loop instead blocks
     on the transport's bounded poll until the round deadline.
  3. DECODE    the moment the threshold-th result lands the master decodes;
     the clock at that instant is the round's wait-for-fastest-T completion
     time.  ``t_all`` (when the LAST dispatched response would have landed)
     is what a wait-for-all master — or an MPC baseline that cannot treat
     stragglers as erasures — would have paid for the same round.  On a
     real transport that counterfactual is unobservable unless
     ``collect_all=True`` keeps the loop open until every dispatched worker
     responds (the straggler benchmark does exactly this).

The scheduler moves messages and time only; the gradient numerics stay in
core/protocol (see runner.py).

``run_mpc_round`` generalizes the single dispatch/collect phase to the
multi-phase rounds the BGW MPC baseline needs (DESIGN.md §7): dispatch ->
local multiply -> all-to-all reshare BARRIER (repeated once per degree
reduction) -> combine -> collect the first 2T+1 final shares.  The reshare
barrier is the structural difference the paper's comparison hinges on: a
recipient needs sub-shares from ALL N workers before it can combine, so
every reshare phase is gated on the slowest worker — stragglers cannot be
treated as erasures the way the coded decode treats them.
"""
from __future__ import annotations

import abc
import dataclasses
import math
import time as _time
from typing import Any

import numpy as np

from repro.cluster.latency import LatencyModel
from repro.cluster.messages import (
    MASTER,
    CombineResult,
    EncodeShare,
    Heartbeat,
    Join,
    SubShare,
    WorkerResult,
    worker_endpoint,
)
from repro.cluster.transport import InProcessTransport, Transport
from repro.obs.trace import NULL_RECORDER


class ClusterDecodeError(RuntimeError):
    """Fewer than ``threshold`` results arrived within the round timeout —
    the coded decode is infeasible and recovery (checkpoint restore +
    worker reprovision) must take over."""


# ---------------------------------------------------------------------------
# Clock abstraction: simulated time is SET, wall time only OBSERVED
# ---------------------------------------------------------------------------

class Clock(abc.ABC):
    """``real`` mirrors Transport.real: a simulated clock is advanced by the
    scheduler to the transport's next delivery; a wall clock cannot be
    advanced at all — ``advance_to`` is a no-op and waiting happens inside
    the transport's bounded poll."""

    real: bool

    @abc.abstractmethod
    def now(self) -> float: ...

    @abc.abstractmethod
    def advance_to(self, t: float) -> None: ...


class SimClock(Clock):
    real = False

    def __init__(self, start: float = 0.0):
        self._now = start

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        self._now = max(self._now, t)


class WallClock(Clock):
    real = True

    def now(self) -> float:
        return _time.monotonic()

    def advance_to(self, t: float) -> None:
        pass                        # wall time advances itself


@dataclasses.dataclass
class RoundTrace:
    """Everything the master observed about one round's timing."""
    round: int
    t_start: float
    dispatched: np.ndarray          # workers the share was sent to
    responders: np.ndarray          # arrival order (may exceed threshold on
                                    # ties at the decode instant)
    arrivals: dict[int, float]      # worker -> absolute arrival time
    latencies: dict[int, float]     # worker -> sampled/reported latency
                                    # (inf = dead)
    t_first_R: float                # clock at the threshold-th arrival
    t_all: float                    # when the slowest dispatched response
                                    # lands (inf if any worker is dead, or
                                    # unobservable on a real transport)
    payloads: dict[int, Any] = dataclasses.field(default_factory=dict)
                                    # worker -> WorkerResult payload (real
                                    # transports carry serialized arrays;
                                    # the simulation carries None)
    # master-side pipeline components (DESIGN.md §9), recorded NEXT TO the
    # wait so the benches can attribute where each round's time went:
    encode_s: float = 0.0           # encode time on the critical path
                                    # BEFORE dispatch (sim: the pre_s
                                    # charge; real: runner-measured wall)
    decode_s: float = 0.0           # decode+step time on the critical path
                                    # AFTER the threshold-th arrival (sim:
                                    # the post_s charge; real: measured)
    t_ready: float = math.nan       # clock when the updated weights were
                                    # ready (t_first_R + post charges; on a
                                    # real transport set by the runner
                                    # after the actual update)
    # wire accounting (real transports only; zeros on the simulation): the
    # delta of the transport's wire_totals() across this round's dispatch +
    # collect — bytes/frames enqueued to and decoded from ALL peers while
    # the round ran, so coalescing/packing wins show up per round
    tx_bytes: int = 0
    rx_bytes: int = 0
    tx_frames: int = 0
    rx_frames: int = 0
    # worker-shipped observability spans (DESIGN.md §11): worker ->
    # [name, start, end] triples on THAT worker's monotonic clock, present
    # only when the master asked for tracing and the peer speaks wire v2
    worker_traces: dict[int, Any] = dataclasses.field(default_factory=dict)

    @property
    def coded_wait_s(self) -> float:
        return self.t_first_R - self.t_start

    @property
    def all_wait_s(self) -> float:
        return self.t_all - self.t_start

    @property
    def critical_path_s(self) -> float:
        """Master-observed round cost: encode + wait-for-threshold + decode
        — the quantity pipelining shrinks (the wait is irreducible)."""
        return self.encode_s + self.coded_wait_s + self.decode_s


@dataclasses.dataclass
class MPCRoundTrace:
    """Everything the master observed about one multi-phase MPC round."""
    round: int
    t_start: float
    dispatched: np.ndarray
    responders: np.ndarray          # arrival order of final shares
    arrivals: dict[int, float]      # worker -> final-share arrival time
    latencies: dict[int, float]     # worker -> reported final-phase latency
    t_done: float                   # clock at the (2T+1)-th final share
                                    # (inf = starved round)
    t_all: float                    # when the LAST final share lands
                                    # (inf if any worker dead/stalled)
    barriers: list[float] = dataclasses.field(default_factory=list)
                                    # simulated reshare-barrier exit times
                                    # (unobservable master-side on a real
                                    # transport: empty)
    payloads: dict[int, Any] = dataclasses.field(default_factory=dict)
    worker_traces: dict[int, Any] = dataclasses.field(default_factory=dict)
                                    # worker-clock span triples incl. the
                                    # BGW barrier phases (wire v2 + tracing)

    @property
    def mpc_wait_s(self) -> float:
        return self.t_done - self.t_start

    @property
    def all_wait_s(self) -> float:
        return self.t_all - self.t_start


class EventScheduler:
    def __init__(self, n_workers, latency: LatencyModel | None = None,
                 transport: Transport | None = None,
                 heartbeat_delay_s: float = 1e-3,
                 master_overhead_s: float = 0.0,
                 recorder=None):
        # ``n_workers`` is an int (fixed fleet, the historical contract) or
        # a cluster.membership.ClusterMembership — then the fleet is ELASTIC
        # and every default worker set is read off the live membership at
        # dispatch time (the runner fences on a view() snapshot per round).
        if isinstance(n_workers, (int, np.integer)):
            self.membership = None
            self._n = int(n_workers)
        else:
            self.membership = n_workers
            self._n = None
        # JOIN (and any future control traffic) arrives on the same master
        # inbox as results; the collect loop stashes it here instead of
        # dropping it, and the runner drains the stash at each round fence.
        self.control_inbox: list[tuple[float, Any]] = []
        self.latency = latency
        self.transport = transport or InProcessTransport()
        self.heartbeat_delay_s = heartbeat_delay_s
        self.master_overhead_s = master_overhead_s
        # flight recorder (DESIGN.md §11): the default NullRecorder makes
        # every span call a constant no-op, so tracing costs nothing off
        self.obs = recorder if recorder is not None else NULL_RECORDER
        if self.transport.real:
            assert latency is None, (
                "a real transport's workers produce their own latencies; "
                "injected latency models are simulation-only")
            self.time: Clock = WallClock()
        else:
            assert latency is not None, (
                "the in-process simulation needs a latency model to enact "
                "its workers")
            self.time = SimClock()

    def bind_membership(self, membership) -> None:
        """Switch an int-constructed scheduler onto a live membership (the
        runner builds its ClusterMembership after the scheduler, because
        the membership needs the monitor and the monitor needs this
        scheduler's clock)."""
        self.membership = membership
        self._n = None

    @property
    def n(self) -> int:
        """Current fleet size (elastic: tracks the live membership)."""
        return self._n if self.membership is None else len(self.membership)

    def default_workers(self) -> np.ndarray:
        """The default dispatch set: all slots (fixed) or the current
        members (elastic).  Elastic callers normally pass an explicit set
        derived from their round's epoch snapshot instead."""
        if self.membership is None:
            return np.arange(self._n)
        return np.asarray(self.membership.view().members, dtype=np.int64)

    @property
    def clock(self) -> float:
        return self.time.now()

    def _deliver_to_master(self, now: float, round: int, monitor,
                           dispatched: set[int],
                           arrivals: dict[int, float],
                           latencies: dict[int, float],
                           responders: list[int],
                           payloads: dict[int, Any],
                           result_type: type = WorkerResult,
                           on_result=None,
                           worker_traces: dict[int, Any] | None = None
                           ) -> None:
        for at, msg in self.transport.recv(MASTER, now):
            if isinstance(msg, Heartbeat):
                if monitor is not None:
                    monitor.heartbeat(msg.worker, now=at)
            elif isinstance(msg, Join):
                # elastic membership: a late worker's JOIN rides the same
                # master inbox as results; stash it for the runner's next
                # round fence (dropping it would strand the joiner forever)
                self.control_inbox.append((at, msg))
            elif isinstance(msg, (WorkerResult, CombineResult)):
                if monitor is not None:
                    # late results of past rounds still count as liveness +
                    # latency evidence; only THIS round's feed the decode.
                    monitor.heartbeat(msg.worker, latency_s=msg.compute_s,
                                      now=at)
                # decode accepts only workers dispatched THIS attempt: after
                # a checkpoint restore, a stale result for the same round
                # number from the aborted attempt (or from a worker the
                # replay excluded) must not enter the responder trace.  The
                # result TYPE is part of the filter: a stale coded
                # WorkerResult can never enter an MPC round's trace.
                if (isinstance(msg, result_type) and msg.round == round
                        and msg.worker in dispatched
                        and msg.worker not in arrivals):
                    arrivals[msg.worker] = at
                    latencies[msg.worker] = msg.compute_s
                    responders.append(msg.worker)
                    payloads[msg.worker] = msg.payload
                    if (worker_traces is not None
                            and getattr(msg, "trace", None) is not None):
                        worker_traces[msg.worker] = msg.trace
                    if on_result is not None:
                        # streaming decode: fold this share into the
                        # reconstruction NOW, while later shares are still
                        # in flight (DESIGN.md §9)
                        on_result(msg.worker, msg.payload)

    def _presumed_dead(self, missing, monitor) -> bool:
        """True when the failure detector has declared EVERY missing worker
        dead (HeartbeatMonitor.is_dead: explicitly mark_failed, or
        heartbeat-silent beyond the monitor's finite timeout).  The collect
        loop's only legitimate way to stop waiting for absent workers on a
        real transport."""
        if monitor is None or not missing:
            return False
        now = self.time.now()
        return all(monitor.is_dead(w, now=now) for w in missing)

    def _collect(self, round: int, threshold: int, dispatched: set[int],
                 monitor, deadline: float, collect_all: bool,
                 result_type: type, on_result=None,
                 worker_traces: dict[int, Any] | None = None
                 ) -> tuple[dict[int, float],
                            dict[int, float], list[int],
                            dict[int, Any]]:
        """The master's event loop: pop deliveries in time order until
        ``threshold`` results of ``result_type`` for THIS round are in (and,
        under ``collect_all``, every dispatched worker has responded), or
        the deadline passes.  On a real transport the collect-ALL extension
        additionally ends when the heartbeat monitor declares every
        still-missing worker dead — a dead worker's silence would otherwise
        spin a deadline-less collect-all forever.  The dead-exit fires only
        AFTER the threshold is met: the decode wait itself is bounded by the
        deadline alone, so a heartbeat timeout shorter than a slow-but-
        healthy round (e.g. jit warmup) can never abandon a decodable round
        early."""
        arrivals: dict[int, float] = {}
        latencies: dict[int, float] = {}
        responders: list[int] = []
        payloads: dict[int, Any] = {}
        real = self.transport.real
        while (len(responders) < threshold
               or (collect_all and len(arrivals) < len(dispatched))):
            nxt = self.transport.next_delivery(MASTER)
            if nxt is None:
                if not real:
                    break              # sim queue drained: nothing will come
                if self.time.now() >= deadline:
                    break              # wall clock ran out: starved
                if (len(responders) >= threshold
                        and self._presumed_dead(
                            dispatched - arrivals.keys(), monitor)):
                    break              # decode done + all absentees dead:
                                       # wait-for-all is unobservable
                continue               # nothing YET: poll again
            if nxt > deadline:
                break
            self.time.advance_to(nxt)
            self._deliver_to_master(self.time.now(), round, monitor,
                                    dispatched, arrivals, latencies,
                                    responders, payloads, result_type,
                                    on_result, worker_traces)
        return arrivals, latencies, responders, payloads

    @staticmethod
    def _check_exitable(real: bool, collect_all: bool, timeout_s: float,
                        monitor) -> None:
        """A real-transport collect-all with no deadline AND no failure
        detector can never conclude a dead worker's response isn't coming —
        refuse up front instead of spinning forever."""
        if (real and collect_all and math.isinf(timeout_s)
                and (monitor is None or math.isinf(monitor.timeout_s))):
            raise ValueError(
                "collect_all on a real transport with timeout_s=inf needs a "
                "heartbeat monitor with a finite timeout: a dead worker's "
                "silence would spin the collect loop forever")

    def _send_round(self, round: int, workers: np.ndarray, t0: float,
                    payloads: dict[int, Any] | None
                    ) -> dict[int, float]:
        """Dispatch the EncodeShares; in simulation also enact the workers.

        Returns the sampled latencies (empty on a real transport — there the
        latencies are whatever the worker processes actually take)."""
        sampled: dict[int, float] = {}
        for w in workers:
            w = int(w)
            payload = None if payloads is None else payloads.get(w)
            if self.latency is None:
                # real transport: the worker process acks + replies itself
                self.transport.send(worker_endpoint(w),
                                    EncodeShare(round, w, payload), at=t0)
                continue
            # the (simulated) worker consumes its previous share when the
            # next one is dispatched — without this drain the per-worker
            # inboxes grow one EncodeShare per round forever.  The CURRENT
            # round's share stays queued and inspectable until then.
            self.transport.recv(worker_endpoint(w), t0)
            self.transport.send(worker_endpoint(w),
                                EncodeShare(round, w, payload), at=t0)
            lat = self.latency.sample(round, w)
            sampled[w] = lat
            if math.isfinite(lat):
                self.transport.send(MASTER, Heartbeat(w, t0), at=t0,
                                    delay=self.heartbeat_delay_s)
            # inf delay = the transport drops it: a dead worker's silence
            self.transport.send(MASTER, WorkerResult(round, w, lat),
                                at=t0, delay=lat)
        return sampled

    def dispatch_round(self, round: int, threshold: int,
                       workers: np.ndarray | None = None,
                       monitor=None,
                       timeout_s: float = math.inf,
                       payloads: dict[int, Any] | None = None,
                       collect_all: bool = False,
                       pre_s: float = 0.0, post_s: float = 0.0,
                       on_result=None) -> RoundTrace:
        """Run one round's event loop; returns the observed RoundTrace.

        Does NOT raise when fewer than ``threshold`` results arrive — the
        trace reports ``t_first_R = inf`` and the caller (runner.py) decides
        between failing and recovering.  ``payloads[w]`` rides in worker w's
        EncodeShare (real transports carry the serialized weight share).
        ``collect_all`` keeps collecting past the decode instant until every
        dispatched worker has responded (or the deadline passes) — the only
        way a real transport can observe the wait-for-all counterfactual.

        ``pre_s``/``post_s`` model master-side encode/decode time on a
        SIMULATED clock (DESIGN.md §9): pre_s advances the clock before
        dispatch (encode on the critical path), post_s after the decode
        instant.  On a wall clock both are no-ops — real master time passes
        by itself and the runner records the measured components on the
        trace.  ``on_result(worker, payload)`` fires at each accepted
        arrival of THIS round, in arrival order — the streaming decoder's
        fold point.
        """
        workers = (self.default_workers() if workers is None
                   else np.asarray(workers))
        real = self.transport.real
        self._check_exitable(real, collect_all, timeout_s, monitor)
        if pre_s:
            self.time.advance_to(self.time.now() + pre_s)
        wire0 = (self.transport.wire_totals()
                 if hasattr(self.transport, "wire_totals") else None)
        t0 = self.time.now()
        with self.obs.span("dispatch", round=round, workers=len(workers)):
            sampled = self._send_round(round, workers, t0, payloads)

        dispatched = {int(w) for w in workers}
        deadline = t0 + timeout_s
        worker_traces: dict[int, Any] = {}
        with self.obs.span("collect", round=round):
            arrivals, latencies, responders, round_payloads = self._collect(
                round, threshold, dispatched, monitor, deadline,
                collect_all=collect_all, result_type=WorkerResult,
                on_result=on_result, worker_traces=worker_traces)
        if self.obs.enabled:
            # per-worker flight lanes in the MASTER clock domain: dispatch
            # instant -> result arrival.  This is the cross-worker surface a
            # straggler shows up on (worker-shipped spans ride their own
            # clocks and are never compared across processes, §11).
            for w, at in sorted(arrivals.items()):
                self.obs.add_span("flight", t0, at, track=f"worker/{w}",
                                  round=round, worker=w,
                                  compute_s=latencies.get(w))

        got_R = len(responders) >= threshold
        # the decode instant is the threshold-th ARRIVAL, which (under
        # collect_all) the clock may have moved past by loop exit.
        t_first_R = arrivals[responders[threshold - 1]] if got_R else math.inf
        if real:
            t_all = (max(arrivals.values())
                     if arrivals and len(arrivals) == len(dispatched)
                     else math.inf)
        else:
            t_all = t0 + max(sampled.values(), default=0.0)
        t_ready = math.inf
        if got_R:
            self.time.advance_to(self.time.now() + self.master_overhead_s
                                 + post_s)
            t_ready = (self.time.now() if not real
                       else math.nan)     # real: runner stamps after update
        elif not real:
            self._park_starved(t0, deadline, t_all, monitor)
        wire_d = {}
        if wire0 is not None:
            wire1 = self.transport.wire_totals()
            wire_d = {k: wire1[k] - wire0[k] for k in wire0}
        return RoundTrace(
            round=round, t_start=t0, dispatched=workers,
            responders=np.asarray(responders, dtype=np.int64),
            arrivals=arrivals, latencies=latencies,
            t_first_R=t_first_R, t_all=t_all, payloads=round_payloads,
            encode_s=pre_s, decode_s=post_s, t_ready=t_ready,
            worker_traces=worker_traces, **wire_d)

    # ------------------------------------------------------------------
    # Multi-phase MPC rounds (DESIGN.md §7: "MPC on the cluster runtime")
    # ------------------------------------------------------------------

    def run_mpc_round(self, round: int, collect_threshold: int,
                      phase_models: list[LatencyModel] | None = None,
                      workers: np.ndarray | None = None,
                      monitor=None,
                      timeout_s: float = math.inf,
                      payloads: dict[int, Any] | None = None
                      ) -> MPCRoundTrace:
        """One BGW iteration's message flow: dispatch -> (local multiply ->
        all-to-all reshare barrier -> combine) x n_reductions -> collect the
        first ``collect_threshold`` (= 2T+1) final shares.

        In simulation ``phase_models`` (length n_reductions + 1: one per
        reshare phase plus the final send) enacts the workers: phase j's
        sample covers worker w's compute+network for that phase, its
        SubShares reach every peer at ``start + lat``, and NO worker enters
        phase j+1 before the slowest finishes phase j — sub-shares from all
        N workers are needed to combine, so the barrier exit is
        ``max_w(start_w + lat_w)``.  A dead worker (inf) makes the barrier
        — and the whole round — never complete: BGW cannot treat stragglers
        as erasures.  On a real transport (``latency=None``) the worker
        processes run the phases themselves (launch/cpml_worker.py, MPC
        serve mode) and the reshare traffic relays through the master's
        transport; only dispatch + final collect are enacted here.
        """
        workers = (self.default_workers() if workers is None
                   else np.asarray(workers))
        t0 = self.time.now()
        dispatched = {int(w) for w in workers}
        barriers: list[float] = []
        with self.obs.span("dispatch", round=round, workers=len(workers)):
            if self.latency is None:                  # real worker processes
                assert phase_models is None, (
                    "a real transport's workers pace their own phases")
                for w in workers:
                    w = int(w)
                    payload = None if payloads is None else payloads.get(w)
                    self.transport.send(worker_endpoint(w),
                                        EncodeShare(round, w, payload),
                                        at=t0)
                sampled: dict[int, float] = {}
            else:
                assert phase_models, (
                    "the in-process simulation needs one latency model per "
                    "reshare phase plus the final send")
                sampled = self._enact_mpc_phases(round, workers, t0,
                                                 phase_models, barriers,
                                                 payloads)
        if self.obs.enabled and barriers:
            # simulated reshare barriers become spans: the wait-for-ALL
            # structure the showdown hinges on, visible per phase.  (On a
            # real transport the master cannot observe the barriers — the
            # workers ship their own barrier spans over the wire instead.)
            prev = t0
            for j, b in enumerate(barriers):
                if math.isfinite(b):
                    self.obs.add_span("barrier", prev, b, round=round,
                                      phase=j)
                    prev = b

        deadline = t0 + timeout_s
        worker_traces: dict[int, Any] = {}
        with self.obs.span("collect", round=round):
            arrivals, latencies, responders, round_payloads = self._collect(
                round, collect_threshold, dispatched, monitor, deadline,
                collect_all=False, result_type=CombineResult,
                worker_traces=worker_traces)
        if self.obs.enabled:
            for w, at in sorted(arrivals.items()):
                self.obs.add_span("flight", t0, at, track=f"worker/{w}",
                                  round=round, worker=w,
                                  compute_s=latencies.get(w))

        got = len(responders) >= collect_threshold
        t_done = (arrivals[responders[collect_threshold - 1]] if got
                  else math.inf)
        if self.transport.real:
            t_all = (max(arrivals.values())
                     if arrivals and len(arrivals) == len(dispatched)
                     else math.inf)
        else:
            t_all = max(sampled.values(), default=math.inf)
        if got:
            self.time.advance_to(self.time.now() + self.master_overhead_s)
        elif not self.transport.real:
            self._park_starved(t0, deadline, t_all, monitor)
        return MPCRoundTrace(
            round=round, t_start=t0, dispatched=workers,
            responders=np.asarray(responders, dtype=np.int64),
            arrivals=arrivals, latencies=latencies,
            t_done=t_done, t_all=t_all, barriers=barriers,
            payloads=round_payloads, worker_traces=worker_traces)

    def _enact_mpc_phases(self, round: int, workers: np.ndarray, t0: float,
                          phase_models: list[LatencyModel],
                          barriers: list[float],
                          payloads: dict[int, Any] | None
                          ) -> dict[int, float]:
        """Simulate the workers through dispatch, every reshare barrier, and
        the final send; returns each worker's final-share landing time."""
        idx = [int(w) for w in workers]
        for w in idx:
            # drain the previous round's share (bounded inboxes), then
            # dispatch; alive workers ack with a heartbeat.  sample() is
            # order-independent, so re-reading phase 0's draw is free.
            payload = None if payloads is None else payloads.get(w)
            self.transport.recv(worker_endpoint(w), t0)
            self.transport.send(worker_endpoint(w),
                                EncodeShare(round, w, payload), at=t0)
            if math.isfinite(phase_models[0].sample(round, w)):
                self.transport.send(MASTER, Heartbeat(w, t0), at=t0,
                                    delay=self.heartbeat_delay_s)
        start = {w: t0 for w in idx}
        for j, model in enumerate(phase_models[:-1]):
            done = {}
            for w in idx:
                lat = model.sample(round, w)
                done[w] = start[w] + lat
                for v in idx:       # all-to-all: sub-share to every peer
                    self.transport.send(worker_endpoint(v),
                                        SubShare(round, j, w, v),
                                        at=start[w], delay=lat)
            barrier = max(done.values())
            barriers.append(barrier)
            for v in idx:           # sub-shares are consumed at the barrier
                self.transport.recv(
                    worker_endpoint(v),
                    barrier if math.isfinite(barrier) else math.inf)
            start = {w: barrier for w in idx}
        sampled = {}
        final = phase_models[-1]
        for w in idx:
            lat = final.sample(round, w)
            sampled[w] = start[w] + lat
            self.transport.send(MASTER, CombineResult(round, w, lat),
                                at=start[w], delay=lat)
        return sampled

    def _park_starved(self, t0: float, deadline: float, t_all: float,
                      monitor) -> None:
        """Starved round in simulation: park the clock at the moment the
        master gave up waiting, so downstream heartbeat-timeout/recovery
        logic sees the time the wait actually consumed.

        With a finite deadline that is min(deadline, t_all).  With an
        infinite deadline the master's patience is unbounded and only a
        failure detector can end the wait: park at the instant the
        monitor's (finite) heartbeat timeout declares this round's silent
        workers dead.  With neither bound the wait is unsimulatable — the
        clock stays at the last delivery (pinned in tests; callers that
        want recovery semantics must supply a finite timeout or monitor).
        """
        give_up = min(deadline, t_all)
        if (not math.isfinite(give_up) and monitor is not None
                and math.isfinite(monitor.timeout_s)):
            give_up = t0 + monitor.timeout_s
        if math.isfinite(give_up):
            self.time.advance_to(give_up)
