"""Event-loop scheduler: simulated-time round dispatch + first-T collect.

The scheduler owns the simulated clock.  One round (DESIGN.md §7):

  1. DISPATCH  at clock t0: send an EncodeShare to every worker in the
     dispatch set; each alive worker acks with a Heartbeat after a small
     network delay and sends its WorkerResult after its sampled latency
     (latency.py).  Dead workers (latency = inf) send nothing.
  2. COLLECT   pop master deliveries in time order, advancing the clock to
     each arrival, until ``threshold`` results of THIS round are in (late
     results of earlier rounds still update the heartbeat monitor — a late
     reply proves the worker is alive, just slow).
  3. DECODE    the moment the threshold-th result lands the master decodes;
     the clock at that instant is the round's wait-for-fastest-T completion
     time.  ``t_all`` (when the LAST dispatched response would have landed)
     is what a wait-for-all master — or an MPC baseline that cannot treat
     stragglers as erasures — would have paid for the same round.

The scheduler moves messages and time only; the gradient numerics stay in
core/protocol (see runner.py).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.cluster.latency import LatencyModel
from repro.cluster.messages import (
    MASTER,
    EncodeShare,
    Heartbeat,
    WorkerResult,
    worker_endpoint,
)
from repro.cluster.transport import InProcessTransport, Transport


class ClusterDecodeError(RuntimeError):
    """Fewer than ``threshold`` results arrived within the round timeout —
    the coded decode is infeasible and recovery (checkpoint restore +
    worker reprovision) must take over."""


@dataclasses.dataclass
class RoundTrace:
    """Everything the master observed about one round's timing."""
    round: int
    t_start: float
    dispatched: np.ndarray          # workers the share was sent to
    responders: np.ndarray          # arrival order (may exceed threshold on
                                    # ties at the decode instant)
    arrivals: dict[int, float]      # worker -> absolute arrival time
    latencies: dict[int, float]     # worker -> sampled latency (inf = dead)
    t_first_R: float                # clock at the threshold-th arrival
    t_all: float                    # when the slowest dispatched response
                                    # lands (inf if any worker is dead)

    @property
    def coded_wait_s(self) -> float:
        return self.t_first_R - self.t_start

    @property
    def all_wait_s(self) -> float:
        return self.t_all - self.t_start


class EventScheduler:
    def __init__(self, n_workers: int, latency: LatencyModel,
                 transport: Transport | None = None,
                 heartbeat_delay_s: float = 1e-3,
                 master_overhead_s: float = 0.0):
        self.n = n_workers
        self.latency = latency
        self.transport = transport or InProcessTransport()
        self.heartbeat_delay_s = heartbeat_delay_s
        self.master_overhead_s = master_overhead_s
        self.clock = 0.0

    def _deliver_to_master(self, now: float, round: int, monitor,
                           dispatched: set[int],
                           arrivals: dict[int, float],
                           latencies: dict[int, float],
                           responders: list[int]) -> None:
        for at, msg in self.transport.recv(MASTER, now):
            if isinstance(msg, Heartbeat):
                if monitor is not None:
                    monitor.heartbeat(msg.worker, now=at)
            elif isinstance(msg, WorkerResult):
                if monitor is not None:
                    # late results of past rounds still count as liveness +
                    # latency evidence; only THIS round's feed the decode.
                    monitor.heartbeat(msg.worker, latency_s=msg.compute_s,
                                      now=at)
                # decode accepts only workers dispatched THIS attempt: after
                # a checkpoint restore, a stale result for the same round
                # number from the aborted attempt (or from a worker the
                # replay excluded) must not enter the responder trace.
                if (msg.round == round and msg.worker in dispatched
                        and msg.worker not in arrivals):
                    arrivals[msg.worker] = at
                    latencies[msg.worker] = msg.compute_s
                    responders.append(msg.worker)

    def dispatch_round(self, round: int, threshold: int,
                       workers: np.ndarray | None = None,
                       monitor=None,
                       timeout_s: float = math.inf) -> RoundTrace:
        """Run one round's event loop; returns the observed RoundTrace.

        Does NOT raise when fewer than ``threshold`` results arrive — the
        trace reports ``t_first_R = inf`` and the caller (runner.py) decides
        between failing and recovering.
        """
        workers = np.arange(self.n) if workers is None else np.asarray(workers)
        t0 = self.clock
        sampled: dict[int, float] = {}
        for w in workers:
            w = int(w)
            # the (simulated) worker consumes its previous share when the
            # next one is dispatched — without this drain the per-worker
            # inboxes grow one EncodeShare per round forever.  The CURRENT
            # round's share stays queued and inspectable until then.
            self.transport.recv(worker_endpoint(w), t0)
            self.transport.send(worker_endpoint(w), EncodeShare(round, w),
                                at=t0)
            lat = self.latency.sample(round, w)
            sampled[w] = lat
            if math.isfinite(lat):
                self.transport.send(MASTER, Heartbeat(w, t0), at=t0,
                                    delay=self.heartbeat_delay_s)
            # inf delay = the transport drops it: a dead worker's silence
            self.transport.send(MASTER, WorkerResult(round, w, lat),
                                at=t0, delay=lat)

        arrivals: dict[int, float] = {}
        latencies: dict[int, float] = {}
        responders: list[int] = []
        dispatched = {int(w) for w in workers}
        deadline = t0 + timeout_s
        while len(responders) < threshold:
            nxt = self.transport.next_delivery(MASTER)
            if nxt is None or nxt > deadline:
                break                      # starved: not enough responses
            self.clock = nxt
            self._deliver_to_master(self.clock, round, monitor, dispatched,
                                    arrivals, latencies, responders)

        got_R = len(responders) >= threshold
        t_first_R = self.clock if got_R else math.inf
        t_all = t0 + max(sampled.values(), default=0.0)
        if got_R:
            self.clock += self.master_overhead_s
        else:
            self.clock = min(deadline, t_all) if math.isfinite(deadline) \
                else self.clock
        return RoundTrace(
            round=round, t_start=t0, dispatched=workers,
            responders=np.asarray(responders, dtype=np.int64),
            arrivals=arrivals, latencies=latencies,
            t_first_R=t_first_R, t_all=t_all)
