"""Event-loop scheduler: round dispatch + first-T collect on either clock.

The scheduler owns the clock — simulated or wall, behind one ``Clock``
abstraction.  One round (DESIGN.md §7):

  1. DISPATCH  at clock t0: send an EncodeShare to every worker in the
     dispatch set.  With a ``latency`` model the scheduler also ENACTS the
     workers (the in-process simulation): each alive worker acks with a
     Heartbeat after a small network delay and sends its WorkerResult after
     its sampled latency (latency.py); dead workers (latency = inf) send
     nothing.  With ``latency=None`` the transport is real
     (socket_transport.py) and actual worker processes produce the replies.
  2. COLLECT   pop master deliveries in time order, advancing the clock to
     each arrival, until ``threshold`` results of THIS round are in (late
     results of earlier rounds still update the heartbeat monitor — a late
     reply proves the worker is alive, just slow).  On a wall clock
     "advancing" is a no-op: time already passed; the loop instead blocks
     on the transport's bounded poll until the round deadline.
  3. DECODE    the moment the threshold-th result lands the master decodes;
     the clock at that instant is the round's wait-for-fastest-T completion
     time.  ``t_all`` (when the LAST dispatched response would have landed)
     is what a wait-for-all master — or an MPC baseline that cannot treat
     stragglers as erasures — would have paid for the same round.  On a
     real transport that counterfactual is unobservable unless
     ``collect_all=True`` keeps the loop open until every dispatched worker
     responds (the straggler benchmark does exactly this).

The scheduler moves messages and time only; the gradient numerics stay in
core/protocol (see runner.py).
"""
from __future__ import annotations

import abc
import dataclasses
import math
import time as _time
from typing import Any

import numpy as np

from repro.cluster.latency import LatencyModel
from repro.cluster.messages import (
    MASTER,
    EncodeShare,
    Heartbeat,
    WorkerResult,
    worker_endpoint,
)
from repro.cluster.transport import InProcessTransport, Transport


class ClusterDecodeError(RuntimeError):
    """Fewer than ``threshold`` results arrived within the round timeout —
    the coded decode is infeasible and recovery (checkpoint restore +
    worker reprovision) must take over."""


# ---------------------------------------------------------------------------
# Clock abstraction: simulated time is SET, wall time only OBSERVED
# ---------------------------------------------------------------------------

class Clock(abc.ABC):
    """``real`` mirrors Transport.real: a simulated clock is advanced by the
    scheduler to the transport's next delivery; a wall clock cannot be
    advanced at all — ``advance_to`` is a no-op and waiting happens inside
    the transport's bounded poll."""

    real: bool

    @abc.abstractmethod
    def now(self) -> float: ...

    @abc.abstractmethod
    def advance_to(self, t: float) -> None: ...


class SimClock(Clock):
    real = False

    def __init__(self, start: float = 0.0):
        self._now = start

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        self._now = max(self._now, t)


class WallClock(Clock):
    real = True

    def now(self) -> float:
        return _time.monotonic()

    def advance_to(self, t: float) -> None:
        pass                        # wall time advances itself


@dataclasses.dataclass
class RoundTrace:
    """Everything the master observed about one round's timing."""
    round: int
    t_start: float
    dispatched: np.ndarray          # workers the share was sent to
    responders: np.ndarray          # arrival order (may exceed threshold on
                                    # ties at the decode instant)
    arrivals: dict[int, float]      # worker -> absolute arrival time
    latencies: dict[int, float]     # worker -> sampled/reported latency
                                    # (inf = dead)
    t_first_R: float                # clock at the threshold-th arrival
    t_all: float                    # when the slowest dispatched response
                                    # lands (inf if any worker is dead, or
                                    # unobservable on a real transport)
    payloads: dict[int, Any] = dataclasses.field(default_factory=dict)
                                    # worker -> WorkerResult payload (real
                                    # transports carry serialized arrays;
                                    # the simulation carries None)

    @property
    def coded_wait_s(self) -> float:
        return self.t_first_R - self.t_start

    @property
    def all_wait_s(self) -> float:
        return self.t_all - self.t_start


class EventScheduler:
    def __init__(self, n_workers: int, latency: LatencyModel | None = None,
                 transport: Transport | None = None,
                 heartbeat_delay_s: float = 1e-3,
                 master_overhead_s: float = 0.0):
        self.n = n_workers
        self.latency = latency
        self.transport = transport or InProcessTransport()
        self.heartbeat_delay_s = heartbeat_delay_s
        self.master_overhead_s = master_overhead_s
        if self.transport.real:
            assert latency is None, (
                "a real transport's workers produce their own latencies; "
                "injected latency models are simulation-only")
            self.time: Clock = WallClock()
        else:
            assert latency is not None, (
                "the in-process simulation needs a latency model to enact "
                "its workers")
            self.time = SimClock()

    @property
    def clock(self) -> float:
        return self.time.now()

    def _deliver_to_master(self, now: float, round: int, monitor,
                           dispatched: set[int],
                           arrivals: dict[int, float],
                           latencies: dict[int, float],
                           responders: list[int],
                           payloads: dict[int, Any]) -> None:
        for at, msg in self.transport.recv(MASTER, now):
            if isinstance(msg, Heartbeat):
                if monitor is not None:
                    monitor.heartbeat(msg.worker, now=at)
            elif isinstance(msg, WorkerResult):
                if monitor is not None:
                    # late results of past rounds still count as liveness +
                    # latency evidence; only THIS round's feed the decode.
                    monitor.heartbeat(msg.worker, latency_s=msg.compute_s,
                                      now=at)
                # decode accepts only workers dispatched THIS attempt: after
                # a checkpoint restore, a stale result for the same round
                # number from the aborted attempt (or from a worker the
                # replay excluded) must not enter the responder trace.
                if (msg.round == round and msg.worker in dispatched
                        and msg.worker not in arrivals):
                    arrivals[msg.worker] = at
                    latencies[msg.worker] = msg.compute_s
                    responders.append(msg.worker)
                    payloads[msg.worker] = msg.payload

    def _send_round(self, round: int, workers: np.ndarray, t0: float,
                    payloads: dict[int, Any] | None
                    ) -> dict[int, float]:
        """Dispatch the EncodeShares; in simulation also enact the workers.

        Returns the sampled latencies (empty on a real transport — there the
        latencies are whatever the worker processes actually take)."""
        sampled: dict[int, float] = {}
        for w in workers:
            w = int(w)
            payload = None if payloads is None else payloads.get(w)
            if self.latency is None:
                # real transport: the worker process acks + replies itself
                self.transport.send(worker_endpoint(w),
                                    EncodeShare(round, w, payload), at=t0)
                continue
            # the (simulated) worker consumes its previous share when the
            # next one is dispatched — without this drain the per-worker
            # inboxes grow one EncodeShare per round forever.  The CURRENT
            # round's share stays queued and inspectable until then.
            self.transport.recv(worker_endpoint(w), t0)
            self.transport.send(worker_endpoint(w),
                                EncodeShare(round, w, payload), at=t0)
            lat = self.latency.sample(round, w)
            sampled[w] = lat
            if math.isfinite(lat):
                self.transport.send(MASTER, Heartbeat(w, t0), at=t0,
                                    delay=self.heartbeat_delay_s)
            # inf delay = the transport drops it: a dead worker's silence
            self.transport.send(MASTER, WorkerResult(round, w, lat),
                                at=t0, delay=lat)
        return sampled

    def dispatch_round(self, round: int, threshold: int,
                       workers: np.ndarray | None = None,
                       monitor=None,
                       timeout_s: float = math.inf,
                       payloads: dict[int, Any] | None = None,
                       collect_all: bool = False) -> RoundTrace:
        """Run one round's event loop; returns the observed RoundTrace.

        Does NOT raise when fewer than ``threshold`` results arrive — the
        trace reports ``t_first_R = inf`` and the caller (runner.py) decides
        between failing and recovering.  ``payloads[w]`` rides in worker w's
        EncodeShare (real transports carry the serialized weight share).
        ``collect_all`` keeps collecting past the decode instant until every
        dispatched worker has responded (or the deadline passes) — the only
        way a real transport can observe the wait-for-all counterfactual.
        """
        workers = np.arange(self.n) if workers is None else np.asarray(workers)
        t0 = self.time.now()
        sampled = self._send_round(round, workers, t0, payloads)

        arrivals: dict[int, float] = {}
        latencies: dict[int, float] = {}
        responders: list[int] = []
        round_payloads: dict[int, Any] = {}
        dispatched = {int(w) for w in workers}
        deadline = t0 + timeout_s
        real = self.transport.real
        while (len(responders) < threshold
               or (collect_all and len(arrivals) < len(dispatched))):
            nxt = self.transport.next_delivery(MASTER)
            if nxt is None:
                if not real:
                    break              # sim queue drained: nothing will come
                if self.time.now() >= deadline:
                    break              # wall clock ran out: starved
                continue               # nothing YET: poll again
            if nxt > deadline:
                break
            self.time.advance_to(nxt)
            self._deliver_to_master(self.time.now(), round, monitor,
                                    dispatched, arrivals, latencies,
                                    responders, round_payloads)

        got_R = len(responders) >= threshold
        # the decode instant is the threshold-th ARRIVAL, which (under
        # collect_all) the clock may have moved past by loop exit.
        t_first_R = arrivals[responders[threshold - 1]] if got_R else math.inf
        if real:
            t_all = (max(arrivals.values())
                     if arrivals and len(arrivals) == len(dispatched)
                     else math.inf)
        else:
            t_all = t0 + max(sampled.values(), default=0.0)
        if got_R:
            self.time.advance_to(self.time.now() + self.master_overhead_s)
        elif not real:
            # starved: park the simulated clock at the moment the master
            # gave up waiting
            if math.isfinite(deadline):
                self.time.advance_to(min(deadline, t_all))
        return RoundTrace(
            round=round, t_start=t0, dispatched=workers,
            responders=np.asarray(responders, dtype=np.int64),
            arrivals=arrivals, latencies=latencies,
            t_first_R=t_first_R, t_all=t_all, payloads=round_payloads)
