"""Coded prediction serving plane: batched private inference (DESIGN.md §12).

Training (runner.py) ships a fresh coded weight share every round; serving
inverts the flow.  The trained model W is quantized, Lagrange-encoded ONCE
— every one of the K interpolation slots carries the SAME W̄, plus T
uniform mask matrices — and each worker keeps its share W̃_i for the life
of the deployment (``provision``).  Clients then submit Query batches that
the master:

  1. ADMITS into a bounded FIFO (``queue_cap`` queries; a full queue
     rejects at submission — backpressure, never unbounded memory),
  2. FLUSHES into a fixed-size coded sub-batch under the deadline-aware
     ``BatchingPolicy``: flush when the pending rows fill ``max_batch`` OR
     when the oldest admitted query has waited ``max_wait_s``, whichever
     comes first,
  3. ENCODES the flush — rows padded to ``max_batch``, split into K
     row-blocks, FRESH query masks drawn per flush — and dispatches
     X̃_i to every live worker through the existing ``EventScheduler``,
  4. DECODES logits at the first ``2(K+T-1)+1`` arrivals.  Worker i
     computes the bilinear X̃_i·W̃_i, so the product polynomial has degree
     2(K+T-1) and exact Lagrange interpolation at the betas returns
     X̄_k·W̄ — bit-identical to the uncoded plaintext evaluation no matter
     WHICH workers responded.

Every flush keeps the worker-side shape static at (max_batch/K, d), so the
workers' jitted field matmul never recompiles mid-service (an XLA
recompile would be a self-inflicted p99 straggler).

Privacy (§12): X̃_i and W̃_i are T-masked Lagrange shares, so any T
colluding workers observe jointly uniform values.  The weight masks are
drawn once per PROVISION and reused across queries — all queries expose
the same T evaluations of the same masked weight polynomial, which is
exactly one leakage budget, not one per query.  Query masks are fresh per
flush, so distinct clients' features stay pairwise protected.

Reuses the cluster runtime nearly verbatim: wire-v2 transport and the
``Query``/``Prediction`` frames (messages.py), ``StreamingDecoder`` folds
on the socket path, HeartbeatMonitor-based straggler exclusion, and the
obs flight recorder (per-query queue/batch/dispatch/decode spans +
``serve_*`` metrics).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time as _time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.latency import LatencyModel
from repro.cluster.membership import ClusterMembership
from repro.cluster.messages import (
    PROVISION_ROUND, SHUTDOWN_ROUND, EncodeShare, Prediction, Query,
    worker_endpoint)
from repro.cluster.runner import await_worker_acks
from repro.cluster.scheduler import (
    ClusterDecodeError, EventScheduler, RoundTrace)
from repro.core import field, lagrange, quantize
from repro.core.protocol import decode
from repro.obs.metrics import MetricsRegistry
from repro.runtime.resilience import HeartbeatMonitor

SERVE_DEG_F = 2                  # worker fn X̃·W̃ is bilinear in the codes


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static parameters of one serving deployment.

    Duck-types the decode layer's config surface (``threshold`` /
    ``scheme`` / ``K`` / ``p``), so ``StreamingDecoder`` and the cached
    decode matrices are reused unchanged — only the threshold changes:
    serving's worker function is the bilinear X̃·W̃ (degree 2), not
    training's degree-(2r+1) gradient polynomial.
    """
    N: int                       # workers
    K: int                       # batch parallelization (row split)
    T: int                       # privacy threshold (colluding workers)
    lx: int = 2                  # query fractional bits
    lw: int = 4                  # weight fractional bits
    p: int = field.P
    max_batch: int = 32          # rows per coded flush (K | max_batch)
    max_wait_s: float = 0.05     # oldest-query deadline before a flush
    queue_cap: int = 64          # admitted-but-unflushed query bound

    def __post_init__(self):
        assert self.K >= 1 and self.T >= 0, (self.K, self.T)
        assert self.max_batch % self.K == 0, (
            f"K={self.K} must divide max_batch={self.max_batch} "
            f"(fixed-shape row blocks)")
        assert self.queue_cap >= 1
        assert math.isfinite(self.max_wait_s) and self.max_wait_s >= 0, (
            "the deadline trigger needs a finite max_wait_s")
        assert self.N >= self.threshold, (
            f"N={self.N} < serve threshold {self.threshold} "
            f"= 2(K+T-1)+1: no responder set could ever decode")

    @property
    def threshold(self) -> int:
        return lagrange.degree_threshold(self.K, self.T, SERVE_DEG_F)

    @property
    def rows_per_part(self) -> int:
        return self.max_batch // self.K

    @property
    def scheme(self) -> lagrange.CodingScheme:
        return lagrange.CodingScheme(self.N, self.K, self.T, self.p)


class BatchingPolicy:
    """Deadline-aware flush decision, separable from the server so the
    size-vs-deadline semantics are unit-testable without a cluster."""

    def __init__(self, max_batch: int, max_wait_s: float):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s

    def should_flush(self, pending_rows: int, oldest_age_s: float) -> bool:
        """Flush on max-batch OR max-wait, whichever fires first."""
        if pending_rows <= 0:
            return False
        return pending_rows >= self.max_batch \
            or oldest_age_s >= self.max_wait_s

    def deadline(self, oldest_admitted_at: float) -> float:
        """Absolute time the deadline trigger fires for the oldest query."""
        return oldest_admitted_at + self.max_wait_s


@dataclasses.dataclass
class _Pending:
    query: Query
    admitted_at: float           # master-clock admission instant
    sent_abs: float              # master-clock submission (latency epoch)
    rows: int


def open_loop_queries(n: int, rows: int, d: int, rate_qps: float,
                      seed: int = 0, clients: int = 4) -> list[Query]:
    """Open-loop load: ``n`` queries of ``rows`` random feature rows each,
    Poisson arrivals at ``rate_qps`` (``rate_qps <= 0`` = all at t=0).
    ``sent_at`` values are offsets from the run() epoch."""
    rng = np.random.default_rng(seed)
    gaps = (rng.exponential(1.0 / rate_qps, n) if rate_qps > 0
            else np.zeros(n))
    at = np.cumsum(gaps)
    return [Query(qid=i, client=f"client{i % clients}",
                  sent_at=float(at[i]),
                  x=rng.standard_normal((rows, d)).astype(np.float32))
            for i in range(n)]


class PredictionServer:
    """The master side of the serving plane.

    Two backends through one code path, exactly like ClusterRunner:

      * ``latency=<LatencyModel>`` — event-driven simulation: the scheduler
        enacts the workers on a SimClock and the master evaluates the
        responders' shares itself, in observed arrival order.
      * ``transport=<SocketTransport>`` — real worker processes hold W̃_i
        (``provision()`` once), each flush ships X̃_i as a wire frame, and
        arriving shares fold into a ``StreamingDecoder`` while later
        shares are still in flight.

    ``verify=True`` recomputes every flush through the uncoded plaintext
    oracle (one quantized matmul on the master) and counts mismatches —
    the bit-identity acceptance check, cheap enough to leave on in tests
    and benchmarks.
    """

    def __init__(self, cfg: ServeConfig, w, key, *,
                 latency: LatencyModel | None = None,
                 transport=None,
                 round_timeout_s: float = math.inf,
                 heartbeat_timeout_s: float = math.inf,
                 straggler_factor: float = 3.0,
                 exclude_stragglers: bool = True,
                 collect_all: bool = False,
                 verify: bool = False,
                 recorder=None,
                 metrics: MetricsRegistry | None = None):
        self.cfg = cfg
        w = jnp.asarray(w, jnp.float32)
        assert w.ndim == 2, f"model weights must be (d, classes), got {w.shape}"
        self.d, self.classes = int(w.shape[0]), int(w.shape[1])
        self.wq = quantize.quantize_data(w, cfg.lw, cfg.p)      # (d, c) field
        kmask, self._kflush = jax.random.split(jax.random.PRNGKey(0)
                                               if key is None else key)
        # provision-time encode: all K slots carry the SAME W̄ (the row
        # split parallelizes the QUERY batch, not the model), + T uniform
        # masks — drawn once, reused for every query (module docstring).
        parts = jnp.broadcast_to(self.wq[None], (cfg.K, self.d, self.classes))
        masks = lagrange.draw_masks(kmask, cfg.T, (self.d, self.classes),
                                    cfg.p)
        self.w_shares = np.asarray(
            lagrange.encode(cfg.scheme, parts, masks, cfg.p))   # (N, d, c)
        self.latency = latency
        self.collect_all = collect_all
        self.verify = verify
        self.exclude_stragglers = exclude_stragglers
        self.round_timeout_s = round_timeout_s
        self.scheduler = EventScheduler(cfg.N, latency, transport,
                                        recorder=recorder)
        self.obs = self.scheduler.obs
        self.obs.bind_clock(self.scheduler.time.now)
        if self.distributed and math.isinf(round_timeout_s):
            self.round_timeout_s = 300.0     # real silence must be detectable
        self.monitor = HeartbeatMonitor(cfg.N, timeout_s=heartbeat_timeout_s,
                                        straggler_factor=straggler_factor,
                                        now=self.scheduler.clock)
        # the serving fleet is a MembershipView like training's (DESIGN.md
        # §13) — fixed here (model shares are provisioned ONCE and reused
        # for every flush, so an elastic join would need a share ship, not
        # just an epoch bump), but the scheduler reads its worker set off
        # the membership rather than a frozen int either way
        self.membership = ClusterMembership(range(cfg.N),
                                            monitor=self.monitor)
        self.scheduler.bind_membership(self.membership)
        self.policy = BatchingPolicy(cfg.max_batch, cfg.max_wait_s)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._init_metrics()
        self._queue: collections.deque[_Pending] = collections.deque()
        self._queued_rows = 0
        self._epoch = 0.0                    # run()-start clock offset
        self._round = 0
        self._last_order: np.ndarray | None = None
        self.results: dict[int, Prediction] = {}
        self.rejected: list[int] = []
        self.traces: dict[int, RoundTrace] = {}
        self.lat_first: list[float] = []     # per query, first-threshold
        self.lat_all: list[float] = []       # per query, wait-for-all
        self.oracle_checked = 0
        self.oracle_mismatches = 0
        self._served_rows = 0
        self._t_first_query: float | None = None
        self._t_last_done: float | None = None

    @property
    def distributed(self) -> bool:
        return self.latency is None

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def _init_metrics(self) -> None:
        m = self.metrics
        self._m_queries = m.counter(
            "serve_queries_total", "queries admitted to the request queue")
        self._m_rejected = m.counter(
            "serve_rejected_total",
            "queries rejected at admission (queue full or oversized)")
        self._m_rounds = m.counter(
            "serve_rounds_total", "coded flushes dispatched")
        self._m_starved = m.counter(
            "serve_starved_rounds_total",
            "flushes with fewer than threshold responses in the timeout")
        self._m_depth = m.gauge(
            "serve_queue_depth", "admitted-but-unflushed queries")
        self._m_fill = m.gauge(
            "serve_batch_fill", "row fill fraction of the last coded flush")
        self._m_p99 = m.gauge(
            "serve_p99_s", "p99 first-threshold query latency so far")
        self._m_lat = m.histogram(
            "serve_latency_seconds",
            "query submission to decoded prediction, first-threshold policy")

    # ------------------------------------------------------------------
    # Distributed provisioning: W̃_i to each worker, once
    # ------------------------------------------------------------------

    def provision(self, timeout_s: float = 60.0) -> None:
        """Ship every worker its model share W̃_i + static serve context;
        block until all N ack (worker warm-compiles its fixed-shape field
        matmul before acking, so no flush ever absorbs an XLA compile)."""
        assert self.distributed, "provision() is for real transports only"
        members = list(self.membership.view().members)
        with self.obs.span("provision", workers=len(members)):
            tr = self.scheduler.transport
            now = self.scheduler.clock
            for w in members:
                tr.send(worker_endpoint(w),
                        EncodeShare(PROVISION_ROUND, w,
                                    {"protocol": "serve",
                                     "w_share": self.w_shares[w],
                                     "p": self.cfg.p,
                                     "rows": self.cfg.rows_per_part,
                                     "trace": bool(self.obs.enabled)}),
                        at=now)
            await_worker_acks(tr, lambda: self.scheduler.clock, set(members),
                              self.monitor, timeout_s)

    def shutdown_workers(self) -> None:
        assert self.distributed
        now = self.scheduler.clock
        for w in self.membership.view().members:
            self.scheduler.transport.send(
                worker_endpoint(w), EncodeShare(SHUTDOWN_ROUND, w), at=now)

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------

    def submit(self, query: Query, now: float | None = None) -> bool:
        """Admit one query into the bounded queue.  False = rejected
        (queue at ``queue_cap``, or more rows than a flush can carry) —
        the client's backpressure signal, never silent loss."""
        now = self.scheduler.clock if now is None else now
        rows = int(np.asarray(query.x).shape[0])
        if rows > self.cfg.max_batch or rows <= 0 \
                or len(self._queue) >= self.cfg.queue_cap:
            self._m_rejected.inc()
            self.rejected.append(query.qid)
            return False
        self._queue.append(_Pending(query, admitted_at=now,
                                    sent_abs=self._epoch + query.sent_at,
                                    rows=rows))
        self._queued_rows += rows
        self._m_queries.inc()
        self._m_depth.set(len(self._queue))
        return True

    # ------------------------------------------------------------------
    # Dispatch-set policy (same shape as ClusterRunner)
    # ------------------------------------------------------------------

    def _alive(self, now: float) -> np.ndarray:
        return np.array(
            [i for i in self.monitor.workers
             if not self.monitor.is_dead(i, now=now)], dtype=np.int64)

    def dispatch_set(self) -> np.ndarray:
        now = self.scheduler.clock
        alive = self._alive(now)
        if self.exclude_stragglers:
            fast = self.monitor.survivors(now=now)
            # strictly more than threshold: speculative exclusion must
            # leave slack for an undetected silent failure
            if len(fast) > self.cfg.threshold:
                return fast
        return alive

    # ------------------------------------------------------------------
    # Flush: pack -> encode -> dispatch -> threshold decode -> respond
    # ------------------------------------------------------------------

    def _take_flush(self) -> list[_Pending]:
        """Pop whole queries off the queue head while they fit the flush
        (FIFO — a query is never split across flushes)."""
        batch: list[_Pending] = []
        used = 0
        while self._queue and used + self._queue[0].rows <= self.cfg.max_batch:
            pend = self._queue.popleft()
            self._queued_rows -= pend.rows
            used += pend.rows
            batch.append(pend)
        return batch

    def _encode_flush(self, batch: list[_Pending], t: int
                      ) -> tuple[np.ndarray, jax.Array, list[tuple[int, int]]]:
        """(N, b, d) query shares + the quantized padded batch + per-query
        row spans.  Rows are zero-padded to max_batch so the worker-side
        jit shape stays static across flushes."""
        cfg = self.cfg
        x = np.zeros((cfg.max_batch, self.d), np.float32)
        spans: list[tuple[int, int]] = []
        row = 0
        for pend in batch:
            x[row: row + pend.rows] = np.asarray(pend.query.x, np.float32)
            spans.append((row, row + pend.rows))
            row += pend.rows
        xq = quantize.quantize_data(jnp.asarray(x), cfg.lx, cfg.p)
        parts = xq.reshape(cfg.K, cfg.rows_per_part, self.d)
        masks = lagrange.draw_masks(               # FRESH masks per flush
            jax.random.fold_in(self._kflush, t), cfg.T,
            (cfg.rows_per_part, self.d), cfg.p)
        shares = np.asarray(lagrange.encode(cfg.scheme, parts, masks, cfg.p))
        return shares, xq, spans

    def _decode_flush(self, trace: RoundTrace, shares: np.ndarray,
                      decoder: decode.StreamingDecoder | None) -> np.ndarray:
        """(max_batch, classes) real logits from the first-threshold
        responders — exact mod-p interpolation, then dequantize."""
        cfg = self.cfg
        order = np.asarray(trace.responders[: cfg.threshold], dtype=np.int64)
        if decoder is not None:                    # socket: shares folded
            parts = decoder.finish(order)          # (K, b, c) int32
            yq = jnp.asarray(parts)
        else:                                      # sim: master evaluates
            xs = jnp.asarray(shares[order])        # (R, b, d)
            ws = jnp.asarray(self.w_shares[order])  # (R, d, c)
            res = jax.vmap(lambda a, b: field.matmul(a, b, cfg.p))(xs, ws)
            yq = lagrange.decode(cfg.scheme, res, order, SERVE_DEG_F, cfg.p)
        self._last_order = np.asarray(trace.responders, dtype=np.int64)
        flat = yq.reshape(cfg.max_batch, self.classes)
        return np.asarray(quantize.dequantize(flat, cfg.lx + cfg.lw, cfg.p))

    def oracle_logits(self, x) -> np.ndarray:
        """Uncoded plaintext oracle: quantize -> one field matmul against
        W̄ -> dequantize.  The coded path must match this bit for bit."""
        xq = quantize.quantize_data(jnp.asarray(x, jnp.float32),
                                    self.cfg.lx, self.cfg.p)
        return self._oracle_from_quantized(xq)

    def _oracle_from_quantized(self, xq: jax.Array) -> np.ndarray:
        yq = field.matmul(xq, self.wq, self.cfg.p)
        return np.asarray(quantize.dequantize(
            yq, self.cfg.lx + self.cfg.lw, self.cfg.p))

    def _flush(self, now: float) -> None:
        cfg = self.cfg
        batch = self._take_flush()
        if not batch:
            return
        t = self._round
        self._round += 1
        used = sum(p.rows for p in batch)
        span = self.obs.begin("serve_round", round=t, queries=len(batch),
                              rows=used)
        enc0 = _time.perf_counter()
        shares, xq, spans = self._encode_flush(batch, t)
        enc_s = _time.perf_counter() - enc0
        workers = self.dispatch_set()
        if len(workers) < cfg.threshold:
            self._m_starved.inc()
            self.obs.end(span, starved=True)
            raise ClusterDecodeError(
                f"flush {t}: only {len(workers)} live workers "
                f"< threshold {cfg.threshold}")
        payloads = decoder = on_result = None
        if self.distributed:
            payloads = {int(w): {"x_share": shares[int(w)]} for w in workers}
            decoder = decode.StreamingDecoder(
                cfg, decode.prefix_decode_plan(cfg, self._last_order))

            def on_result(w, payload, _d=decoder):
                _d.fold(w, payload)
        trace = self.scheduler.dispatch_round(
            t, cfg.threshold, workers, monitor=self.monitor,
            timeout_s=self.round_timeout_s, payloads=payloads,
            collect_all=self.collect_all, on_result=on_result)
        if self.scheduler.time.real:
            trace.encode_s = enc_s    # measured wall encode (batch span)
        if not math.isfinite(trace.t_first_R):
            for w in workers:
                if int(w) not in trace.arrivals:
                    self.monitor.mark_failed(int(w))
            self._m_starved.inc()
            self.obs.end(span, starved=True)
            raise ClusterDecodeError(
                f"flush {t}: {len(trace.responders)} responses "
                f"< threshold {cfg.threshold} within "
                f"{self.round_timeout_s}s")
        dec0 = _time.perf_counter()
        logits = self._decode_flush(trace, shares, decoder)
        dec_s = _time.perf_counter() - dec0
        if self.verify:
            self.oracle_checked += 1
            if not np.array_equal(logits, self._oracle_from_quantized(xq)):
                self.oracle_mismatches += 1
        # the first-threshold decode instant: the threshold-th arrival plus
        # the measured decode.  Deliberately NOT the post-dispatch clock —
        # under collect_all the dispatch loop stays open until every
        # straggler reports (the wait-for-all COUNTERFACTUAL), and that
        # wait must not leak into the latency the first-T policy delivers.
        t_done = trace.t_first_R + (dec_s if self.scheduler.time.real
                                    else 0.0)
        if self.scheduler.time.real:
            trace.decode_s = dec_s
        self.traces[t] = trace
        self._respond(batch, spans, logits, trace, t_done, t)
        self.obs.end(span, responders=len(trace.responders))
        self._m_rounds.inc()
        self._m_fill.set(used / cfg.max_batch)
        self._m_depth.set(len(self._queue))
        if self.lat_first:
            self._m_p99.set(float(np.percentile(self.lat_first, 99)))

    def _respond(self, batch: list[_Pending], spans: list[tuple[int, int]],
                 logits: np.ndarray, trace: RoundTrace, t_done: float,
                 t: int) -> None:
        for pend, (r0, r1) in zip(batch, spans):
            q = pend.query
            lat = t_done - pend.sent_abs
            lat_all = (trace.t_all - pend.sent_abs
                       if math.isfinite(trace.t_all) else math.inf)
            self.results[q.qid] = Prediction(
                qid=q.qid, client=q.client, y=logits[r0:r1], latency_s=lat)
            self.lat_first.append(lat)
            self.lat_all.append(lat_all)
            self._m_lat.observe(lat)
            self._served_rows += pend.rows
            if self._t_first_query is None \
                    or pend.sent_abs < self._t_first_query:
                self._t_first_query = pend.sent_abs
            self._t_last_done = t_done
            if self.obs.enabled:
                track = f"query/{q.qid}"
                self.obs.add_span("queue", pend.admitted_at, trace.t_start,
                                  track=track, round=t)
                self.obs.add_span("batch", trace.t_start - trace.encode_s,
                                  trace.t_start, track=track, round=t)
                self.obs.add_span("dispatch", trace.t_start, trace.t_first_R,
                                  track=track, round=t,
                                  responders=len(trace.responders))
                self.obs.add_span("decode", trace.t_first_R, t_done,
                                  track=track, round=t)
        if self.obs.enabled:
            for w, wspans in trace.worker_traces.items():
                self.obs.add_process_spans(f"worker{int(w)}", wspans, round=t)

    # ------------------------------------------------------------------
    # Client loops
    # ------------------------------------------------------------------

    def run(self, queries: list[Query]) -> dict[int, Prediction]:
        """Open-loop service: admit each query at its ``sent_at`` offset
        (relative to the call instant), flush under the batching policy,
        drain the queue, return every decoded Prediction by qid."""
        queries = sorted(queries, key=lambda q: q.sent_at)
        self._epoch = self.scheduler.clock
        i = 0
        while i < len(queries) or self._queue:
            now = self.scheduler.clock
            while i < len(queries) \
                    and self._epoch + queries[i].sent_at <= now:
                self.submit(queries[i], now=now)
                i += 1
            if self._queue and self.policy.should_flush(
                    self._queued_rows, now - self._queue[0].admitted_at):
                self._flush(now)
                continue
            nxt = math.inf
            if i < len(queries):
                nxt = self._epoch + queries[i].sent_at
            if self._queue:
                nxt = min(nxt, self.policy.deadline(
                    self._queue[0].admitted_at))
            if not math.isfinite(nxt):
                break
            if nxt <= now:
                # float-rounding guard: admitted_at + max_wait can land
                # exactly on `now` while now - admitted_at still rounds
                # below max_wait — the clock cannot progress, so the
                # oldest query's wait is over and the flush is due
                self._flush(now)
                continue
            if self.scheduler.time.real:
                _time.sleep(max(0.0, nxt - self.scheduler.clock))
            else:
                self.scheduler.time.advance_to(nxt)
        return self.results

    def run_closed_loop(self, queries: list[Query]) -> dict[int, Prediction]:
        """Closed-loop service: one query in flight at a time, each flushed
        immediately — the zero-queueing throughput ceiling (pair with
        full-batch queries so every flush is saturated)."""
        for q in queries:
            now = self.scheduler.clock
            if self.submit(dataclasses.replace(
                    q, sent_at=now - self._epoch), now=now):
                self._flush(now)
        return self.results

    # ------------------------------------------------------------------
    # Reporting
    # ------------------------------------------------------------------

    @staticmethod
    def _lat_summary(a: list[float]) -> dict[str, float]:
        fin = np.asarray([v for v in a if math.isfinite(v)], dtype=float)
        if fin.size == 0:
            return {"n": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0,
                    "unobserved": len(a)}
        return {"n": int(fin.size), "mean": float(fin.mean()),
                "p50": float(np.percentile(fin, 50)),
                "p99": float(np.percentile(fin, 99)),
                "unobserved": len(a) - int(fin.size)}

    def stats(self) -> dict[str, Any]:
        """Served/rejected counts, queries/s, and p50/p99 latency under
        BOTH wait policies — first-threshold (what this server does) and
        wait-for-all (the counterfactual, from the same traces' ``t_all``)."""
        served = len(self.results)
        elapsed = 0.0
        if served and self._t_last_done is not None \
                and self._t_first_query is not None:
            elapsed = max(self._t_last_done - self._t_first_query, 1e-12)
        return {
            "queries": served,
            "rejected": len(self.rejected),
            "rounds": self._round,
            "rows": self._served_rows,
            "elapsed_s": elapsed,
            "queries_per_s": served / elapsed if elapsed else 0.0,
            "rows_per_s": self._served_rows / elapsed if elapsed else 0.0,
            "latency_first": self._lat_summary(self.lat_first),
            "latency_all": self._lat_summary(self.lat_all),
            "oracle": {"checked": self.oracle_checked,
                       "bit_identical": self.oracle_mismatches == 0},
        }
