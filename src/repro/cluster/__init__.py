"""Coded cluster runtime: event-driven master/worker simulation (DESIGN.md §7).

Simulates the paper's EC2 deployment end-to-end: a master dispatches each
protocol round to N workers over a transport, collects results as they
arrive in simulated time, and decodes the moment the fastest ``threshold``
responders are in — the first-T-responders property that separates coded
computing from MPC baselines (which must wait for everyone, every round).

Modules:

  messages.py   typed master<->worker messages (EncodeShare, WorkerResult,
                Heartbeat) + endpoint naming
  transport.py  transport abstraction; InProcessTransport delivers on a
                simulated clock (heap of pending deliveries), interface
                ready for a multi-process socket transport later
  latency.py    seeded, replayable per-worker latency models
                (deterministic / lognormal-tail / bursty-straggler / dead)
  scheduler.py  the event loop: dispatch round -> advance clock to next
                arrival -> decode at the threshold-th result; records
                first-T vs wait-all completion times per round
  runner.py     ClusterRunner: drives core/protocol rounds through the
                scheduler, feeds observed responder traces into decode
                matrix selection, integrates runtime/resilience
                (HeartbeatMonitor exclusion + ResilientLoop checkpointing)

Numerics stay in core/protocol: the runner calls ``engine.round_fn`` with
its observed responder order, so cluster training is bit-identical to
``engine.train_reference`` replaying the same trace (tests/test_cluster.py).
"""
from repro.cluster.latency import (
    BurstyStragglerLatency,
    DeadWorkerLatency,
    DeterministicLatency,
    LatencyModel,
    LognormalTailLatency,
    make_latency,
)
from repro.cluster.messages import (
    MASTER,
    EncodeShare,
    Heartbeat,
    WorkerResult,
    worker_endpoint,
)
from repro.cluster.runner import ClusterRunner, RoundRecord, wait_summary
from repro.cluster.scheduler import (
    ClusterDecodeError,
    EventScheduler,
    RoundTrace,
)
from repro.cluster.transport import InProcessTransport, Transport

__all__ = [
    "MASTER",
    "BurstyStragglerLatency",
    "ClusterDecodeError",
    "ClusterRunner",
    "DeadWorkerLatency",
    "DeterministicLatency",
    "EncodeShare",
    "EventScheduler",
    "Heartbeat",
    "InProcessTransport",
    "LatencyModel",
    "LognormalTailLatency",
    "RoundRecord",
    "RoundTrace",
    "Transport",
    "WorkerResult",
    "make_latency",
    "wait_summary",
    "worker_endpoint",
]
