"""Coded cluster runtime: event-driven master/worker simulation (DESIGN.md §7).

Simulates the paper's EC2 deployment end-to-end: a master dispatches each
protocol round to N workers over a transport, collects results as they
arrive in simulated time, and decodes the moment the fastest ``threshold``
responders are in — the first-T-responders property that separates coded
computing from MPC baselines (which must wait for everyone, every round).

Modules:

  messages.py          typed master<->worker messages (EncodeShare,
                       WorkerResult, Heartbeat) + endpoint naming
  transport.py         transport abstraction; InProcessTransport delivers
                       on a simulated clock (heap of pending deliveries)
  wire.py              length-prefixed pickle-free framing for the messages
                       (dtype/shape + raw bytes for field arrays, exact
                       big-endian encoding for python ints)
  socket_transport.py  the SAME Transport contract over real TCP: a
                       selectors-based master endpoint, worker client
                       connections, wall-clock arrival stamps
  latency.py           seeded, replayable per-worker latency models
                       (deterministic / lognormal-tail / bursty / dead)
  membership.py        elastic fleet membership (DESIGN.md §13): an epoch-
                       numbered MembershipView state machine — JOINs admit
                       pre-encoded spare Lagrange slots, LEAVEs retire dead
                       members permanently; every round derives its
                       dispatch set + decode plan from one epoch snapshot
  master_group.py      d-sharded master group (DESIGN.md §13): S masters
                       each encode + stream-decode a contiguous 1/S slice
                       of the model dimension, bit-identical to one master
  scheduler.py         the event loop on either clock: dispatch round ->
                       advance/await the next arrival -> decode at the
                       threshold-th result; records first-T vs wait-all
                       completion times per round
  pipeline.py          pipelined round engine (DESIGN.md §9): a one-round-
                       ahead prefetch thread building each round's
                       W-independent context (fresh masks + their encoded
                       contribution, batch draw, predicted-order decode
                       coefficients) while the previous round is in flight
  runner.py            ClusterRunner: drives core/protocol rounds through
                       the scheduler — simulated workers via round_fn, or
                       real worker processes (launch/cpml_worker.py) whose
                       serialized results feed engine.update_fn —
                       integrates runtime/resilience; --pipeline modes
                       overlap encode/decode with in-flight compute
  mpc_runner.py        MPCClusterRunner: the BGW MPC baseline as a real
                       distributed protocol over the SAME runtime — r+1
                       all-to-all reshare barriers per iteration (SubShare
                       peer traffic), reconstruction at the first 2T+1
                       CombineResults, bit-identical to the
                       core/mpc_baseline single-host oracle
  serve.py             prediction serving plane (DESIGN.md §12): model
                       shares provisioned once, client Query batches
                       admitted into a bounded queue, flushed under a
                       max-batch/max-wait policy, decoded at the first
                       2(K+T-1)+1 responders, bit-identical to the
                       uncoded plaintext oracle

Numerics stay in core/protocol: the runner feeds its observed responder
order into the exact round/update functions train()/train_reference() use,
so cluster training — simulated OR over sockets — is bit-identical to
``engine.train_reference`` replaying the same trace (tests/test_cluster.py,
tests/test_socket_cluster.py).
"""
from repro.cluster.latency import (
    BurstyStragglerLatency,
    DeadWorkerLatency,
    DeterministicLatency,
    LatencyModel,
    LognormalTailLatency,
    SleepyStragglerLatency,
    make_latency,
)
from repro.cluster.master_group import MasterGroup, ShardedStreamingDecoder
from repro.cluster.membership import (
    ClusterMembership,
    MembershipView,
    Transition,
)
from repro.cluster.messages import (
    MASTER,
    PROVISION_ROUND,
    SHUTDOWN_ROUND,
    CombineResult,
    EncodeShare,
    Epoch,
    Heartbeat,
    Join,
    Prediction,
    Query,
    SubShare,
    WorkerResult,
    worker_endpoint,
)
from repro.cluster.serve import (
    BatchingPolicy,
    PredictionServer,
    ServeConfig,
    open_loop_queries,
)
from repro.cluster.mpc_runner import MPCClusterRunner, mpc_phase_models
from repro.cluster.pipeline import (
    PIPELINE_MODES,
    RoundContext,
    RoundPrefetcher,
)
from repro.cluster.runner import ClusterRunner, RoundRecord, wait_summary
from repro.cluster.scheduler import (
    Clock,
    ClusterDecodeError,
    EventScheduler,
    MPCRoundTrace,
    RoundTrace,
    SimClock,
    WallClock,
)
from repro.cluster.socket_transport import SocketTransport
from repro.cluster.transport import InProcessTransport, Transport

__all__ = [
    "MASTER",
    "PROVISION_ROUND",
    "SHUTDOWN_ROUND",
    "BatchingPolicy",
    "BurstyStragglerLatency",
    "Clock",
    "ClusterDecodeError",
    "ClusterMembership",
    "ClusterRunner",
    "CombineResult",
    "DeadWorkerLatency",
    "DeterministicLatency",
    "EncodeShare",
    "Epoch",
    "EventScheduler",
    "Heartbeat",
    "InProcessTransport",
    "Join",
    "LatencyModel",
    "LognormalTailLatency",
    "MPCClusterRunner",
    "MPCRoundTrace",
    "MasterGroup",
    "MembershipView",
    "PIPELINE_MODES",
    "Prediction",
    "PredictionServer",
    "Query",
    "RoundContext",
    "RoundPrefetcher",
    "RoundRecord",
    "RoundTrace",
    "ServeConfig",
    "ShardedStreamingDecoder",
    "SimClock",
    "SleepyStragglerLatency",
    "SocketTransport",
    "SubShare",
    "Transition",
    "Transport",
    "WallClock",
    "WorkerResult",
    "make_latency",
    "mpc_phase_models",
    "open_loop_queries",
    "wait_summary",
    "worker_endpoint",
]
