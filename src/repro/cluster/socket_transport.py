"""SocketTransport: the Transport contract over real TCP (DESIGN.md §7).

The multi-process backend transport.py promises: the SAME typed messages
(messages.py) carried as length-prefixed wire frames (wire.py) between one
master endpoint and N worker processes on real sockets, with the wall clock
replacing the simulated clock.

Topology is a star: the master listens; each worker connects and registers
with a HELLO frame naming its endpoint ("worker/3").  Worker<->worker
delivery (the MPC reshare round) still works through the star: a worker's
``send`` to a peer endpoint wraps the frame in a Forward envelope addressed
via the master, which relays the inner bytes verbatim to the destination
connection.  Either side holds ONE ``SocketTransport`` whose ``local``
endpoint is its own name:

  * ``SocketTransport.master(...)``  — selectors-based server; ``send`` routes
    by destination endpoint to the registered connection.
  * ``SocketTransport.connect(...)`` — worker client; its only peer is the
    master.

Wire v2 (DESIGN.md §10) hangs off the HELLO handshake: a v2 client sends
HELLO2 carrying its version, the v2 master acks with its own HELLO2, and
each side speaks ``min(theirs, ours)`` to that peer from then on.  A plain
HELLO (or no ack) pins the peer at v1, so old and new builds interoperate
frame-for-frame.  The send path serializes to an iovec of memoryviews
(``wire.serialize_iovec``) flushed with ``socket.sendmsg`` scatter-gather —
frames are never joined into one bytes copy, and a partially written buffer
resumes from a sliced memoryview, never a re-copy.  The recv path reads
into one persistent per-transport scratch buffer (``recv_into``) and the
FrameReader decodes arrays straight out of it.  Per-endpoint tx/rx byte and
frame counters (``wire_stats``) make coalescing/packing wins measurable.

Contract mapping (the backend-shared contract tests pin this):

  * ``send(dst, msg, at, delay)`` — ``at`` is ignored (the wall clock is
    always "now"); a finite positive ``delay`` holds the frame in a timer
    thread before writing (real injected latency); ``delay == math.inf``
    drops the message — same "lost in the void" semantics as the simulated
    backend, which is also what a write to a dead peer degrades to.
  * ``recv(dst, now)`` — pops locally-arrived messages stamped ``<= now``;
    arrival stamps are ``time.monotonic()`` at the moment the frame was read
    off the socket.
  * ``next_delivery(dst)`` — polls the selector up to ``poll_interval_s``
    and returns the earliest queued arrival stamp, or None if nothing has
    arrived YET (callers on a real clock poll again until their deadline).
"""
from __future__ import annotations

import collections
import heapq
import itertools
import math
import selectors
import socket
import threading
import time
from typing import Any

from repro.cluster.messages import MASTER
from repro.cluster.transport import Transport
from repro.cluster import wire

_RECV_CHUNK = 1 << 18
_OUTBOX_MAX = 1 << 28            # per-destination cap on buffered send bytes
_SENDMSG_BATCH = 64              # iovec entries per sendmsg call (< IOV_MAX)


def _new_stat() -> dict[str, int]:
    return {"tx_bytes": 0, "tx_frames": 0, "rx_bytes": 0, "rx_frames": 0}


class SocketTransport(Transport):
    real = True

    def __init__(self, local: str, poll_interval_s: float = 0.05,
                 wire_version: int = wire.WIRE_VERSION):
        self.local = local
        self.poll_interval_s = poll_interval_s
        self.wire_version = wire_version
        self._sel = selectors.DefaultSelector()
        self._listener: socket.socket | None = None
        self._conns: dict[str, socket.socket] = {}      # endpoint -> conn
        self._readers: dict[socket.socket, wire.FrameReader] = {}
        self._names: dict[socket.socket, str | None] = {}
        self._inbox: list[tuple[float, int, Any]] = []  # (stamp, seq, msg)
        self._seq = itertools.count()
        self._wlock = threading.Lock()   # guards the endpoint/conn maps
        self._conn_locks: dict[str, threading.Lock] = {}  # per-endpoint
        # per-destination outbox: a deque of BUFFERS (bytes/memoryview) in
        # stream order; a partial send slices the head view forward in place
        self._outbox: dict[str, collections.deque] = {}
        self._outbox_bytes: dict[str, int] = {}
        # negotiated wire version per peer endpoint; absent/1 until a HELLO2
        # exchange proves the peer speaks v2 (DESIGN.md §10)
        self._peer_version: dict[str, int] = {}
        # per-endpoint tx/rx byte+frame counters; "(handshake)" buckets the
        # few pre-HELLO bytes of a connection that hasn't named itself yet
        self._stats: dict[str, dict[str, int]] = {}
        self._scratch = bytearray(_RECV_CHUNK)   # persistent recv buffer
        # write serialization: a slow peer must only delay ITS frames
        self._timers: list[threading.Timer] = []
        self._closed = False
        self.peer_closed = False         # a registered peer hung up
        self.port: int | None = None

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------

    @classmethod
    def master(cls, host: str = "127.0.0.1", port: int = 0,
               backlog: int = 64, **kw) -> "SocketTransport":
        t = cls(MASTER, **kw)
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host, port))
        srv.listen(backlog)
        srv.setblocking(False)
        t._listener = srv
        t.port = srv.getsockname()[1]
        t._sel.register(srv, selectors.EVENT_READ)
        return t

    @classmethod
    def connect(cls, host: str, port: int, endpoint: str,
                timeout_s: float = 10.0, **kw) -> "SocketTransport":
        t = cls(endpoint, **kw)
        conn = socket.create_connection((host, port), timeout=timeout_s)
        t._register(conn, MASTER)
        # a v2 client announces its version via HELLO2; the master's HELLO2
        # ack (consumed in _poll) upgrades the return direction.  Until the
        # ack lands we speak v1 to the master — always safe.
        hello = wire.Hello(endpoint, version=t.wire_version)
        t._write(MASTER, wire.serialize_iovec(hello, t.wire_version))
        return t

    def _register(self, conn: socket.socket, name: str | None) -> None:
        conn.setblocking(False)
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        # our reader speaks OUR version: a v1 transport rejects v2 tags
        # exactly like a real v1 build would
        self._readers[conn] = wire.FrameReader(version=self.wire_version)
        self._names[conn] = name
        if name is not None:
            with self._wlock:
                self._conns[name] = conn
                # pre-provision the per-destination send state once, at
                # registration, not lazily on the hot send path
                self._conn_locks.setdefault(name, threading.Lock())
                self._outbox.setdefault(name, collections.deque())
                self._outbox_bytes.setdefault(name, 0)
                self._stats.setdefault(name, _new_stat())
        self._sel.register(conn, selectors.EVENT_READ)

    # ------------------------------------------------------------------
    # Event pump (runs on the caller's thread; selectors-based)
    # ------------------------------------------------------------------

    def _stat(self, name: str | None) -> dict[str, int]:
        return self._stats.setdefault(name or "(handshake)", _new_stat())

    def _poll(self, timeout: float) -> None:
        if self._closed:
            return
        for key, _ in self._sel.select(timeout):
            sock = key.fileobj
            if sock is self._listener:
                try:
                    conn, _ = sock.accept()
                except OSError:
                    continue              # client aborted mid-handshake
                self._register(conn, None)    # named once HELLO arrives
                continue
            try:
                n = sock.recv_into(self._scratch)
            except (BlockingIOError, InterruptedError):
                continue
            except OSError:
                n = 0
            if not n:
                self._drop(sock)
                continue
            self._stat(self._names.get(sock))["rx_bytes"] += n
            for msg in self._readers[sock].feed(memoryview(self._scratch)[:n]):
                self._stat(self._names.get(sock))["rx_frames"] += 1
                if isinstance(msg, wire.Hello):
                    self._names[sock] = msg.endpoint
                    with self._wlock:
                        self._conns[msg.endpoint] = sock
                        self._conn_locks.setdefault(msg.endpoint,
                                                    threading.Lock())
                        self._outbox.setdefault(msg.endpoint,
                                                collections.deque())
                        self._outbox_bytes.setdefault(msg.endpoint, 0)
                        self._stats.setdefault(msg.endpoint, _new_stat())
                    self._peer_version[msg.endpoint] = min(self.wire_version,
                                                           msg.version)
                    # negotiation ack: the listening master answers a v2
                    # HELLO2 with its own, upgrading the master->worker
                    # direction; a v1 HELLO gets no ack (a real v1 master
                    # wouldn't know how) and the peer stays at v1
                    if self._listener is not None and msg.version >= wire.WIRE_V2 \
                            and self.wire_version >= wire.WIRE_V2:
                        ack = wire.Hello(self.local, version=self.wire_version)
                        self._write(msg.endpoint,
                                    wire.serialize_iovec(ack, wire.WIRE_V2))
                elif isinstance(msg, wire.Forward):
                    # star-topology relay (DESIGN.md §7): worker->worker
                    # frames ride to the master inside a Forward; pass the
                    # inner frame bytes on verbatim.  An unknown/dead
                    # destination drops the frame — the same lost-in-the-
                    # void semantics every send to a dead peer has.
                    if msg.dst == self.local:
                        for inner in wire.FrameReader().feed(msg.frame):
                            heapq.heappush(
                                self._inbox,
                                (time.monotonic(), next(self._seq), inner))
                    else:
                        self._write(msg.dst, [msg.frame])
                else:
                    heapq.heappush(self._inbox,
                                   (time.monotonic(), next(self._seq), msg))
        self._flush_outboxes()

    def _drop(self, sock: socket.socket) -> None:
        name = self._names.pop(sock, None)
        self._readers.pop(sock, None)
        with self._wlock:
            if name is not None:
                self._outbox.pop(name, None)
                self._outbox_bytes.pop(name, None)
            if name is not None and self._conns.get(name) is sock:
                del self._conns[name]
                self._conn_locks.pop(name, None)
        try:
            self._sel.unregister(sock)
        except (KeyError, ValueError):
            pass
        sock.close()
        if name is not None:
            self.peer_closed = True

    # ------------------------------------------------------------------
    # Transport contract
    # ------------------------------------------------------------------

    def peer_version(self, dst: str) -> int:
        """Negotiated wire version toward ``dst`` (1 until proven v2)."""
        return min(self.wire_version, self._peer_version.get(dst, wire.WIRE_V1))

    def send(self, dst: str, msg: Any, at: float = 0.0,
             delay: float = 0.0) -> None:
        if math.isinf(delay):
            return                        # lost in the void, like the sim
        if self.local != MASTER and dst != MASTER:
            # a worker's only wire is to the master: peer traffic (SubShare
            # reshares) is enveloped and relayed — see _poll's Forward arm.
            # The INNER frame is always v1: the sender cannot know what the
            # final recipient negotiated with the master.
            inner = wire.serialize(msg, wire.WIRE_V1)
            bufs = wire.serialize_iovec(wire.Forward(dst, inner),
                                        self.peer_version(MASTER))
            dst = MASTER
        else:
            bufs = wire.serialize_iovec(msg, self.peer_version(dst))
        if delay > 0:
            # prune fired timers so a long-lived transport with injected
            # latency doesn't grow the list (and its frame bytes) unboundedly
            self._timers = [t for t in self._timers if t.is_alive()]
            timer = threading.Timer(delay, self._write, (dst, bufs))
            timer.daemon = True
            self._timers.append(timer)
            timer.start()
        else:
            self._write(dst, bufs)

    def _write(self, dst: str, bufs: list) -> None:
        """Enqueue one frame (as its iovec buffers) for ``dst`` and flush
        what the socket accepts NOW; the rest drains on later polls.

        All writes to an endpoint go through ONE per-destination outbox, so
        frames can never interleave mid-frame (a partially flushed SubShare
        followed by a direct EncodeShare send would desynchronize the
        recipient's FrameReader permanently) and a slow reader — e.g. an
        alive MPC straggler mid-sleep whose buffers fill with relayed
        reshare traffic — only ever DELAYS its own frames, never loses or
        corrupts them, and never blocks sends to healthy peers or the
        caller's thread.  Loss happens exactly where death semantics want
        it: unknown/closed endpoints, EOF (``_drop`` clears the outbox),
        and an outbox past ``_OUTBOX_MAX`` (a peer that stopped reading for
        good).  Sockets stay non-blocking for the selector loop; a
        timer-thread send simply parks in the outbox like any other.
        """
        nbytes = sum(len(b) for b in bufs)
        with self._wlock:
            conn = self._conns.get(dst)
            if conn is None or self._closed:
                return                    # unknown or dead peer: dropped
            lock = self._conn_locks.setdefault(dst, threading.Lock())
            # dict MEMBERSHIP changes only under _wlock (timer threads call
            # _write concurrently with the poll loop's outbox iteration);
            # the queue's CONTENTS are guarded by the per-endpoint lock.
            q = self._outbox.setdefault(dst, collections.deque())
            stat = self._stats.setdefault(dst, _new_stat())
        with lock:
            if self._outbox_bytes.get(dst, 0) + nbytes > _OUTBOX_MAX:
                return            # reader gone for good: lost in the void
            q.extend(bufs)
            self._outbox_bytes[dst] = (self._outbox_bytes.get(dst, 0)
                                       + nbytes)
            stat["tx_bytes"] += nbytes
            stat["tx_frames"] += 1
            self._drain_outbox_locked(dst, conn)

    def _drain_outbox_locked(self, dst: str, conn: socket.socket) -> None:
        """Write as much outbox as ``dst``'s socket accepts (lock held),
        scatter-gather: up to ``_SENDMSG_BATCH`` queued buffers per
        ``sendmsg`` call.  A partial write slices the head buffer's
        memoryview forward — the unsent tail is never re-copied — so the
        byte stream always resumes exactly where it stopped; the byte
        accounting is incremental (O(1) per send, not O(queue))."""
        q = self._outbox.get(dst)
        if not q:
            return
        try:
            while q:
                bufs = list(itertools.islice(q, _SENDMSG_BATCH))
                try:
                    sent = conn.sendmsg(bufs)
                except (BlockingIOError, InterruptedError):
                    return            # socket full: later polls resume
                self._outbox_bytes[dst] -= sent
                while sent:
                    head = q[0]
                    if sent >= len(head):
                        sent -= len(head)
                        q.popleft()
                    else:
                        view = (head if isinstance(head, memoryview)
                                else memoryview(head))
                        q[0] = view[sent:]
                        sent = 0
        except OSError:
            q.clear()                     # peer died mid-write: the read
            self._outbox_bytes[dst] = 0   # side will observe EOF and _drop

    def _flush_outboxes(self) -> None:
        with self._wlock:
            dsts = [d for d, q in self._outbox.items() if q]
        for dst in dsts:
            with self._wlock:
                conn = self._conns.get(dst)
                if conn is None or self._closed:
                    self._outbox.pop(dst, None)
                    self._outbox_bytes.pop(dst, None)
                    continue
                lock = self._conn_locks.setdefault(dst, threading.Lock())
            with lock:
                self._drain_outbox_locked(dst, conn)

    def recv(self, dst: str, now: float) -> list[tuple[float, Any]]:
        if dst != self.local:
            raise ValueError(f"recv for {dst!r} on endpoint {self.local!r}: "
                             f"a socket transport only receives locally")
        self._poll(0)
        out = []
        while self._inbox and self._inbox[0][0] <= now:
            t, _, msg = heapq.heappop(self._inbox)
            out.append((t, msg))
        return out

    def next_delivery(self, dst: str) -> float | None:
        if dst != self.local:
            raise ValueError(f"next_delivery for {dst!r} on endpoint "
                             f"{self.local!r}")
        if not self._inbox:
            self._poll(self.poll_interval_s)
        return self._inbox[0][0] if self._inbox else None

    # ------------------------------------------------------------------
    # Wire accounting
    # ------------------------------------------------------------------

    def wire_stats(self) -> dict[str, dict[str, int]]:
        """Per-endpoint tx/rx byte and frame counters (bytes enqueued to /
        decoded from each peer; dropped-to-the-void frames are not tx)."""
        return {name: dict(s) for name, s in self._stats.items()}

    def wire_totals(self) -> dict[str, int]:
        """Counters summed across endpoints — the scheduler snapshots this
        around each round to attribute bytes to rounds."""
        tot = _new_stat()
        for s in self._stats.values():
            for k in tot:
                tot[k] += s[k]
        return tot

    # ------------------------------------------------------------------
    # Lifecycle / orchestration helpers
    # ------------------------------------------------------------------

    def endpoints(self) -> list[str]:
        """Currently registered remote endpoints (master side: the workers)."""
        return sorted(self._conns)

    def wait_for_endpoints(self, names: list[str], timeout_s: float = 30.0
                           ) -> None:
        """Block until every named endpoint has connected + HELLOed."""
        deadline = time.monotonic() + timeout_s
        while not all(n in self._conns for n in names):
            if time.monotonic() > deadline:
                missing = [n for n in names if n not in self._conns]
                raise TimeoutError(f"endpoints never connected: {missing}")
            self._poll(self.poll_interval_s)

    def close(self) -> None:
        with self._wlock:
            self._closed = True
        for timer in self._timers:
            timer.cancel()
        for sock in list(self._readers):
            try:
                self._sel.unregister(sock)
            except (KeyError, ValueError):
                pass
            sock.close()
        self._readers.clear()
        self._names.clear()
        self._conns.clear()
        if self._listener is not None:
            try:
                self._sel.unregister(self._listener)
            except (KeyError, ValueError):
                pass
            self._listener.close()
            self._listener = None
        self._sel.close()

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
