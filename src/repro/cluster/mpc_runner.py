"""MPCClusterRunner: the BGW baseline run as a REAL distributed protocol.

The measured half of the paper's headline comparison (§5, Figs. 5-7):
PRs 2-3 made CodedPrivateML training run through the cluster runtime;
before this module the MPC side of `speedup_vs_mpc` was a *modeled*
counterfactual (an analytic max-over-workers per communication round).
Here the BGW protocol itself crosses the same Transport/EventScheduler
stack — same clocks, same latency models, same wire — so the speedup is a
measurement of protocol structure, not a formula.

Division of labor mirrors runner.ClusterRunner exactly:

  * the scheduler moves messages and time (`EventScheduler.run_mpc_round`:
    dispatch -> reshare barrier(s) -> collect the first 2T+1 final shares);
  * ALL numerics run through the per-phase hooks of core/mpc_baseline —
    the exact functions `_step_jit` composes — with reconstruction taken
    at the OBSERVED first-2T+1 arrival subset (`reconstruct_at`: any 2T+1
    correct shares of a degree-2T sharing interpolate to the same field
    element, exactly).  Consequence: an MPC cluster run — simulated or
    over sockets — is BIT-IDENTICAL to ``mpc_baseline.train`` with the
    same key (tests/test_mpc_cluster.py), stragglers included.

What the runtime CANNOT give BGW is erasure tolerance: every degree
reduction needs sub-shares from ALL N workers before anyone can combine,
so each of the r reshare phases is gated on the slowest worker (the
wait-for-all the paper contrasts with first-T decoding), and a dead
worker starves the round outright — there is no MPC analogue of riding
through a crash.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.latency import LatencyModel, make_latency
from repro.cluster.membership import ClusterMembership
from repro.cluster.messages import (
    PROVISION_ROUND,
    SHUTDOWN_ROUND,
    EncodeShare,
    worker_endpoint,
)
from repro.cluster.runner import await_worker_acks, wait_summary
from repro.cluster.scheduler import (
    ClusterDecodeError,
    EventScheduler,
    MPCRoundTrace,
)
from repro.cluster.transport import Transport
from repro.core import field
from repro.core import mpc_baseline as mpc
from repro.obs.metrics import MetricsRegistry
from repro.runtime.resilience import HeartbeatMonitor


def mpc_phase_models(name: str, seed: int = 0, r: int = 1
                     ) -> list[LatencyModel]:
    """One latency model per BGW phase: r reshare rounds + the final send.

    Phase 0 reuses the coded run's exact (seed, round, worker) stream and
    each extra phase gets a disjointly-seeded stream sampled at the same
    round index — the same pairing bench_cluster's analytic model has
    always used, so measured and modeled MPC numbers share noise semantics.
    """
    return [make_latency(name, seed=seed if j == 0 else seed + 7919 * j)
            for j in range(r + 1)]


class MPCClusterRunner:
    """Drives ``iters`` BGW iterations through the event scheduler.

    Two transports, one round loop (DESIGN.md §7):

      * ``phase_latency`` given (list of r+1 models) — in-process
        simulation: the scheduler enacts the workers through every reshare
        barrier; the runner computes all worker phases on the master via
        the vectorized oracle hooks and reconstructs from the observed
        first-2T+1 arrival order.
      * ``phase_latency=None`` + a real transport — N worker processes
        (launch/cpml_worker.py, MPC serve mode) run the phases themselves,
        resharing through the master's relay; the runner encodes + ships
        w-shares and reshare keys, and reconstructs from the first 2T+1
        CombineResult payloads received.  ``provision()`` must run once
        before rounds.
    """

    def __init__(self, cfg: mpc.MPCConfig, key, x, y,
                 phase_latency: list[LatencyModel] | None = None, *,
                 eta: float | None = None,
                 transport: Transport | None = None,
                 round_timeout_s: float = math.inf,
                 heartbeat_timeout_s: float = math.inf,
                 master_overhead_s: float = 0.0,
                 recorder=None,
                 metrics: MetricsRegistry | None = None):
        from repro.core import protocol as cpml
        self.cfg = cfg
        self.collect_threshold = 2 * cfg.T + 1
        ksetup, self.kloop = jax.random.split(key)
        self.state = mpc.setup(cfg, ksetup, x, y)
        self.eta = (cpml.lipschitz_eta(self.state.xq_real)
                    if eta is None else eta)
        self.phase_latency = phase_latency
        self.scheduler = EventScheduler(
            cfg.N,
            None if phase_latency is None else phase_latency[0],
            transport, master_overhead_s=master_overhead_s,
            recorder=recorder)
        # same flight-recorder wiring as ClusterRunner (DESIGN.md §11): the
        # MPC barrier structure becomes spans on the shared clock
        self.obs = self.scheduler.obs
        self.obs.bind_clock(self.scheduler.time.now)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_rounds = self.metrics.counter(
            "mpc_rounds_total", "completed BGW iterations")
        self._m_wait = self.metrics.histogram(
            "mpc_round_wait_seconds",
            "dispatch to (2T+1)-th final share, per iteration")
        self.round_timeout_s = round_timeout_s
        if self.distributed and math.isinf(round_timeout_s):
            self.round_timeout_s = 300.0   # real silence must be detectable
        self.monitor = HeartbeatMonitor(cfg.N, timeout_s=heartbeat_timeout_s,
                                        now=self.scheduler.clock)
        # BGW's fleet is a MembershipView too (DESIGN.md §13), but a FIXED
        # one: N is baked into every reshare polynomial, so there are no
        # spare evaluation points to join on and a permanent leave is
        # terminal — the membership still owns the worker set so the
        # scheduler never reads a frozen int
        self.membership = ClusterMembership(range(cfg.N),
                                            monitor=self.monitor)
        self.scheduler.bind_membership(self.membership)
        self.w = self.state.w
        self.traces: dict[int, MPCRoundTrace] = {}
        self._encode = jax.jit(
            lambda k, w: mpc.encode_step(cfg, k, w)[0])
        self._g_shares = jax.jit(
            lambda k, w: _all_g_shares(cfg, k, w, self.state.x_shares))
        self._finish = jax.jit(
            lambda w, dec: mpc.finish_update(
                cfg, w, dec, self.state.xty,
                jnp.float32(self.eta / self.state.m)))

    @property
    def distributed(self) -> bool:
        return self.phase_latency is None

    # ------------------------------------------------------------------
    # Distributed-mode lifecycle
    # ------------------------------------------------------------------

    def provision(self, timeout_s: float = 60.0) -> None:
        """Ship each worker its FULL-dataset Shamir share + static context
        (the encode-everything-everywhere cost the paper charges BGW)."""
        assert self.distributed, "provision() is for real transports only"
        tr = self.scheduler.transport
        x_shares = np.asarray(self.state.x_shares)
        cfg_kw = {"N": self.cfg.N, "T": self.cfg.T, "r": self.cfg.r,
                  "lx": self.cfg.lx, "lw": self.cfg.lw, "lc": self.cfg.lc,
                  "p": self.cfg.p}
        now = self.scheduler.clock
        members = list(self.membership.view().members)
        for w in members:
            tr.send(worker_endpoint(w),
                    EncodeShare(PROVISION_ROUND, w,
                                {"protocol": "mpc", "cfg": cfg_kw,
                                 "x_share": x_shares[w],
                                 "cbar": mpc.poly_coeffs(self.cfg),
                                 "trace": bool(self.obs.enabled)}),
                    at=now)
        await_worker_acks(tr, lambda: self.scheduler.clock, set(members),
                          self.monitor, timeout_s)

    def shutdown_workers(self) -> None:
        assert self.distributed
        now = self.scheduler.clock
        for w in self.membership.view().members:
            self.scheduler.transport.send(
                worker_endpoint(w), EncodeShare(SHUTDOWN_ROUND, w), at=now)

    # ------------------------------------------------------------------
    # One iteration
    # ------------------------------------------------------------------

    def step_round(self, t: int) -> MPCRoundTrace:
        rspan = self.obs.begin("mpc_round", round=t)
        try:
            return self._step_round_inner(t)
        except ClusterDecodeError:
            self.obs.instant("starved", round=t)
            raise
        finally:
            self.obs.end(rspan)

    def _step_round_inner(self, t: int) -> MPCRoundTrace:
        cfg = self.cfg
        key_t = mpc.iteration_key(self.kloop, t)
        payloads = None
        if self.distributed:
            # encode this iteration's weight shares + reshare keys and ship
            # one slice to each worker; field elements are exact int32 and
            # PRNG keys replay exactly, so the phases a worker process runs
            # are bit-identical to the oracle's vmap lanes.
            _, _, kred = mpc.step_keys(cfg, key_t)
            w_shares = np.asarray(self._encode(key_t, self.w))  # (N, d, r)
            kred_np = np.stack([np.asarray(k) for k in kred])
            payloads = {w: {"w_share": w_shares[w], "kred": kred_np}
                        for w in range(cfg.N)}
        trace = self.scheduler.run_mpc_round(
            t, self.collect_threshold, phase_models=self.phase_latency,
            monitor=self.monitor, timeout_s=self.round_timeout_s,
            payloads=payloads)
        if not math.isfinite(trace.t_done):
            raise ClusterDecodeError(
                f"MPC round {t}: {len(trace.responders)} final shares < "
                f"2T+1 = {self.collect_threshold} within "
                f"{self.round_timeout_s}s — BGW cannot ride through a "
                f"dead or stalled worker")
        order = np.asarray(trace.responders[: self.collect_threshold])
        if self.distributed:
            g = jnp.asarray(np.stack(
                [np.asarray(trace.payloads[int(w)], dtype=np.int32)
                 for w in order]))
        else:
            g = jnp.take(self._g_shares(key_t, self.w),
                         jnp.asarray(order, jnp.int32), axis=0)
        decoded = mpc.reconstruct_at(cfg, g, order)
        self.w = self._finish(self.w, decoded)
        self.traces[t] = trace
        if self.obs.enabled:
            # the wait-for-all structure BGW pays: one span from dispatch to
            # the (2T+1)-th final share, under the open "mpc_round" span;
            # worker-side barrier phases arrive via the traced CombineResult
            self.obs.add_span("wait", trace.t_start, trace.t_done, round=t,
                              responders=len(trace.responders))
            for w, spans in trace.worker_traces.items():
                self.obs.add_process_spans(f"worker{int(w)}", spans, round=t)
        self._m_rounds.inc()
        self._m_wait.observe(trace.mpc_wait_s)
        return trace

    def run(self, iters: int):
        """No resilient variant: a starved round is terminal for BGW."""
        self.w = self.state.w
        self.traces.clear()
        for t in range(iters):
            self.step_round(t)
        return self.w

    # ------------------------------------------------------------------
    # Stats (same aggregation keys as runner.wait_stats)
    # ------------------------------------------------------------------

    def wait_stats(self) -> dict[str, dict[str, float]]:
        trs = sorted(self.traces.values(), key=lambda r: r.round)
        waits = np.array([r.mpc_wait_s for r in trs])
        allw = np.array([r.all_wait_s for r in trs])
        return {"mpc": wait_summary(waits),
                "mpc_all": wait_summary(allw[np.isfinite(allw)]),
                "rounds": {"n": float(len(trs))}}


def _all_g_shares(cfg: mpc.MPCConfig, key, w, x_shares):
    """All N workers' final degree-2T gradient shares for one iteration —
    the oracle's `_step_jit` body up to (but excluding) reconstruction,
    composed from the identical hooks."""
    cbar = jnp.asarray(mpc.poly_coeffs(cfg), jnp.int32)
    w_shares, kred = mpc.encode_step(cfg, key, w)
    z = jax.vmap(lambda xs, ws: mpc.worker_mul(cfg, xs, ws))(
        x_shares, w_shares)
    z = mpc.degree_reduce(cfg, kred[0], z)
    prod = z[..., 0]
    s = mpc.s_init(cfg, cbar, prod)
    for i in range(2, cfg.r + 1):
        prod = field.mulmod(prod, z[..., i - 1], cfg.p)
        prod = mpc.degree_reduce(cfg, kred[i - 1], prod)
        s = mpc.s_accum(cfg, cbar[i], s, prod)
    return jax.vmap(lambda xs, ss: mpc.worker_final(cfg, xs, ss))(
        x_shares, s)
