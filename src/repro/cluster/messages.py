"""Typed master<->worker(<->worker) messages + endpoint naming (DESIGN.md §7).

Every in-flight unit of the cluster protocol is one of these frozen
dataclasses.  Payloads are deliberately ``Any``: the in-process simulation
carries lightweight references (the numeric work stays on-device in
core/protocol — see runner.py), while the multi-process socket transport
carries serialized arrays (wire.py) through the SAME message types —
EncodeShare ships the round's weight share W̃_i, WorkerResult ships the
worker's (d, c) field evaluation.

The MPC baseline (cluster/mpc_runner.py) adds worker<->worker traffic:
SubShare is one worker's degree-T re-share of its product share addressed
to one peer (the all-to-all round of BGW degree reduction), CombineResult
is the worker's post-barrier final share back to the master.  Keeping them
distinct from WorkerResult means the coded collect loop can never mistake
MPC traffic for CPML results.
"""
from __future__ import annotations

import dataclasses
from typing import Any

MASTER = "master"

# Control "rounds" for real worker processes (launch/cpml_worker.py): a
# provisioning EncodeShare carries the worker's coded dataset share + static
# round context before round 0; a shutdown EncodeShare ends the serve loop.
# Real rounds are >= 0, so neither can collide with training traffic.
PROVISION_ROUND = -1
SHUTDOWN_ROUND = -2


def worker_endpoint(worker: int) -> str:
    return f"worker/{worker}"


# The canonical per-(worker, round) dispatch payload the scheduler ships in
# one EncodeShare (runner.step_round): the round's weight share, this
# round's batch rows, and — when pipelining — the NEXT round's rows so the
# worker can pre-slice.  Wire v2 coalesces exactly this dict into a single
# compact ROUND frame (wire.py); any other payload shape (provisioning,
# shutdown, tests) rides the generic encoding unchanged.
ROUND_PAYLOAD_KEYS = ("w_share", "batch", "next_batch")


@dataclasses.dataclass(frozen=True)
class EncodeShare:
    """Master -> worker: round t's coded weight share (+ optional batch)."""
    round: int
    worker: int
    payload: Any = None          # weight-share ref / serialized W̃_i


@dataclasses.dataclass(frozen=True)
class WorkerResult:
    """Worker -> master: the worker's polynomial evaluation f(X̃_i, W̃_i).

    ``trace`` is the optional piggy-backed worker-side span list (DESIGN.md
    §11): ``[name, start, end]`` triples on the WORKER's monotonic clock
    (recv/compute/serialize/send phases).  It rides a v2-only wire frame —
    a v1 peer's serialization simply omits it, the same negotiation shape
    as HELLO2 — and is None unless the master asked for tracing at
    provisioning.
    """
    round: int
    worker: int
    compute_s: float             # simulated compute+network time this round
    payload: Any = None          # result ref / serialized (d, c) field array
    trace: Any = None            # worker-clock span triples (v2 wire only)


@dataclasses.dataclass(frozen=True)
class SubShare:
    """Worker src -> worker dst: one degree-T re-share of src's degree-2T
    product share, for BGW degree reduction ``phase`` of round ``round``.

    The all-to-all exchange of these is the wait-for-all barrier MPC pays
    per multiplication: every recipient needs ALL N sub-shares before it can
    Lagrange-combine, so one straggler stalls everyone (DESIGN.md §7).
    """
    round: int
    phase: int                   # which degree reduction of this round
    src: int
    dst: int
    payload: Any = None          # sub-share ref / serialized field array


@dataclasses.dataclass(frozen=True)
class CombineResult:
    """Worker -> master: the worker's final degree-2T gradient share, sent
    after the last reshare barrier of round ``round`` (the master
    reconstructs from the first 2T+1 of these)."""
    round: int
    worker: int
    compute_s: float             # worker-side compute time this round
    payload: Any = None          # result ref / serialized (d,) field array
    trace: Any = None            # worker-clock span triples incl. barrier
                                 # phases (v2 wire only, like WorkerResult)


@dataclasses.dataclass(frozen=True)
class Query:
    """Client -> master: one prediction request for the serving plane
    (cluster/serve.py).

    ``sent_at`` is the client-clock submission time — the open-loop load
    generator stamps the arrival schedule here, and every served latency
    (queue wait + batching + dispatch + decode) is measured from it.
    """
    qid: int
    client: str
    sent_at: float               # client submission time (latency epoch)
    x: Any = None                # (rows, d) feature block / serialized array


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Master -> client: the decoded logits answering one Query.

    Decoded at the first `deg_f*(K+T-1)+1` responders of the query's coded
    flush — exact Lagrange interpolation, so ``y`` is bit-identical to the
    uncoded plaintext evaluation regardless of WHICH workers responded.
    """
    qid: int
    client: str
    y: Any = None                # (rows, c) real logits
    latency_s: float = 0.0       # sent_at -> decode completion


@dataclasses.dataclass(frozen=True)
class Join:
    """Worker -> master: a late worker asks to enter the fleet (elastic
    membership, DESIGN.md §13).

    Sent right after the transport HELLO by a worker started with
    ``--join-at-round``: ``worker`` is the spare slot it answers for,
    ``at_round`` the first round fence it wants to be dispatched from.  The
    master stashes the request and admits the worker at the fence —
    provisioning its pre-encoded spare share, bumping the membership epoch,
    and broadcasting the new Epoch.  Wire v2 only: a v1 fleet has no JOIN
    frame and keeps fixed-fleet semantics bit-identically.
    """
    worker: int
    at_round: int
    sent_at: float = 0.0             # worker-clock request time


@dataclasses.dataclass(frozen=True)
class Epoch:
    """Master -> workers: the membership epoch changed (join/leave).

    Informational fan-out so workers can stamp their spans/metrics with the
    fleet generation they computed under; the master's own round math never
    depends on a worker having seen it (the epoch fence lives master-side).
    Wire v2 only — the master skips v1 peers, whose byte stream stays
    bit-identical to the fixed-fleet protocol.
    """
    epoch: int
    members: Any = None              # tuple of active slots (int32-able)
    round: int = 0                   # fence round the transition landed at


@dataclasses.dataclass(frozen=True)
class Heartbeat:
    """Worker -> master liveness ack, sent on receipt of an EncodeShare.

    Dead workers never ack; the HeartbeatMonitor's timeout turns silence
    into exclusion from the next round's dispatch set.
    """
    worker: int
    sent_at: float               # simulated send time
