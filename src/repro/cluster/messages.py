"""Typed master<->worker messages + endpoint naming (DESIGN.md §7).

Every in-flight unit of the cluster protocol is one of three frozen
dataclasses.  Payloads are deliberately ``Any``: the in-process simulation
carries lightweight references (the numeric work stays on-device in
core/protocol — see runner.py), while the multi-process socket transport
carries serialized arrays (wire.py) through the SAME message types —
EncodeShare ships the round's weight share W̃_i, WorkerResult ships the
worker's (d, c) field evaluation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

MASTER = "master"

# Control "rounds" for real worker processes (launch/cpml_worker.py): a
# provisioning EncodeShare carries the worker's coded dataset share + static
# round context before round 0; a shutdown EncodeShare ends the serve loop.
# Real rounds are >= 0, so neither can collide with training traffic.
PROVISION_ROUND = -1
SHUTDOWN_ROUND = -2


def worker_endpoint(worker: int) -> str:
    return f"worker/{worker}"


@dataclasses.dataclass(frozen=True)
class EncodeShare:
    """Master -> worker: round t's coded weight share (+ optional batch)."""
    round: int
    worker: int
    payload: Any = None          # weight-share ref / serialized W̃_i


@dataclasses.dataclass(frozen=True)
class WorkerResult:
    """Worker -> master: the worker's polynomial evaluation f(X̃_i, W̃_i)."""
    round: int
    worker: int
    compute_s: float             # simulated compute+network time this round
    payload: Any = None          # result ref / serialized (d, c) field array


@dataclasses.dataclass(frozen=True)
class Heartbeat:
    """Worker -> master liveness ack, sent on receipt of an EncodeShare.

    Dead workers never ack; the HeartbeatMonitor's timeout turns silence
    into exclusion from the next round's dispatch set.
    """
    worker: int
    sent_at: float               # simulated send time
