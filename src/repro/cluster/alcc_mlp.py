"""ALCCMLPRunner: coded MLP training over the cluster runtime (DESIGN.md §14).

The exact engine cannot train the seed MLP (models/layers.gelu_mlp): gelu
and softmax are not field polynomials.  Over the reals they do not need to
be — only the *coded phases* must be polynomial, and one gradient step of
the two-layer MLP splits into exactly two BILINEAR coded phases with all
the nonlinear work on the master in between:

  round 2t   (phase A, forward):  workers compute X̃_i[batch] @ W̃1_i;
             decoding any ``mlp_threshold`` responses yields the per-part
             pre-activations Z1_k = X̄_k[batch] @ W1.
  master     (in the clear):      gelu forward + softmax-CE backward
             through layer 2 (alcc_engine._mlp_middle) -> the W2 gradient
             and the layer-1 deltas δ1_k = ∂loss/∂Z1_k.
  round 2t+1 (phase B, backward): δ1 is ENCODED LIKE DATA (per-part values
             at the K betas + fresh masks) and workers compute
             X̃_i[batch]ᵀ @ δ̃1_i; the decode SUM is the W1 gradient
             Σ_k X̄_kᵀ δ1_k.  Same batch indices ship in both phases.

Both phases are degree-2 in coded inputs, so the per-phase recovery
threshold 2(K+T-1)+1 is LOWER than the logistic round's (2r+1)(K+T-1)+1 at
equal (K, T).  Privacy is the same (T, sigma)-analog statement as the
logistic engine: workers only ever see Lagrange shares of X, W1 and δ1
(δ1 is a function of the labels, so it is masked like the data — the
master never reveals it in the clear).

The runner drives the same EventScheduler as ClusterRunner on BOTH
backends: a latency model simulates the fleet (worker evaluations computed
master-side in float32, exactly what real workers would return), or a
SocketTransport dispatches to real cpml_worker processes provisioned with
``protocol: "alcc_mlp"``.  Verification mirrors the logistic engine's
two-tier contract: a sim run replays bit-for-bit through
``train_reference`` below; a socket run replays to within the decode error
budget; convergence is judged against ``alcc_engine.mlp_oracle``.
"""
from __future__ import annotations

import math
import time as _time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.latency import LatencyModel
from repro.cluster.messages import (
    PROVISION_ROUND, SHUTDOWN_ROUND, EncodeShare, worker_endpoint)
from repro.cluster.runner import await_worker_acks, wait_summary
from repro.cluster.scheduler import ClusterDecodeError, EventScheduler
from repro.cluster.transport import Transport
from repro.core.protocol import alcc_engine
from repro.obs.metrics import MetricsRegistry
from repro.runtime.resilience import HeartbeatMonitor


class ALCCMLPRunner:
    """Drives ``iters`` two-phase MLP steps through the event scheduler.

    Knobs (a deliberate subset of ClusterRunner's — the MLP plane is a
    fixed fleet, no pipeline/elastic/sharded-master machinery):

      * ``latency`` — in-process simulation; ``latency=None`` + a real
        ``transport`` — socket backend (``provision()`` first).
      * ``eta`` — step size for both layers (no Lipschitz auto-tune here;
        the gelu head's curvature is not the logistic bound's).
      * ``round_timeout_s`` — per-PHASE collect deadline on a real
        transport (two phases per step, each its own dispatch + decode).
    """

    def __init__(self, cfg: alcc_engine.ALCCConfig, key, x, y, hidden: int,
                 latency: LatencyModel | None = None, *,
                 eta: float = 0.1,
                 transport: Transport | None = None,
                 round_timeout_s: float = math.inf,
                 heartbeat_timeout_s: float = math.inf,
                 metrics: MetricsRegistry | None = None,
                 recorder=None):
        self.cfg = cfg
        self.hidden = int(hidden)
        self.eta = float(eta)
        self.threshold = cfg.mlp_threshold
        ksetup, self.kloop = jax.random.split(key)
        self.state = alcc_engine.mlp_setup(cfg, ksetup, x, y, hidden)
        self.w1 = self.state.w1
        self.w2 = self.state.w2
        self.scheduler = EventScheduler(cfg.N, latency, transport,
                                        recorder=recorder)
        self.obs = self.scheduler.obs
        self.obs.bind_clock(self.scheduler.time.now)
        self.latency = latency
        self.round_timeout_s = round_timeout_s
        if self.distributed and math.isinf(round_timeout_s):
            self.round_timeout_s = 300.0
        self.monitor = HeartbeatMonitor(cfg.N, timeout_s=heartbeat_timeout_s,
                                        now=self.scheduler.clock)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_steps = self.metrics.counter(
            "cpml_mlp_steps_total", "completed MLP training steps")
        self._m_cond = self.metrics.gauge(
            "cpml_alcc_decode_cond",
            "condition number of the last ALCC least-squares decode")
        self._m_budget = self.metrics.gauge(
            "cpml_alcc_error_budget",
            "a-priori absolute decode error bound of the last ALCC round")
        self._m_fallback = self.metrics.counter(
            "cpml_alcc_decode_fallbacks_total",
            "ALCC decodes that took the overdetermined fallback path")
        self.alcc_info: list[dict] = []
        self.survivors: dict[int, np.ndarray] = {}      # round id -> order
        self.history: list[dict[str, float]] = []
        self._phase_stats: list[dict[str, float]] = []

    @property
    def distributed(self) -> bool:
        return self.latency is None

    # ------------------------------------------------------------------
    # Socket provisioning
    # ------------------------------------------------------------------

    def provision(self, timeout_s: float = 60.0) -> None:
        """Ship each worker its float dataset share + the MLP serve mode.

        The worker acks with a Heartbeat after jitting BOTH phase
        functions (cpml_worker.py), so step-0 timing never absorbs XLA
        compilation — the same contract as ClusterRunner.provision.
        """
        assert self.distributed, "provision() is for real transports only"
        cfg = self.cfg
        wall0 = _time.perf_counter()
        with self.obs.span("provision", workers=cfg.N):
            tr = self.scheduler.transport
            cfg_kw = {"N": cfg.N, "K": cfg.K, "T": cfg.T, "r": cfg.r,
                      "c": cfg.c, "sigma": cfg.sigma,
                      "batch_rows": cfg.batch_rows}
            x_shares = np.asarray(self.state.x_shares, np.float32)
            now = self.scheduler.clock
            for w in range(cfg.N):
                tr.send(worker_endpoint(w),
                        EncodeShare(PROVISION_ROUND, w, {
                            "protocol": "alcc_mlp", "cfg": cfg_kw,
                            "hidden": self.hidden, "x_share": x_shares[w],
                            "trace": bool(self.obs.enabled)}),
                        at=now)
            await_worker_acks(tr, lambda: self.scheduler.clock, cfg.N,
                              self.monitor, timeout_s)
        self.metrics.gauge(
            "cpml_provision_seconds",
            "wall seconds from provisioning dispatch to the last worker "
            "ack (includes worker XLA warmup)").set(
                _time.perf_counter() - wall0)

    def shutdown_workers(self) -> None:
        assert self.distributed
        now = self.scheduler.clock
        for w in range(self.cfg.N):
            self.scheduler.transport.send(
                worker_endpoint(w), EncodeShare(SHUTDOWN_ROUND, w), at=now)

    # ------------------------------------------------------------------
    # One coded phase = one scheduler round
    # ------------------------------------------------------------------

    def _coded_phase(self, rid: int, shares: np.ndarray, batch_np,
                     phase: int) -> tuple[np.ndarray, np.ndarray]:
        """Dispatch round ``rid`` with per-worker ``shares`` and return
        (stacked float32 responses in decode order, order)."""
        cfg = self.cfg
        payloads = None
        enc_t0 = _time.perf_counter()
        if self.distributed:
            payloads = {w: {"w_share": shares[w], "batch": batch_np,
                            "next_batch": None}
                        for w in range(cfg.N)}
        trace = self.scheduler.dispatch_round(
            rid, self.threshold, monitor=self.monitor,
            timeout_s=self.round_timeout_s, payloads=payloads)
        if not math.isfinite(trace.t_first_R):
            raise ClusterDecodeError(
                f"phase round {rid}: {len(trace.responders)} responses < "
                f"threshold {self.threshold}")
        # like the logistic engine, let the least-squares decode pick its
        # row count: square path reads `threshold` rows, the ill-conditioned
        # fallback all responders (core/alcc.py)
        _, info = cfg.scheme.decode_matrix(trace.responders, 2)
        order = np.asarray(trace.responders[: info["rows"]], np.int64)
        if self.distributed:
            fastest = np.stack([np.asarray(trace.payloads[int(w)], np.float32)
                                for w in order])
        else:
            # simulate the worker evaluations in float32, the same ops a
            # real worker's jitted phase function runs
            xb = (self.state.x_shares if batch_np is None
                  else self.state.x_shares[:, batch_np])
            xs = xb[order].astype(np.float32)
            ws = shares[order]
            if phase == 0:
                fastest = np.einsum("rbd,rdh->rbh", xs, ws)
            else:
                fastest = np.einsum("rbd,rbh->rdh", xs, ws)
            fastest = fastest.astype(np.float32)
        self._phase_stats.append({
            "wait_s": trace.t_first_R - trace.t_start,
            "encode_s": _time.perf_counter() - enc_t0
            if self.distributed else 0.0})
        self.survivors[rid] = np.asarray(trace.responders).copy()
        return fastest, order

    def _track(self, info: dict, rid: int) -> None:
        self.alcc_info.append(info)
        self._m_cond.set(float(info["cond"]))
        self._m_budget.set(float(info["abs_err_budget"]))
        if info["fallback"]:
            self._m_fallback.inc()
        self.obs.instant("alcc_decode", round=rid,
                         cond=float(info["cond"]),
                         err_budget=float(info["abs_err_budget"]),
                         fallback=bool(info["fallback"]))

    def step(self, t: int, iters: int) -> None:
        """One MLP gradient step = phase A round, master middle, phase B
        round, then the two-layer update."""
        cfg = self.cfg
        with self.obs.span("mlp_step", step=t):
            bidx = (np.asarray(alcc_engine.draw_batch(
                        cfg, self.kloop, iters, self.state.mk, t))
                    if cfg.batch_rows is not None else None)
            kA = alcc_engine.round_key(self.kloop, 2 * t)
            kB = alcc_engine.round_key(self.kloop, 2 * t + 1)
            w1_shares = alcc_engine.mlp_encode_forward(cfg, kA, self.w1)
            fast, order = self._coded_phase(2 * t, w1_shares, bidx, 0)
            z1_parts, info = alcc_engine.mlp_decode_forward(cfg, fast, order)
            self._track(info, 2 * t)
            gw2, dz1, loss, acc = alcc_engine.mlp_middle(
                cfg, self.state, z1_parts, bidx)
            d1_shares = alcc_engine.mlp_encode_backward(cfg, kB, dz1)
            fast, order = self._coded_phase(2 * t + 1, d1_shares, bidx, 1)
            gw1, info = alcc_engine.mlp_decode_backward(cfg, fast, order)
            self._track(info, 2 * t + 1)
            self.w1 = jnp.asarray(
                np.asarray(self.w1, np.float64) - self.eta * gw1, jnp.float32)
            self.w2 = self.w2 - self.eta * gw2
            self.history.append({"step": t, "loss": float(loss),
                                 "acc": float(acc)})
        self._m_steps.inc()

    def run(self, iters: int):
        """Train for ``iters`` steps from the initial weights; returns
        (w1, w2)."""
        self.w1, self.w2 = self.state.w1, self.state.w2
        self.alcc_info.clear()
        self.survivors.clear()
        self.history.clear()
        self._phase_stats.clear()
        for t in range(iters):
            self.step(t, iters)
        return self.w1, self.w2

    # ------------------------------------------------------------------
    # Verification + stats
    # ------------------------------------------------------------------

    def survivor_fn(self) -> Callable[[int], np.ndarray]:
        """Round-id (2t / 2t+1) -> observed responders, for
        train_reference replay."""
        trace = dict(self.survivors)
        return lambda rid: trace[rid]

    def wait_stats(self) -> dict[str, dict[str, float]]:
        stats = {
            "coded_T": wait_summary([p["wait_s"] for p in self._phase_stats]),
            "encode": wait_summary(
                [p["encode_s"] for p in self._phase_stats]),
            "alcc": {
                "cond": wait_summary([i["cond"] for i in self.alcc_info]),
                "abs_err_budget": wait_summary(
                    [i["abs_err_budget"] for i in self.alcc_info]),
                "fallbacks": {"n": float(sum(
                    1 for i in self.alcc_info if i["fallback"]))},
            },
            "rounds": {"n": float(len(self._phase_stats))},
        }
        wire_totals = getattr(self.scheduler.transport, "wire_totals", None)
        if wire_totals is not None:
            stats["wire_totals"] = {k: float(v)
                                    for k, v in wire_totals().items()}
        return stats

    def metrics_now(self) -> tuple[float, float]:
        """Full-data (loss, accuracy) of the current weights."""
        return alcc_engine.mlp_metrics(self.state, self.w1, self.w2)


def train_reference(cfg: alcc_engine.ALCCConfig, key, x, y, hidden: int,
                    iters: int, eta: float,
                    survivor_fn: Callable[[int], np.ndarray] | None = None):
    """Schedulerless replay of the two-phase loop over the same hooks.

    With a runner's ``survivor_fn()`` this reproduces a SIMULATED run's
    weights bit-for-bit and a socket run's to within the decode error
    budget (cf. the module docstring).  Returns (w1, w2, history).
    """
    ksetup, kloop = jax.random.split(jnp.asarray(key))
    state = alcc_engine.mlp_setup(cfg, ksetup, x, y, hidden)
    w1, w2 = state.w1, state.w2
    history = []
    for t in range(iters):
        bidx = (np.asarray(alcc_engine.draw_batch(
                    cfg, kloop, iters, state.mk, t))
                if cfg.batch_rows is not None else None)
        surv = [survivor_fn(2 * t) if survivor_fn is not None else None,
                survivor_fn(2 * t + 1) if survivor_fn is not None else None]
        orders = []
        for rid, sv in zip((2 * t, 2 * t + 1), surv):
            sv = np.arange(cfg.N) if sv is None else np.asarray(sv)
            _, info = cfg.scheme.decode_matrix(sv, 2)
            orders.append(np.asarray(sv[: info["rows"]], np.int64))
        kA = alcc_engine.round_key(kloop, 2 * t)
        kB = alcc_engine.round_key(kloop, 2 * t + 1)
        w1_shares = alcc_engine.mlp_encode_forward(cfg, kA, w1)
        xb = (state.x_shares if bidx is None else state.x_shares[:, bidx])
        xs = xb[orders[0]].astype(np.float32)
        fast = np.einsum("rbd,rdh->rbh", xs, w1_shares[orders[0]]
                         ).astype(np.float32)
        z1_parts, _ = alcc_engine.mlp_decode_forward(cfg, fast, orders[0])
        gw2, dz1, loss, acc = alcc_engine.mlp_middle(cfg, state, z1_parts,
                                                     bidx)
        d1_shares = alcc_engine.mlp_encode_backward(cfg, kB, dz1)
        xs = xb[orders[1]].astype(np.float32)
        fast = np.einsum("rbd,rbh->rdh", xs, d1_shares[orders[1]]
                         ).astype(np.float32)
        gw1, _ = alcc_engine.mlp_decode_backward(cfg, fast, orders[1])
        w1 = jnp.asarray(np.asarray(w1, np.float64) - eta * gw1, jnp.float32)
        w2 = w2 - eta * gw2
        history.append({"step": t, "loss": float(loss), "acc": float(acc)})
    return w1, w2, history
