"""Pipelined round engine: prefetch W-independent work ahead of the decode.

CodedPrivateML's per-round critical path is

    encode W̃ -> dispatch -> wait for the fastest `threshold` -> decode -> step

and only the WAIT involves the workers; encode and decode are master-side
serial time the sequential loop pays every round.  The data dependency is
narrow: round t+1's encode needs round t's DECODED WEIGHTS, but the round
key split, the T fresh privacy masks, their encoded contribution
(encode.weight_mask_shares), the mini-batch draw, and the decode-coefficient
structures for the plausible responder prefixes depend only on (kloop, t) —
they can all be computed while round t is still in flight (DESIGN.md §9).

``RoundPrefetcher`` runs a one-round-ahead producer thread with the same
single-slot mailbox discipline as data/loader.py's ``LMBatchLoader``
prefetch thread: the producer builds round t+1's W-independent
``RoundContext`` while the consumer (cluster/runner.py) is blocked in round
t's collect loop.  Unlike the loader, training can REWIND (checkpoint
restore replays earlier rounds), so ``get(t)`` for an unexpected t resets
the producer to t instead of asserting monotonicity.

Privacy is unaffected: the masks are the SAME fresh per-round draws the
sequential encode makes (identical key derivation), merely computed
earlier on the master — which holds them in either case.  Bit-identity is
structural: every context is a pure function of (cfg, kloop, t), so
prefetched rounds replay exactly (tests/test_pipeline.py).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

import numpy as np

from repro.obs.trace import NULL_RECORDER

PIPELINE_MODES = ("off", "prefetch", "streaming", "full")


@dataclasses.dataclass
class RoundContext:
    """W-independent context for one round, built ahead of its dispatch."""
    t: int
    kq: Any                          # stochastic-quantization key
    mask_shares: np.ndarray          # (N, d, c, r) encoded mask contribution
    batch_idx: Any | None            # (batch_rows,) or None
    plan: Any | None                 # decode.DecodePlan for the predicted
                                     # responder order (None = no prediction)
    next_batch: np.ndarray | None = None
                                     # round t+1's batch indices, shipped to
                                     # workers so they pre-slice while idle
                                     # (drawn here, off the critical path)
    epoch: int = 0                   # membership epoch the context was built
                                     # under; a fence that bumped the epoch
                                     # invalidates only the PLAN (its
                                     # predicted responders referenced the
                                     # old fleet) — kq/masks/batch are pure
                                     # functions of (kloop, t), epoch-free


class RoundPrefetcher:
    """One-round-ahead producer of ``RoundContext``s.

    ``build_fn(t) -> RoundContext`` runs on the producer thread (jax
    dispatch is thread-safe; the GIL is released while XLA executes and
    while the consumer blocks in a socket poll, so the build genuinely
    overlaps the in-flight round).  Use as a context manager or call
    ``close()``: like LMBatchLoader, the thread is joined on close so a
    finished run never leaks a producer.
    """

    def __init__(self, build_fn: Callable[[int], RoundContext],
                 start: int, stop: int, recorder=None):
        self._build = build_fn
        # flight-recorder hook (DESIGN.md §11): builds get their own
        # "prefetch" track so the overlap with the in-flight round is
        # visible in the waterfall; the default NullRecorder is a no-op
        self._obs = recorder if recorder is not None else NULL_RECORDER
        self._stop_t = stop
        self._cond = threading.Condition()
        self._next = start          # next t the producer should build
        self._ready: RoundContext | None = None
        self._halt = False
        # the GATE times the overlap: after get(t) hands a context out the
        # producer stays parked until release() — called by the runner just
        # before it blocks in the collect loop — so the t+1 build competes
        # with the master's idle WAIT, never with its W-dependent encode
        # (which runs on the critical path right after get()).
        self._gate = True
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self) -> None:
        while True:
            with self._cond:
                while not self._halt and (self._ready is not None
                                          or not self._gate
                                          or self._next >= self._stop_t):
                    self._cond.wait()
                if self._halt:
                    return
                t = self._next
            with self._obs.span("prefetch_build", track="prefetch", round=t):
                ctx = self._build(t)                # heavy work, no lock
            with self._cond:
                if self._halt:
                    return
                if self._next == t and self._ready is None:
                    self._ready = ctx               # else: a rewind raced
                    self._next = t + 1              # in; rebuild next loop
                    self._cond.notify_all()

    def get(self, t: int) -> RoundContext:
        """Round t's context: the prefetched one when the producer is on
        track, else (first round, or a rewind after checkpoint restore)
        reset the producer to t and wait for the fresh build.  Parks the
        producer until the next ``release()``."""
        with self._cond:
            if self._ready is not None and self._ready.t == t:
                ctx, self._ready = self._ready, None
                self._gate = False
                self._cond.notify_all()
                return ctx
            self._ready = None                       # stale or absent
            self._next = t
            self._gate = True                        # we NEED a build now
            self._cond.notify_all()
            while not (self._halt
                       or (self._ready is not None and self._ready.t == t)):
                self._cond.wait()
            if self._halt:
                raise RuntimeError("prefetcher closed while waiting")
            ctx, self._ready = self._ready, None
            self._gate = False
            self._cond.notify_all()
            return ctx

    def release(self) -> None:
        """Un-park the producer: the caller is about to block waiting on
        workers, so the next round's build can use the idle master."""
        with self._cond:
            self._gate = True
            self._cond.notify_all()

    def close(self) -> None:
        """Stop and JOIN the producer thread (idempotent)."""
        with self._cond:
            self._halt = True
            self._cond.notify_all()
        self._thread.join(timeout=10.0)

    def __enter__(self) -> "RoundPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
