"""Per-worker latency models for the cluster simulation (DESIGN.md §7).

Every model maps (round, worker) -> simulated seconds of compute+network
time for that worker's response; ``math.inf`` means the response never
arrives (dead worker).  Two properties matter for the runtime:

  * SEEDED + ORDER-INDEPENDENT: ``sample(t, w)`` derives a private RNG
    stream from ``(seed, t, w)`` — the same call returns the same value
    regardless of call order or how many other samples were drawn.  This is
    what makes checkpoint-restore REPLAY deterministic (ResilientLoop
    re-runs rounds; the cluster must re-observe the same latencies).
  * HEAVY TAILS ON DEMAND: the paper's EC2 speedup comes from not waiting
    for the slow tail; the lognormal-tail and bursty-straggler models
    reproduce that tail so BENCH_cluster.json can measure the Fig. 5 effect.
"""
from __future__ import annotations

import abc
import math

import numpy as np


def _rng(seed: int, *ids: int) -> np.random.Generator:
    """Private RNG stream for one (round, worker) draw — order-independent."""
    return np.random.default_rng((int(seed),) + tuple(int(i) for i in ids))


class LatencyModel(abc.ABC):
    @abc.abstractmethod
    def sample(self, round: int, worker: int) -> float:
        """Simulated response latency in seconds; math.inf = never arrives."""

    def revive(self, worker: int, at_round: int) -> None:
        """Node replacement hook; a no-op unless the model kills workers."""


class DeterministicLatency(LatencyModel):
    """Fixed per-worker latency with a linear skew: worker i always takes
    ``base * (1 + skew * i)``.  The replayable no-noise baseline."""

    def __init__(self, base: float = 1.0, skew: float = 0.05):
        self.base = base
        self.skew = skew

    def sample(self, round: int, worker: int) -> float:
        return self.base * (1.0 + self.skew * worker)


class LognormalTailLatency(LatencyModel):
    """Lognormal body with an occasional multiplicative heavy tail.

    latency = base * LogNormal(0, sigma), multiplied by ``tail_scale`` with
    probability ``tail_prob`` — the classic EC2 straggler distribution
    (most responses tight around base, a few 10x outliers).
    """

    def __init__(self, seed: int = 0, base: float = 1.0, sigma: float = 0.3,
                 tail_prob: float = 0.05, tail_scale: float = 10.0):
        self.seed = seed
        self.base = base
        self.sigma = sigma
        self.tail_prob = tail_prob
        self.tail_scale = tail_scale

    def sample(self, round: int, worker: int) -> float:
        rng = _rng(self.seed, 0, round, worker)
        lat = self.base * math.exp(rng.normal(0.0, self.sigma))
        if rng.random() < self.tail_prob:
            lat *= self.tail_scale
        return lat


class BurstyStragglerLatency(LatencyModel):
    """Markov-style straggling: a worker that enters a burst stays slow for
    ``burst_len`` consecutive rounds (node paging / noisy neighbor), then
    recovers.  Burst membership is computed from scratch per (round, worker)
    — a burst covers round t iff one STARTED in (t - burst_len, t] — so
    sampling stays order-independent despite the temporal correlation.
    """

    def __init__(self, seed: int = 0, base: float = 1.0, sigma: float = 0.1,
                 burst_prob: float = 0.03, burst_len: int = 5,
                 slow_factor: float = 8.0):
        self.seed = seed
        self.base = base
        self.sigma = sigma
        self.burst_prob = burst_prob
        self.burst_len = burst_len
        self.slow_factor = slow_factor

    def _burst_starts(self, round: int, worker: int) -> bool:
        return _rng(self.seed, 1, round, worker).random() < self.burst_prob

    def in_burst(self, round: int, worker: int) -> bool:
        lo = max(0, round - self.burst_len + 1)
        return any(self._burst_starts(s, worker)
                   for s in range(lo, round + 1))

    def sample(self, round: int, worker: int) -> float:
        rng = _rng(self.seed, 2, round, worker)
        lat = self.base * math.exp(rng.normal(0.0, self.sigma))
        if self.in_burst(round, worker):
            lat *= self.slow_factor
        return lat


class SleepyStragglerLatency(LatencyModel):
    """Wraps another model and adds a fixed sleep to chosen workers —
    the simulation analog of cpml_worker's ``--sleep-s`` injection
    (``sleeps={worker: seconds}``), so sim and socket benchmarks inject
    the SAME deterministic straggler shape.
    """

    def __init__(self, inner: LatencyModel, sleeps: dict[int, float]):
        self.inner = inner
        self.sleeps = dict(sleeps)

    def sample(self, round: int, worker: int) -> float:
        return self.inner.sample(round, worker) + self.sleeps.get(worker, 0.0)

    def revive(self, worker: int, at_round: int) -> None:
        self.inner.revive(worker, at_round)


class DeadWorkerLatency(LatencyModel):
    """Wraps another model and kills chosen workers at chosen rounds.

    ``deaths={worker: round}``: the worker stops responding from that round
    on, until ``revive(worker, at_round)`` models its replacement node
    coming up — the worker is then alive again for rounds >= at_round
    (rounds in [death, revival) stay dead on replay, keeping restore-and-
    replay deterministic).
    """

    def __init__(self, inner: LatencyModel, deaths: dict[int, int]):
        self.inner = inner
        self.deaths = dict(deaths)
        self.revivals: dict[int, int] = {}

    def _dead(self, round: int, worker: int) -> bool:
        died = self.deaths.get(worker)
        if died is None or round < died:
            return False
        revived = self.revivals.get(worker)
        return revived is None or round < revived

    def sample(self, round: int, worker: int) -> float:
        if self._dead(round, worker):
            return math.inf
        return self.inner.sample(round, worker)

    def revive(self, worker: int, at_round: int) -> None:
        if worker in self.deaths:
            self.revivals[worker] = at_round


LATENCY_MODELS = ("deterministic", "lognormal", "bursty", "dead")


def make_latency(name: str, seed: int = 0, **kw) -> LatencyModel:
    """CLI/benchmark factory.  ``dead`` wraps lognormal with worker 0 dying
    at round 3 (override via ``deaths={worker: round}``)."""
    if name == "deterministic":
        return DeterministicLatency(**kw)
    if name == "lognormal":
        return LognormalTailLatency(seed=seed, **kw)
    if name == "bursty":
        return BurstyStragglerLatency(seed=seed, **kw)
    if name == "dead":
        deaths = kw.pop("deaths", {0: 3})
        return DeadWorkerLatency(LognormalTailLatency(seed=seed, **kw), deaths)
    raise ValueError(f"unknown latency model {name!r}; "
                     f"choose from {LATENCY_MODELS}")
