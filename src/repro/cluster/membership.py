"""Elastic cluster membership: epoch-numbered worker sets (DESIGN.md §13).

The fixed-N runtime froze the fleet at provision time: a dead worker could
only be speculatively excluded round by round, and a new worker could never
join mid-run.  This module makes membership a first-class mutable object:

  * ``MembershipView`` — an immutable (epoch, members) snapshot.  Every
    round derives its dispatch set, decode matrix, and DecodePlan from ONE
    view taken at the round fence, so a mid-round transition can never mix
    two fleets inside a single round (the epoch fence).
  * ``ClusterMembership`` — the epoch state machine.  JOIN admits a worker
    from the pre-provisioned SPARE pool (extra Lagrange evaluation points
    encoded up front — see below); LEAVE permanently retires a worker the
    failure detector declared dead, instead of re-excluding it every round.
    Each transition bumps the epoch and is logged for the flight recorder.

Spare evaluation points & bit-identity: a ``CodingScheme(N, K, T)`` uses
CONSECUTIVE evaluation points (alphas = K+T+1 .. K+T+N), so the scheme for
N + spares extends the point set without moving the first N points — the
first N columns of the encode matrix, hence shares 0..N-1 and every decode
over survivors drawn from them, are bit-identical to the fixed-N scheme's.
A joiner simply picks up a spare share of the SAME degree-(K+T-1) masked
polynomial: any T shares of it remain jointly uniform, so T-privacy is
unchanged (DESIGN.md §13).

The monitor (runtime/resilience.py HeartbeatMonitor) stays the liveness
authority; ClusterMembership owns WHO is in the fleet and drives
``add_worker``/``remove_worker`` on it as workers join and leave.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class MembershipView:
    """Immutable epoch snapshot: the fleet as one round sees it."""
    epoch: int
    members: tuple[int, ...]        # sorted active worker slots

    def __contains__(self, worker: int) -> bool:
        return int(worker) in self.members

    def __len__(self) -> int:
        return len(self.members)


@dataclasses.dataclass(frozen=True)
class Transition:
    """One membership change, as logged for the flight recorder."""
    epoch: int                      # epoch AFTER the transition
    kind: str                       # "join" | "leave"
    worker: int
    round: int                      # fence round the transition landed at
    at: float                       # scheduler clock at the transition


class ClusterMembership:
    """Epoch state machine over a worker-slot set, with a spare pool.

    ``initial`` seeds epoch 0; ``spares`` are slot ids whose coded shares
    were provisioned up front (extra evaluation points) but which carry no
    live worker yet.  A spare becomes a member via ``admit`` — either as a
    scheduled JOIN (``schedule_join``/``due_joins``) or as the permanent
    replacement pulled by ``leave``.
    """

    def __init__(self, initial: Iterable[int],
                 monitor=None, spares: Iterable[int] = ()):
        self._members: set[int] = {int(w) for w in initial}
        self._spares: list[int] = sorted(int(w) for w in spares)
        assert not (self._members & set(self._spares)), (
            "spare slots must be disjoint from the initial members")
        self.monitor = monitor
        self.epoch = 0
        self.transitions: list[Transition] = []
        self._pending: list[tuple[int, int]] = []   # (slot, at_round)
        self._left: set[int] = set()

    # -- snapshots ------------------------------------------------------

    def view(self) -> MembershipView:
        """The epoch fence: one immutable snapshot per round."""
        return MembershipView(self.epoch, tuple(sorted(self._members)))

    def __len__(self) -> int:
        return len(self._members)

    def __contains__(self, worker: int) -> bool:
        return int(worker) in self._members

    @property
    def spares(self) -> tuple[int, ...]:
        return tuple(self._spares)

    # -- scheduled joins ------------------------------------------------

    def schedule_join(self, worker: int, at_round: int) -> None:
        """Register a JOIN request (late HELLO): ``worker`` wants to enter
        the fleet at the first round fence with t >= at_round.  Idempotent
        per slot; a slot that already left may rejoin (resilient restore)."""
        worker = int(worker)
        if worker in self._members:
            return
        if any(w == worker for w, _ in self._pending):
            return
        self._pending.append((worker, int(at_round)))

    def due_joins(self, t: int) -> list[int]:
        """Pending joiners whose at_round has arrived, in request order."""
        return [w for w, r in self._pending if r <= t]

    def take_spare(self) -> int | None:
        """Pop the lowest pre-provisioned spare slot (None = pool dry)."""
        return self._spares.pop(0) if self._spares else None

    # -- transitions (each bumps the epoch) -----------------------------

    def admit(self, worker: int, round: int, now: float = 0.0
              ) -> MembershipView:
        """JOIN: move a slot into the member set; new epoch.

        The slot's coded share already exists (spare evaluation point), so
        admission is pure bookkeeping plus telling the monitor a fresh
        worker now answers for the slot.
        """
        worker = int(worker)
        assert worker not in self._members, f"worker {worker} already member"
        self._members.add(worker)
        self._spares = [s for s in self._spares if s != worker]
        self._pending = [(w, r) for w, r in self._pending if w != worker]
        self._left.discard(worker)
        self.epoch += 1
        if self.monitor is not None:
            self.monitor.add_worker(worker, now=now)
        self.transitions.append(
            Transition(self.epoch, "join", worker, int(round), float(now)))
        return self.view()

    def leave(self, worker: int, round: int, now: float = 0.0
              ) -> MembershipView:
        """LEAVE: permanently retire a slot the detector declared dead; new
        epoch.  The slot is never dispatched again (no per-round
        re-exclusion); its monitor entry is removed with it.  The caller
        decides whether a spare replaces it (``take_spare`` + ``admit``)."""
        worker = int(worker)
        assert worker in self._members, f"worker {worker} not a member"
        self._members.discard(worker)
        self._left.add(worker)
        self.epoch += 1
        if self.monitor is not None:
            self.monitor.remove_worker(worker)
        self.transitions.append(
            Transition(self.epoch, "leave", worker, int(round), float(now)))
        return self.view()

    @property
    def departed(self) -> frozenset[int]:
        return frozenset(self._left)
